#!/usr/bin/env sh
# Tier-1 verify wrapper: reproducible on CPU-only hosts with no network.
# The sharded subprocess tests need >= 8 (fake) devices; pytest.ini puts
# src/ and tests/ on sys.path.
set -eu
cd "$(dirname "$0")/.."
XLA_FLAGS="--xla_force_host_platform_device_count=${XLA_DEVICES:-8}" \
    exec python -m pytest -x -q "$@"
