"""Fault-tolerant training demo — train a small LM on the synthetic
chain task with periodic checkpoints WHILE a failure plan kills the
"node" twice (once mid-step, once mid-checkpoint-save); the runner
restarts from the latest atomic checkpoint each time and the final
parameters are bit-identical to an uninterrupted run.

    PYTHONPATH=src python examples/train_ft.py [--steps 60]
"""
import argparse
import shutil
import tempfile

import jax

from repro import configs
from repro.data import DataConfig, entropy_floor
from repro.models import registry
from repro.optim import adamw, warmup_cosine
from repro.train import FailurePlan, Trainer, TrainerConfig


def run(steps, ckpt_dir, plan=None, seed=3):
    cfg = configs.smoke("xlstm-125m")
    model = registry.build(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                      global_batch=8, seed=seed)
    tcfg = TrainerConfig(total_steps=steps, ckpt_dir=ckpt_dir,
                         ckpt_interval=10, seed=seed)
    opt = adamw(warmup_cosine(3e-3, 5, steps))
    tr = Trainer(model, opt, data, tcfg, failure_plan=plan)
    state = tr.run()
    return tr, state, data


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=60)
    args = p.parse_args()

    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        print("== reference run (no failures)")
        ref_tr, ref_state, data = run(args.steps, d1)
        print(f"   loss {ref_tr.history[0]['loss']:.3f} -> "
              f"{ref_tr.history[-1]['loss']:.3f} "
              f"(entropy floor ~{entropy_floor(data):.3f})")

        mid = args.steps // 2
        plan = FailurePlan(crash_at=(mid,), crash_in_save=(mid + 10,))
        print(f"== faulty run (crash at step {mid}, crash-in-save at "
              f"{mid + 10})")
        tr, state, _ = run(args.steps, d2, plan)
        print(f"   restarts: {tr.restarts}; loss "
              f"{tr.history[-1]['loss']:.3f}")

        same = all(
            bool(jax.numpy.array_equal(a, b))
            for a, b in zip(jax.tree.leaves(ref_state.params),
                            jax.tree.leaves(state.params)))
        print(f"== final params identical to uninterrupted run: {same}")
        assert same
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)


if __name__ == "__main__":
    main()
