"""Quickstart — the paper's mechanism in one page.

    PYTHONPATH=src python examples/quickstart.py

1. quantize a weight matrix to balanced-ternary trits (8b -> 5t truncation)
2. multiply through the bit-exact TL-nvSRAM-CIM macro (16-row groups,
   5-bit ADC, shift-&-add)
3. pack the trits for HBM-dense storage and run the Pallas kernel path
4. measure restore yield at the paper's operating point (n=60, m=4)
"""
import jax
import jax.numpy as jnp

from repro.core import cim, ternary
from repro.core.yield_model import tl_restore_yield
from repro.kernels import execute, ops, plan_matmul, shape_of

key = jax.random.key(0)
kx, kw = jax.random.split(key)
x = jax.random.normal(kx, (4, 256))
w = jax.random.normal(kw, (256, 64))

# -- 1. ternary quantization (Table 1 / Table 3) -------------------------
tt = ternary.ternarize(w, num_trits=5, method="truncate")
print(f"weight {w.shape} -> {tt.trits.shape} trit planes, "
      f"values {set(jnp.unique(tt.trits).tolist())}")
rel = float(jnp.linalg.norm(tt.dequantize() - w) / jnp.linalg.norm(w))
print(f"5-trit truncating quantization rel-error: {rel:.4f}")

# -- 2. bit-exact CIM macro MAC (Figs. 3-4) -------------------------------
y_float = x @ w
y_cim = cim.cim_matmul(x, w)
err = float(jnp.max(jnp.abs(y_cim - y_float)) / jnp.max(jnp.abs(y_float)))
print(f"CIM macro (16-row groups + 5-bit ADC) vs float matmul: "
      f"rel err {err:.4f}")

# -- 3. packed-ternary fast path (the TPU density mechanism) --------------
# resolve an ExecutionPlan per backend once, then execute: the same
# ternary MAC contract served by the pallas kernel and the xla path
pw = ops.pack_weights(w, "base3")                 # per-column scales
plan_pallas = plan_matmul(shape_of(x, pw), backend="pallas")
plan_xla = plan_matmul(shape_of(x, pw), backend="xla")
print(f"plan: {plan_pallas}")
y_kernel = execute(plan_pallas, x, pw)
y_oracle = execute(plan_xla, x, pw)
print(f"packed base3: {w.nbytes} B float -> {pw.data.nbytes} B packed "
      f"({w.nbytes / pw.data.nbytes:.1f}x denser than f32); Pallas kernel "
      f"vs oracle err {float(jnp.max(jnp.abs(y_kernel - y_oracle))):.2e}")

# -- 4. restore yield at the paper's operating point (Fig. 6) -------------
y = tl_restore_yield(jax.random.key(1), n=60, m=4, num_mc=4096)
print(f"restore yield @ n=60, m=4: {y['weighted']*100:.2f}% "
      f"(paper: >= 94%)  per-state HRS/MRS/LRS = "
      + "/".join(f"{float(v)*100:.1f}%" for v in y["per_state"]))
