"""Device-variation study — the paper's reliability argument end to end:

  sweep ReRAM count per cluster n -> Monte-Carlo restore yield (Fig. 6)
  -> inject the measured error rates into a ternarized classifier
  -> accuracy before/after retraining (Fig. 10 methodology)

    PYTHONPATH=src python examples/yield_accuracy_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import eval_mlp, train_mlp
from benchmarks.accuracy_yield import _quantize_with_errors, _retrain
from repro.core.yield_model import sl_restore_yield, tl_restore_yield
from repro.data import ClassTaskConfig

NS = (6, 18, 60)


def main():
    task = ClassTaskConfig(num_classes=10, dim=128, snr=2.5, seed=0)
    print("training float classifier (CIFAR-10 stand-in)...")
    params = train_mlp(task)
    print(f"float accuracy: {eval_mlp(params, task):.4f}\n")
    key = jax.random.key(5)

    print(f"{'n':>4} {'TL yield':>9} {'TL acc':>7} | {'SL yield':>9} "
          f"{'SL acc':>7}")
    for n in NS:
        ytl = tl_restore_yield(jax.random.fold_in(key, n), n, 4, 4096)
        ysl = sl_restore_yield(jax.random.fold_in(key, 50 + n), n, 4096)
        accs = {}
        for scheme, ps in (("tl", ytl["per_state"]),
                           ("sl", jnp.array([ysl["per_state"][0],
                                             ysl["per_state"].mean(),
                                             ysl["per_state"][1]]))):
            noisy = _quantize_with_errors(
                params, ps, jax.random.fold_in(key, 100 + n))
            accs[scheme] = eval_mlp(_retrain(noisy, task), task)
        print(f"{n:>4} {ytl['weighted']:>9.4f} {accs['tl']:>7.4f} | "
              f"{ysl['weighted']:>9.4f} {accs['sl']:>7.4f}")
    print("\nTL holds accuracy to n=60 (dense clusters); the SL divider "
          "degrades — the paper's scalability claim.")


if __name__ == "__main__":
    main()
