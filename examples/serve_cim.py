"""End-to-end driver — serve a model with batched requests from
packed-ternary weights (the paper is an inference accelerator: weight
storage density + ternary MACs; this is its system-level image).

    PYTHONPATH=src python examples/serve_cim.py [--arch internlm2-1.8b]

Flow: init model -> quantize every matmul weight to the paper's 5-trit
base3 format (2x denser than bf16; trit2 is 8x) -> submit a batch of
requests -> continuous greedy decoding -> report density + throughput.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.cim_linear import CIMConfig, hbm_bytes, ternarize_params
from repro.models import registry
from repro.serve import Request, ServeEngine


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="internlm2-1.8b")
    p.add_argument("--packing", default="base3", choices=("base3", "trit2"))
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=12)
    args = p.parse_args()

    cfg = configs.smoke(args.arch)      # reduced config: CPU-runnable
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    float_bytes = hbm_bytes(params)

    cim = CIMConfig(mode="ternary", packing=args.packing)
    packed = ternarize_params(params, cim)
    print(f"{cfg.name}: weights {float_bytes/1e6:.2f} MB float -> "
          f"{hbm_bytes(packed)/1e6:.2f} MB {args.packing} "
          f"(matmul weights at "
          f"{'1 byte / 5-trit weight' if args.packing == 'base3' else '2 bits/trit'})")

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = lambda b: jnp.zeros((b, cfg.encoder_seq,
                                               cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["patches"] = lambda b: jnp.zeros((b, cfg.encoder_seq,
                                                cfg.d_model), cfg.dtype)
    eng = ServeEngine(model, packed, capacity=128, max_batch=4, cim=cim,
                      extra_inputs=extra)
    key = jax.random.key(7)
    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (24,), 0,
                                    cfg.vocab_size)
        eng.submit(Request(uid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.monotonic()
    done = eng.run()
    dt = time.monotonic() - t0
    print(f"served {len(done)} requests, {eng.generated_tokens} tokens in "
          f"{dt:.1f}s ({eng.generated_tokens/dt:.1f} tok/s on 1 CPU core, "
          f"Pallas interpret mode)")
    print("sample output tokens:", done[0].out_tokens)


if __name__ == "__main__":
    main()
