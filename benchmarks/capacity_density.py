"""Fig. 11 — (a) array capacity / storage-density ablation
(SL -> SL+selectors 4.5x -> TL 10.0x/7.2x) and (b) whole-model area
(89.1% saved, 76 vs 6 subarrays) + energy-efficiency-per-area (11.0x,
2.3x at equal area) on ResNet-18."""
from __future__ import annotations

import dataclasses

from repro.core.cim import MacroConfig
from repro.core.energy import (area_and_ee_per_area, array_area_um2,
                               array_capacity_bits, arrays_to_fit,
                               inference_energy)
from repro.core.mapping import resnet18_cifar, subarrays_needed

from .common import save_json


def run(verbose=True) -> dict:
    # Fig 11(a): m=3 clusters for the ablation (paper's note)
    cfg3 = dataclasses.replace(MacroConfig(), clusters_per_cell=3)
    cap_sl = array_capacity_bits("sl")
    cap_sl_sel = array_capacity_bits("sl_sel")
    cap_tl = array_capacity_bits("tl", cfg3)
    den_sl = cap_sl / array_area_um2("sl")
    den_sl_sel = cap_sl_sel / array_area_um2("sl")
    den_tl = cap_tl / array_area_um2("tl", cfg3)

    layers = resnet18_cifar()
    fig11b = area_and_ee_per_area(layers)

    out = {
        "capacity_gain_sl_sel": cap_sl_sel / cap_sl,
        "claim_4p5x_selectors": bool(2.8 <= cap_sl_sel / cap_sl <= 5.0),
        "capacity_gain_tl": cap_tl / cap_sl,
        "claim_10x_capacity": bool(8.0 <= cap_tl / cap_sl <= 12.0),
        "density_gain_tl": den_tl / den_sl,
        "claim_7p2x_density": bool(6.0 <= den_tl / den_sl <= 8.5),
        "resnet18_subarrays": {"tl": fig11b["tl_arrays"],
                               "sl": fig11b["sl_arrays"]},
        "claim_6_vs_76_subarrays": bool(fig11b["tl_arrays"] <= 8
                                        and 60 <= fig11b["sl_arrays"] <= 90),
        "area_saved": fig11b["area_saved"],
        "claim_89p1_area_saved": bool(0.84 <= fig11b["area_saved"] <= 0.93),
        "ee_per_area_gain": fig11b["ee_per_area_gain"],
        "claim_11x_ee_per_area": bool(8.0 <= fig11b["ee_per_area_gain"]
                                      <= 14.0),
        "ee_per_area_same_area": fig11b["ee_per_area_gain_same_area"],
        "claim_2p3x_same_area": bool(1.8 <= fig11b[
            "ee_per_area_gain_same_area"] <= 2.9),
        "paper_ref": "Fig. 11",
    }
    if verbose:
        print(f"  capacity: SL+sel {out['capacity_gain_sl_sel']:.1f}x "
              f"(paper 4.5x*), TL {out['capacity_gain_tl']:.1f}x (paper "
              f"10.0x); density TL {out['density_gain_tl']:.1f}x (paper 7.2x)")
        print(f"  ResNet-18: {fig11b['tl_arrays']} TL vs "
              f"{fig11b['sl_arrays']} SL subarrays; area saved "
              f"{fig11b['area_saved']*100:.1f}% (paper 89.1%)")
        print(f"  EE/area: {fig11b['ee_per_area_gain']:.1f}x (paper 11.0x); "
              f"same-area {fig11b['ee_per_area_gain_same_area']:.2f}x "
              f"(paper 2.3x)")
    save_json("capacity_density", out)
    return out


if __name__ == "__main__":
    run()
