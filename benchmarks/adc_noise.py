"""ADC-readout-noise ablation (extends §3.5 / Fig. 10's variation study).

The paper treats the 5-bit ADC as exact; real CBL sensing has readout
noise.  We sweep additive ADC noise (in LSB sigma) through the bit-exact
macro model and measure classifier accuracy — quantifying how much
sensing margin the ternary scheme leaves (and when the 16-row/5-bit
operating point starts to degrade).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cim import MacroConfig, cim_matmul
from repro.data import ClassTaskConfig

from .common import eval_mlp, save_json, train_mlp

SIGMAS = (0.0, 0.05, 0.1, 0.25, 0.5)


def run(verbose=True) -> dict:
    task = ClassTaskConfig(num_classes=10, dim=128, snr=2.5, seed=0)
    params = train_mlp(task)
    macro = MacroConfig()
    key = jax.random.key(9)

    accs = {}
    for s in SIGMAS:
        def mm(x, w, s=s):
            k = jax.random.fold_in(key, int(s * 100) + x.shape[0])
            return cim_matmul(x, w, macro, adc_noise_sigma=s,
                              key=k if s > 0 else None)
        accs[s] = eval_mlp(params, task, mm, batches=4)
    out = {
        "accuracy_vs_adc_noise_lsb": {str(k): v for k, v in accs.items()},
        # FINDING: the shift-&-add amplifies plane-(i,j) ADC errors by
        # 3^(i+j) (up to 6561x for 5-trit x 5-trit), so the macro is far
        # more ADC-noise-sensitive than a binary design — it tolerates
        # ~0.1 LSB but collapses by 0.5 LSB.  This quantifies why the
        # paper's restore path digitizes trits BEFORE accumulation and
        # keeps CBL sensing margins wide (Fig. 5's V_X margins).
        "claim_tolerates_0p1_lsb": bool(accs[0.1] >= accs[0.0] - 0.05),
        "claim_collapses_by_0p5_lsb": bool(accs[0.5] <= accs[0.0] - 0.2),
    }
    if verbose:
        print("  sigma(LSB): " + "  ".join(f"{s:5.2f}" for s in SIGMAS))
        print("  accuracy:   " + "  ".join(f"{accs[s]:.3f}" for s in SIGMAS))
        print("  finding: 3^(i+j) shift-add amplification => tolerant to "
              f"~0.1 LSB ({out['claim_tolerates_0p1_lsb']}), collapses by "
              f"0.5 LSB ({out['claim_collapses_by_0p5_lsb']})")
    save_json("adc_noise", out)
    return out


if __name__ == "__main__":
    run()
