"""Schema contracts for the tracked benchmark JSON artifacts.

`make bench` (and tests) validate the artifacts against these minimal
required-key sets so a refactor cannot silently drop a field the perf
trajectory depends on.  Keys here are a floor, not a ceiling — suites
may add fields freely.
"""
from __future__ import annotations

import json
import os

# artifact name -> required top-level keys
TOP_LEVEL = {
    "wallclock": {
        "backend", "platform", "shapes", "serve",
        "min_decode_flop_waste_reduction",
        "claim_waste_reduction_ge_8x",
        "claim_device_loop_single_transfer",
        "claim_loops_token_identical",
    },
    "kernel_bench": {
        "sweep", "max_rel_err", "all_match_oracle",
        "vmem_working_set_bytes", "hbm_density",
    },
}

# wallclock per-shape-cell required keys
WALLCLOCK_CELL = {
    "phase", "m", "k", "n", "mode", "blocks_adaptive", "blocks_fixed",
    "flops_ideal", "flops_padded_adaptive", "flops_padded_fixed",
    "flop_waste_adaptive", "flop_waste_fixed", "flop_waste_reduction",
    "hbm_bytes_adaptive", "hbm_bytes_fixed",
}


def validate(name: str, payload: dict) -> list[str]:
    """Return a list of schema violations (empty = valid)."""
    errors = []
    required = TOP_LEVEL.get(name)
    if required is None:
        return errors                       # no contract for this artifact
    if not isinstance(payload, dict):
        return [f"{name}: top level is {type(payload).__name__}, not object"]
    missing = required - payload.keys()
    if missing:
        errors.append(f"{name}: missing top-level keys {sorted(missing)}")
    if name == "wallclock":
        for i, cell in enumerate(payload.get("shapes", [])):
            miss = WALLCLOCK_CELL - cell.keys()
            if miss:
                errors.append(f"wallclock shapes[{i}]: missing "
                              f"{sorted(miss)}")
        if not payload.get("shapes"):
            errors.append("wallclock: empty shapes sweep")
    return errors


def validate_file(path: str) -> list[str]:
    if not os.path.exists(path):
        return [f"missing artifact: {path}"]
    try:
        with open(path) as f:
            payload = json.load(f)
    except ValueError as e:                 # half-written/corrupt artifact
        return [f"unparseable artifact {path}: {e}"]
    name = os.path.basename(path)
    name = name.removeprefix("BENCH_").removesuffix(".json")
    return validate(name, payload)
