"""Schema contracts for the tracked benchmark JSON artifacts.

`make bench` (and tests) validate the artifacts against these minimal
required-key sets so a refactor cannot silently drop a field the perf
trajectory depends on.  Keys here are a floor, not a ceiling — suites
may add fields freely.
"""
from __future__ import annotations

import json
import os

# device-fidelity accuracy bound: the smoke classifier served through
# the fault-injected analog path at the MEASURED TL restore yield may
# lose at most this much accuracy vs the exact ternary kernels (the
# acceptance bound serve_fidelity's claim is judged against — pinned
# HERE so a bench edit cannot quietly relax it)
FIDELITY_ACC_DROP_MAX = 0.05

# artifact name -> required top-level keys
TOP_LEVEL = {
    "wallclock": {
        "backend", "platform", "shapes", "serve", "serve_continuous",
        "serve_paged", "serve_fidelity", "serve_frontend",
        "min_decode_flop_waste_reduction",
        "claim_waste_reduction_ge_8x",
        "claim_device_loop_single_transfer",
        "claim_loops_token_identical",
        "claim_continuous_beats_bucket_tokps",
        "claim_continuous_beats_bucket_p99",
        "claim_continuous_tokens_identical",
        "claim_chunk_transfer_accounting",
        "claim_paged_tokens_identical",
        "claim_paged_kv_bytes_2x",
        "claim_paged_prefix_hits",
        "claim_paged_fused_tokens_identical",
        "claim_paged_fused_beats_gather",
        "claim_paged_fused_hbm_lt_gather",
        "claim_fidelity_accuracy_within_bound",
        "claim_fidelity_degrades_without_scrub",
        "claim_fidelity_scrub_repairs",
        "claim_fidelity_transfer_accounting",
        "claim_frontend_tokens_identical",
        "claim_frontend_backpressure_bounded",
        "claim_frontend_goodput_under_overload",
        "claim_frontend_transfer_accounting",
    },
    "kernel_bench": {
        "sweep", "max_rel_err", "all_match_oracle",
        "vmem_working_set_bytes", "hbm_density",
    },
}

# wallclock per-shape-cell required keys
WALLCLOCK_CELL = {
    "phase", "m", "k", "n", "mode", "plan", "plan_int8",
    "blocks_adaptive", "blocks_fixed",
    "flops_ideal", "flops_padded_adaptive", "flops_padded_fixed",
    "flop_waste_adaptive", "flop_waste_fixed", "flop_waste_reduction",
    "hbm_bytes_adaptive", "hbm_bytes_fixed",
}

# each cell's resolved-plan record (kernels.ExecutionPlan.describe):
# which backend/domain/blocks — and, since the device backend landed,
# which FIDELITY — actually produced the step timings
WALLCLOCK_PLAN = {"backend", "domain", "packing", "blocks", "fidelity"}

# wallclock serve_continuous section: the continuous-vs-bucket artifact
# contract (ROADMAP §Performance)
SERVE_CONTINUOUS = {
    "slots", "chunk", "trace", "bucket", "continuous",
    "claim_continuous_beats_bucket_tokps",
    "claim_continuous_beats_bucket_p99",
    "claim_continuous_tokens_identical",
    "claim_chunk_transfer_accounting",
}
SERVE_CONTINUOUS_DRIVER = {"tok_per_s", "wall_s", "tokens", "p50_s",
                           "p99_s", "p999_s", "queue_wait_mean_s",
                           "service_mean_s"}
SERVE_CONTINUOUS_ONLY = {"slot_occupancy", "host_transfers", "chunks",
                         "decode_steps"}

# wallclock serve_paged section: the paged-vs-dense slot-pool artifact
# contract (resident KV bytes, page accounting, prefix sharing, tok/s
# at equal pool width/memory budget) + the fused-vs-gather decode read
# (the planned paged_attn executor vs the slot_view gather path: both
# tok/s, measured chunk byte traffic, and the basis the beats-gather
# claim was judged on — wallclock where the kernel lowers natively,
# byte traffic under interpret emulation)
SERVE_PAGED = {
    "slots", "chunk", "capacity", "page_size", "num_pages", "trace",
    "tok_per_s_dense", "tok_per_s_paged",
    "kv_bytes_dense", "kv_bytes_paged_pool", "kv_bytes_paged_peak",
    "kv_bytes_reduction", "pages_in_use_peak", "prefix_hit_rate",
    "attn_plan", "tok_per_s_paged_fused", "tok_per_s_paged_gather",
    "hbm_bytes_chunk_fused", "hbm_bytes_chunk_gather",
    "hbm_bytes_reduction", "hbm_bytes_source", "fused_claim_basis",
    "latency_dense", "latency_paged", "ungated_metrics",
    "claim_paged_tokens_identical",
    "claim_paged_kv_bytes_2x",
    "claim_paged_prefix_hits",
    "claim_paged_fused_tokens_identical",
    "claim_paged_fused_beats_gather",
    "claim_paged_fused_hbm_lt_gather",
}

# the two bases a committed artifact may judge the fused beats-gather
# claim on (the full prose after the token explains the choice)
FUSED_CLAIM_BASES = {"wallclock", "hbm-bytes"}

# wallclock serve_fidelity section: device-fidelity serving at the
# measured TL restore yield — accuracy vs the schema-pinned bound,
# scrub-gate error rates (repair must be measured, not a no-op),
# throughput, ADC clip counters, and the scrub restore-energy cost
SERVE_FIDELITY = {
    "fault_model", "plan_exact", "plan_device",
    "acc_float", "acc_exact", "acc_device", "acc_drop", "acc_drop_max",
    "tok_per_s_exact", "tok_per_s_device", "token_agreement",
    "err_with_scrub", "err_no_scrub", "scrub_residual_bound",
    "scrubs_run", "adc_clip_lo", "adc_clip_hi",
    "host_transfers_device", "chunks_device",
    "scrub_energy_j", "scrub_energy_j_per_token",
    "claim_fidelity_accuracy_within_bound",
    "claim_fidelity_degrades_without_scrub",
    "claim_fidelity_scrub_repairs",
    "claim_fidelity_transfer_accounting",
}

# wallclock serve_frontend section: the SLO-aware front-end over the
# model registry (repro.frontend) — parity + throughput vs driving the
# schedulers directly, the bounded-backpressure overload replay, and
# the goodput (deadline-met tok/s) comparison of SLO admission vs the
# FIFO baseline.  FIFO-under-overload is the adversarial baseline, so
# its goodput lives in ungated_metrics (the schema checks it is there)
SERVE_FRONTEND = {
    "models", "queue_limit", "overload_queue_limit",
    "tok_per_s_frontend", "tok_per_s_direct",
    "frontend", "overload",
    "deadline_tight_s", "service_floor_s",
    "tok_per_s_goodput_slo", "tok_per_s_goodput_fifo",
    "deadline_met_slo", "deadline_met_fifo", "deadline_total",
    "shed_slo", "ungated_metrics",
    "claim_frontend_tokens_identical",
    "claim_frontend_backpressure_bounded",
    "claim_frontend_goodput_under_overload",
    "claim_frontend_transfer_accounting",
}
# one warm open-loop epoch's stats (the `frontend` sub-dict): latency
# percentiles with the queue-wait/service split, TTFT, and the
# streaming transfer accounting
SERVE_FRONTEND_EPOCH = {
    "wall_s", "tokens", "p50_s", "p99_s", "p999_s",
    "ttft_p50_s", "ttft_p99_s", "queue_wait_mean_s", "service_mean_s",
    "host_transfers", "chunks",
}
# the backpressure replay (the `overload` sub-dict): every submit must
# be accounted for — completed + rejected, nothing silently dropped
SERVE_FRONTEND_OVERLOAD = {
    "submitted", "completed", "rejected", "max_pending_seen",
    "rejects_by_reason",
}


def validate(name: str, payload: dict) -> list[str]:
    """Return a list of schema violations (empty = valid)."""
    errors = []
    if name == "autotune":
        # the measured block-shape table: one contract, shared with the
        # runtime loader and the `make analyze` autotune pass
        from repro.kernels.autotune import validate_table
        return [f"autotune {where}: {message} [{rule}]"
                for rule, where, message in validate_table(payload)]
    required = TOP_LEVEL.get(name)
    if required is None:
        return errors                       # no contract for this artifact
    if not isinstance(payload, dict):
        return [f"{name}: top level is {type(payload).__name__}, not object"]
    missing = required - payload.keys()
    if missing:
        errors.append(f"{name}: missing top-level keys {sorted(missing)}")
    if name == "wallclock":
        for i, cell in enumerate(payload.get("shapes", [])):
            miss = WALLCLOCK_CELL - cell.keys()
            if miss:
                errors.append(f"wallclock shapes[{i}]: missing "
                              f"{sorted(miss)}")
            for pk in ("plan", "plan_int8"):
                if pk not in cell:
                    continue               # absence reported above
                rec = cell[pk]
                if not isinstance(rec, dict):
                    errors.append(f"wallclock shapes[{i}].{pk}: expected "
                                  f"object, got {type(rec).__name__}")
                    continue
                pmiss = WALLCLOCK_PLAN - rec.keys()
                if pmiss:
                    errors.append(f"wallclock shapes[{i}].{pk}: missing "
                                  f"{sorted(pmiss)}")
        if not payload.get("shapes"):
            errors.append("wallclock: empty shapes sweep")
        sc = payload.get("serve_continuous")
        if isinstance(sc, dict):
            miss = SERVE_CONTINUOUS - sc.keys()
            if miss:
                errors.append(f"wallclock serve_continuous: missing "
                              f"{sorted(miss)}")
            for drv in ("bucket", "continuous"):
                sub = sc.get(drv)
                if not isinstance(sub, dict):
                    if drv in sc:          # present but malformed
                        errors.append(f"wallclock serve_continuous."
                                      f"{drv}: not an object")
                    continue               # absent: already reported
                need = SERVE_CONTINUOUS_DRIVER | (
                    SERVE_CONTINUOUS_ONLY if drv == "continuous"
                    else set())
                miss = need - sub.keys()
                if miss:
                    errors.append(f"wallclock serve_continuous.{drv}: "
                                  f"missing {sorted(miss)}")
        elif "serve_continuous" in payload:
            errors.append("wallclock serve_continuous: not an object")
        sp = payload.get("serve_paged")
        if isinstance(sp, dict):
            miss = SERVE_PAGED - sp.keys()
            if miss:
                errors.append(f"wallclock serve_paged: missing "
                              f"{sorted(miss)}")
            rec = sp.get("attn_plan")
            if isinstance(rec, dict):
                pmiss = WALLCLOCK_PLAN - rec.keys()
                if pmiss:
                    errors.append(f"wallclock serve_paged.attn_plan: "
                                  f"missing {sorted(pmiss)}")
                # the fused measurement must have run the fused
                # executor, not a fallback
                if rec.get("backend") != "paged_attn":
                    errors.append(
                        f"wallclock serve_paged.attn_plan: backend "
                        f"{rec.get('backend')!r} is not 'paged_attn'")
            elif "attn_plan" in sp:
                errors.append("wallclock serve_paged.attn_plan: not an "
                              "object")
            basis = sp.get("fused_claim_basis")
            if isinstance(basis, str) and \
                    basis.split()[0] not in FUSED_CLAIM_BASES:
                errors.append(
                    f"wallclock serve_paged: fused_claim_basis "
                    f"{basis!r} does not start with one of "
                    f"{sorted(FUSED_CLAIM_BASES)}")
            ungated = sp.get("ungated_metrics")
            if isinstance(ungated, list):
                for key in ungated:
                    if key not in sp:
                        errors.append(
                            f"wallclock serve_paged: ungated_metrics "
                            f"names absent key {key!r}")
                # an interpret-emulation wallclock number must never be
                # gated as a perf claim by benchmarks/compare.py
                if isinstance(basis, str) \
                        and not basis.startswith("wallclock") \
                        and "tok_per_s_paged_fused" not in ungated:
                    errors.append(
                        "wallclock serve_paged: fused_claim_basis is "
                        "not wallclock but tok_per_s_paged_fused is "
                        "missing from ungated_metrics")
            elif "ungated_metrics" in sp:
                errors.append("wallclock serve_paged: ungated_metrics "
                              "is not a list")
        elif "serve_paged" in payload:
            errors.append("wallclock serve_paged: not an object")
        sf = payload.get("serve_fidelity")
        if isinstance(sf, dict):
            miss = SERVE_FIDELITY - sf.keys()
            if miss:
                errors.append(f"wallclock serve_fidelity: missing "
                              f"{sorted(miss)}")
            for pk in ("plan_exact", "plan_device"):
                rec = sf.get(pk)
                if not isinstance(rec, dict):
                    continue               # absence reported above
                pmiss = WALLCLOCK_PLAN - rec.keys()
                if pmiss:
                    errors.append(f"wallclock serve_fidelity.{pk}: "
                                  f"missing {sorted(pmiss)}")
            if isinstance(sf.get("plan_device"), dict) and \
                    sf["plan_device"].get("fidelity") != "device":
                errors.append("wallclock serve_fidelity.plan_device: "
                              "fidelity is not 'device'")
            # the bound is pinned here, not in the bench: an artifact
            # claiming the accuracy gate against a looser bound fails
            if "acc_drop_max" in sf and \
                    sf["acc_drop_max"] != FIDELITY_ACC_DROP_MAX:
                errors.append(
                    f"wallclock serve_fidelity: acc_drop_max "
                    f"{sf['acc_drop_max']} != schema-pinned "
                    f"{FIDELITY_ACC_DROP_MAX}")
        elif "serve_fidelity" in payload:
            errors.append("wallclock serve_fidelity: not an object")
        sfr = payload.get("serve_frontend")
        if isinstance(sfr, dict):
            miss = SERVE_FRONTEND - sfr.keys()
            if miss:
                errors.append(f"wallclock serve_frontend: missing "
                              f"{sorted(miss)}")
            fe = sfr.get("frontend")
            if isinstance(fe, dict):
                fmiss = SERVE_FRONTEND_EPOCH - fe.keys()
                if fmiss:
                    errors.append(f"wallclock serve_frontend.frontend: "
                                  f"missing {sorted(fmiss)}")
            elif "frontend" in sfr:
                errors.append("wallclock serve_frontend.frontend: not "
                              "an object")
            ov = sfr.get("overload")
            if isinstance(ov, dict):
                omiss = SERVE_FRONTEND_OVERLOAD - ov.keys()
                if omiss:
                    errors.append(f"wallclock serve_frontend.overload: "
                                  f"missing {sorted(omiss)}")
                # the no-silent-drop contract, structurally: every
                # submit of the overload replay is accounted for
                elif ov["submitted"] != ov["completed"] + ov["rejected"]:
                    errors.append(
                        f"wallclock serve_frontend.overload: "
                        f"{ov['submitted']} submitted != "
                        f"{ov['completed']} completed + "
                        f"{ov['rejected']} rejected (a request was "
                        f"silently dropped)")
            elif "overload" in sfr:
                errors.append("wallclock serve_frontend.overload: not "
                              "an object")
            ungated = sfr.get("ungated_metrics")
            if isinstance(ungated, list):
                for key in ungated:
                    if key not in sfr:
                        errors.append(
                            f"wallclock serve_frontend: ungated_metrics "
                            f"names absent key {key!r}")
                # the FIFO-baseline goodput is adversarial by design;
                # it must never be gated as a perf claim
                if "tok_per_s_goodput_fifo" not in ungated:
                    errors.append(
                        "wallclock serve_frontend: "
                        "tok_per_s_goodput_fifo is missing from "
                        "ungated_metrics (the adversarial FIFO "
                        "baseline must not be regression-gated)")
            elif "ungated_metrics" in sfr:
                errors.append("wallclock serve_frontend: "
                              "ungated_metrics is not a list")
        elif "serve_frontend" in payload:
            errors.append("wallclock serve_frontend: not an object")
    return errors


def validate_file(path: str) -> list[str]:
    if not os.path.exists(path):
        return [f"missing artifact: {path}"]
    try:
        with open(path) as f:
            payload = json.load(f)
    except ValueError as e:                 # half-written/corrupt artifact
        return [f"unparseable artifact {path}: {e}"]
    name = os.path.basename(path)
    name = name.removeprefix("BENCH_").removesuffix(".json")
    return validate(name, payload)
