"""Shared benchmark utilities: a small trainable classifier (CIFAR-10
stand-in, §4.1) whose linear layers can be executed through every CIM
mode, plus result formatting."""
from __future__ import annotations

import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cim import MacroConfig
from repro.core.cim_linear import CIMConfig
from repro.core.ternary import TernaryTensor, ternarize
from repro.data import ClassTaskConfig, class_batch

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "benchmarks")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def save_json(name: str, payload: dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def save_bench_json(name: str, payload: dict) -> str:
    """Write a tracked perf-trajectory artifact (BENCH_<name>.json at the
    repo root — the wall-clock numbers later perf PRs are judged against),
    in addition to the experiments/ copy."""
    save_json(name, payload)
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def stable_seed(*parts) -> int:
    """PYTHONHASHSEED-independent seed from a tuple of ints/strings
    (builtin hash() of str is salted per process — irreproducible).
    Canonical implementation lives in ``repro.core.seeding``; this is
    the benchmarks-facing alias the RA004 lint rule recognizes."""
    from repro.core.seeding import stable_seed as _stable_seed
    return _stable_seed(*parts)


def time_fn(fn, *args, warmup: int = 1, iters: int = 5,
            min_total: float = 0.25, max_iters: int = 40, **kw) -> float:
    """Best-of-N wall-clock seconds of fn(*args) with jit warmup and
    block_until_ready on the result.  The minimum, not the median:
    scheduler noise on shared hosts only ever ADDS time, and the
    bench-compare regression gate needs a statistic stable enough
    that a 15% threshold measures the code, not the host (the serve
    benches and the autotuner already time best-of-N).  At least
    `iters` samples, then more until `min_total` seconds of
    measurement (capped at `max_iters`) — a fixed, pre-registered
    budget rule, so sub-millisecond steps get the many samples their
    process-to-process jitter needs while multi-ms steps stop early."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best, total, n = float("inf"), 0.0, 0
    while n < iters or (total < min_total and n < max_iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        dt = time.perf_counter() - t0
        best, total, n = min(best, dt), total + dt, n + 1
    return best


# ------------------------------------------------------------------ MLP

def mlp_init(key, dim=128, hidden=256, classes=10):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) / jnp.sqrt(dim),
        "w2": jax.random.normal(k2, (hidden, classes)) / jnp.sqrt(hidden),
    }


def mlp_logits(params, x, matmul=None):
    mm = matmul or (lambda a, b: a @ b)
    h = jax.nn.relu(mm(x, params["w1"]))
    return mm(h, params["w2"])


def train_mlp(task: ClassTaskConfig, steps=400, batch=256, lr=3e-2, seed=0):
    params = mlp_init(jax.random.key(seed),  # lint: allow RA004 (caller passes a literal seed)
                      dim=task.dim, classes=task.num_classes)

    @jax.jit
    def step(params, i):
        b = class_batch(task, i, batch)

        def loss_fn(p):
            lg = mlp_logits(p, b["x"])
            return -jnp.mean(jax.nn.log_softmax(lg)[
                jnp.arange(batch), b["y"]])
        loss, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, loss

    for i in range(steps):
        params, loss = step(params, jnp.asarray(i))
    return params


def eval_mlp(params, task: ClassTaskConfig, matmul=None, batches=8,
             batch=512, seed_base=10_000):
    correct = total = 0
    for i in range(batches):
        b = class_batch(task, jnp.asarray(seed_base + i), batch)
        lg = mlp_logits(params, b["x"], matmul)
        correct += int(jnp.sum(jnp.argmax(lg, -1) == b["y"]))
        total += batch
    return correct / total


def quantized_matmul(scheme: str, macro: MacroConfig = MacroConfig()):
    """matmul closure that pushes the weight through a quantization scheme
    (and the bit-exact CIM macro for 'cim_*' schemes)."""
    from repro.core.cim import cim_matmul
    from repro.core.ternary import (quantize_8b, quantize_5t_direct,
                                    quantize_8b_truncate_5t)

    def dequant(qfun, x, w):
        q = qfun(w)
        return x @ (q.values.astype(jnp.float32) * q.scale)

    if scheme == "float":
        return lambda x, w: x @ w
    if scheme == "bc8":
        return partial(dequant, quantize_8b)
    if scheme == "tc5_direct":
        return partial(dequant, quantize_5t_direct)
    if scheme == "tc5_truncate":
        return partial(dequant, quantize_8b_truncate_5t)
    if scheme == "cim_exact":
        return lambda x, w: cim_matmul(x, w, macro)
    raise ValueError(scheme)


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0
