"""Table 3 — inference accuracy of the coding schemes.

Paper claim (CIFAR-10, ResNet-18/VGG-9): BC(8b) ~ float; TC(5t) direct
loses a little; BC(8b) truncated to TC(5t) recovers to ~BC(8b).  We
reproduce the ORDERING on the offline classification task (class-
conditional Gaussians — DESIGN.md §2 assumption (ii)) with the exact
coding functions, plus the bit-exact CIM-macro execution of the
truncated weights (16-row groups + 5-bit ADC).
"""
from __future__ import annotations

from repro.data import ClassTaskConfig

from .common import eval_mlp, quantized_matmul, save_json, train_mlp


def run(verbose=True) -> dict:
    task = ClassTaskConfig(num_classes=10, dim=128, snr=2.5, seed=0)
    params = train_mlp(task)
    acc = {s: eval_mlp(params, task, quantized_matmul(s))
           for s in ("float", "bc8", "tc5_direct", "tc5_truncate",
                     "cim_exact")}
    ok_order = (acc["bc8"] >= acc["tc5_direct"] - 0.02
                and acc["tc5_truncate"] >= acc["tc5_direct"] - 0.005
                and abs(acc["tc5_truncate"] - acc["bc8"]) < 0.02
                and abs(acc["cim_exact"] - acc["tc5_truncate"]) < 0.02)
    out = {"accuracy": acc, "paper_ordering_reproduced": bool(ok_order),
           "paper_ref": "Table 3"}
    if verbose:
        for k, v in acc.items():
            print(f"  {k:14s} {v:.4f}")
        print(f"  ordering reproduced: {ok_order}")
    save_json("quantization", out)
    return out


if __name__ == "__main__":
    run()
