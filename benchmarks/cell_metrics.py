"""Table 4 — cell-level metrics: storage density 7.8x, store/restore
energy reductions, CIM efficiency +46.6%."""
from __future__ import annotations

from repro.core.energy import C, cell_metrics

from .common import save_json


def run(verbose=True) -> dict:
    m = cell_metrics()
    tl, sl = m["tl"], m["sl"]
    out = {
        "tl": {k: float(v) for k, v in tl.items()},
        "sl": {k: float(v) for k, v in sl.items()},
        "density_gain": float(m["density_gain"]),
        "claim_density_7p8x": bool(7.0 <= m["density_gain"] <= 8.5),
        "store_energy_reduction": 1 - tl["store_energy"] / sl["store_energy"],
        "claim_store_energy_minus_80p7": bool(
            0.77 <= 1 - tl["store_energy"] / sl["store_energy"] <= 0.84),
        "restore_energy_reduction":
            1 - tl["restore_energy"] / sl["restore_energy"],
        "claim_restore_energy_minus_45p1": bool(
            0.42 <= 1 - tl["restore_energy"] / sl["restore_energy"] <= 0.48),
        "cim_efficiency_gain": tl["cim_efficiency_op_per_fj"]
            / sl["cim_efficiency_op_per_fj"] - 1,
        "claim_cim_eff_plus_46p6": bool(
            0.40 <= tl["cim_efficiency_op_per_fj"]
            / sl["cim_efficiency_op_per_fj"] - 1 <= 0.52),
        "paper_ref": "Table 4",
    }
    if verbose:
        print(f"  density: {tl['density_bits_um2']:.1f} vs "
              f"{sl['density_bits_um2']:.2f} bit/um2 -> "
              f"{m['density_gain']:.2f}x (paper 7.8x)")
        print(f"  store E: -{out['store_energy_reduction']*100:.1f}% "
              f"(paper -80.7%); restore E: "
              f"-{out['restore_energy_reduction']*100:.1f}% (paper -45.1%)")
        print(f"  CIM eff: +{out['cim_efficiency_gain']*100:.1f}% "
              f"(paper +46.6%)")
    save_json("cell_metrics", out)
    return out


if __name__ == "__main__":
    run()
