"""Fig. 10 — inference accuracy under restore-yield-driven trit errors,
with retraining, across ReRAM settings.

Paper claims (CIFAR-10): TL-nvSRAM-CIM accuracy is FLAT as ReRAMs per
cluster grow to 60 (reliable DC-free restore keeps yield high), while
SL-nvSRAM-CIM degrades with group size (divider margins collapse).
Reproduced on the offline classification task: the measured per-state
yields from the Monte-Carlo model drive trit-error injection into the
ternarized MLP weights; retraining = a short fine-tune with errors
frozen (the paper's methodology, §4.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.error_injection import inject_restore_errors
from repro.core.ternary import ternarize
from repro.core.yield_model import sl_restore_yield, tl_restore_yield
from repro.data import ClassTaskConfig, class_batch

from .common import eval_mlp, mlp_logits, save_json, stable_seed, train_mlp

NS = (6, 18, 30, 60)


def _quantize_with_errors(params, per_state_yield, key):
    """Ternarize every weight, inject restore errors, dequantize."""
    out = {}
    for i, (name, w) in enumerate(sorted(params.items())):
        tt = ternarize(w, 5, method="truncate")
        tt = inject_restore_errors(
            tt, per_state_yield, jax.random.fold_in(key, i))
        out[name] = tt.dequantize()
    return out


def _retrain(params, task, steps=60, lr=5e-3):
    """Short error-aware fine-tune (errors frozen in the dequantized
    weights; retraining adapts the remaining precision)."""
    @jax.jit
    def step(p, i):
        b = class_batch(task, i, 256)

        def loss_fn(p):
            lg = mlp_logits(p, b["x"])
            return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(256), b["y"]])
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l

    for i in range(steps):
        params, _ = step(params, jnp.asarray(50_000 + i))
    return params


def run(verbose=True, num_mc=4096) -> dict:
    task = ClassTaskConfig(num_classes=10, dim=128, snr=2.5, seed=0)
    params = train_mlp(task)
    base_acc = eval_mlp(params, task)
    # configuration-derived Monte-Carlo keys (stable_seed), replacing
    # the old ad-hoc offsets (100+n, 999+n)
    key = jax.random.key(stable_seed("accuracy_yield", 3))

    results = {"tl": {}, "sl": {}}
    for n in NS:
        ytl = tl_restore_yield(
            jax.random.fold_in(key, stable_seed("tl-yield", n, num_mc)),
            n, 4, num_mc)["per_state"]
        ysl_w = sl_restore_yield(
            jax.random.fold_in(key, stable_seed("sl-yield", n, num_mc)),
            n, num_mc)["per_state"]
        # SL stores binary bits; map its HRS/LRS yields onto the trit
        # confusion (state 0 unaffected by construction -> use mean)
        ysl = jnp.array([ysl_w[0], (ysl_w[0] + ysl_w[1]) / 2, ysl_w[1]])
        for scheme, y in (("tl", ytl), ("sl", ysl)):
            noisy = _quantize_with_errors(
                params, y,
                jax.random.fold_in(key, stable_seed("inject", scheme, n)))
            acc0 = eval_mlp(noisy, task)
            acc1 = eval_mlp(_retrain(noisy, task), task)
            results[scheme][n] = {"pre_retrain": acc0, "post_retrain": acc1}

    tl_accs = [results["tl"][n]["post_retrain"] for n in NS]
    sl_accs = [results["sl"][n]["post_retrain"] for n in NS]
    out = {
        "float_accuracy": base_acc,
        "tl": results["tl"], "sl": results["sl"],
        "claim_tl_flat": bool(max(tl_accs) - min(tl_accs) < 0.03),
        "claim_sl_degrades_or_trails_tl": bool(
            sl_accs[-1] <= tl_accs[-1] + 0.005),
        "paper_ref": "Fig. 10",
    }
    if verbose:
        print(f"  float acc {base_acc:.4f}")
        print("  n:   " + "  ".join(f"{n:6d}" for n in NS))
        print("  TL:  " + "  ".join(f"{a:.4f}" for a in tl_accs))
        print("  SL:  " + "  ".join(f"{a:.4f}" for a in sl_accs))
        print(f"  TL flat: {out['claim_tl_flat']}; SL trails: "
              f"{out['claim_sl_degrades_or_trails_tl']}")
    save_json("accuracy_yield", out)
    return out


if __name__ == "__main__":
    run()
