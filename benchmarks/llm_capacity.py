"""Beyond-paper: the paper's capacity/area/energy model applied to the
ten ASSIGNED LLM architectures.

The paper evaluates ResNet-18 (11 MB) and VGG-9 (3 MB).  Modern LLMs are
3-6 orders of magnitude larger — exactly the regime the paper's
"accommodate all weights on-chip" argument targets.  For every assigned
arch we derive its weight matmuls as LayerSpecs, then ask the paper's
own model (core/energy.py):

  * how many TL- vs SL-nvSRAM-CIM subarrays hold ALL weights (8b / 5t),
  * the silicon area of each (mm²),
  * per-token inference energy of TL vs baseline-1 (DRAM + SRAM-CIM) —
    the ratio the paper reports as 2.5-2.9x on CNNs.

MoE archs count FULL expert storage but only the routed (active)
fraction of expert MACs per token — the paper's density pitch is
strongest exactly there (kimi-k2: 1 TB of weights, 3.2% active/token).
"""
from __future__ import annotations

from repro import configs
from repro.core.energy import (array_area_um2, arrays_to_fit,
                               inference_energy)
from repro.core.mapping import LayerSpec, subarrays_needed

from .common import save_json


def lm_layer_specs(cfg, batch: int = 1) -> list:
    """Weight matmuls of one decode step (`batch` tokens) as LayerSpecs.

    spatial = weight-reuse per inference: `batch` for dense layers, the
    routed token-fraction for expert layers (storage counts params
    fully; streaming baselines only touch min(spatial, 1) of them)."""
    d, hd = cfg.d_model, cfg.hd
    h, kv, ff = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    L = cfg.num_layers
    specs = []

    def layer(name, cin, cout, spatial=float(batch)):
        specs.append(LayerSpec(name, cin, cout, 1, spatial))

    for prefix, n_layers in (("dec", L),) + (
            (("enc", cfg.encoder_layers),) if cfg.encoder_layers else ()):
        layer(f"{prefix}_wq", d * n_layers, h * hd)
        layer(f"{prefix}_wk", d * n_layers, kv * hd)
        layer(f"{prefix}_wv", d * n_layers, kv * hd)
        layer(f"{prefix}_wo", h * hd * n_layers, d)
        if cfg.num_experts:
            frac = batch * cfg.experts_per_token / cfg.num_experts
            layer(f"{prefix}_moe_w1", d * n_layers * cfg.num_experts, ff,
                  frac)
            layer(f"{prefix}_moe_w3", d * n_layers * cfg.num_experts, ff,
                  frac)
            layer(f"{prefix}_moe_w2", ff * n_layers * cfg.num_experts, d,
                  frac)
        elif ff:
            layer(f"{prefix}_w1", d * n_layers, ff)
            layer(f"{prefix}_w3", d * n_layers, ff)
            layer(f"{prefix}_w2", ff * n_layers, d)
        else:                       # xlstm: block-internal projections
            layer(f"{prefix}_proj", d * n_layers, 4 * d)
    layer("unembed", d, cfg.padded_vocab)
    return specs


BATCHES = (1, 32, 1024)


def run(verbose=True) -> dict:
    out = {}
    ok_density = ok_ee = True
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        layers = lm_layer_specs(cfg)
        mb = sum(l.params() for l in layers) / 1e6        # ~MB at 8b
        n_tl = subarrays_needed(layers)
        n_sl = arrays_to_fit(mb * 1e6, "sl")
        a_tl = n_tl * array_area_um2("tl") / 1e6          # mm^2
        a_sl = n_sl * array_area_um2("sl") / 1e6
        ee = {}
        for b in BATCHES:
            lb = lm_layer_specs(cfg, b)
            e_tl = inference_energy(lb, "tl", num_arrays=n_tl).total
            e_b1 = inference_energy(lb, "sram_dram").total
            ee[b] = round(e_b1 / e_tl, 2)
        out[arch] = {
            "weight_mb_8b": round(mb, 1),
            "tl_subarrays": n_tl, "sl_subarrays": n_sl,
            "tl_area_mm2": round(a_tl, 1), "sl_area_mm2": round(a_sl, 1),
            "ee_vs_dram_by_batch": {str(b): v for b, v in ee.items()},
        }
        ok_density &= n_sl > 10 * n_tl
        ok_ee &= ee[1] > 10.0 and ee[1024] > 1.0
    out["claim_density_gain_holds_at_llm_scale"] = bool(ok_density)
    # decode (no weight reuse) amplifies the paper's CNN-scale 2.5-2.9x
    # advantage to >10x; large batches re-amortize DRAM streaming and
    # converge back toward the paper's regime
    out["claim_ee_amplified_at_batch1"] = bool(ok_ee)
    if verbose:
        print(f"  {'arch':22s} {'MB(8b)':>9s} {'TL arr':>8s} {'SL arr':>9s}"
              f" {'TL mm2':>8s}  EE@b=1  b=32  b=1024")
        for arch in configs.ARCHS:
            r = out[arch]
            e = r["ee_vs_dram_by_batch"]
            print(f"  {arch:22s} {r['weight_mb_8b']:>9.0f} "
                  f"{r['tl_subarrays']:>8d} {r['sl_subarrays']:>9d} "
                  f"{r['tl_area_mm2']:>8.0f} {e['1']:>7.1f} {e['32']:>5.1f}"
                  f" {e['1024']:>6.1f}")
    save_json("llm_capacity", out)
    return out


if __name__ == "__main__":
    run()
