"""Kernel-level benchmark (TPU adaptation of the paper's density claim).

Shape/dtype sweep of the packed-ternary matmul against the pure-jnp
oracle (interpret mode = bit-level contract validation on CPU), VMEM
working-set accounting for the BlockSpecs (the structural check that the
tiles fit the 16 MB v5e VMEM), and the HBM-byte savings of each packing
(the memory-roofline win measured end-to-end in §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import packed_bytes
from repro.kernels import execute, ops, plan_matmul, ref
from repro.kernels.ternary_matmul import (_vmem_working_set,
                                          select_block_shapes)

from .common import save_json, stable_seed

SWEEP = [
    # (M, K, N, mode)
    (8, 256, 128, "base3"), (8, 256, 128, "trit2"),
    (64, 512, 256, "base3"), (64, 512, 256, "trit2"),
    (16, 1024, 512, "base3"), (16, 1024, 512, "trit2"),
    (128, 384, 640, "base3"),        # non-multiple-of-block shapes
    (33, 272, 130, "trit2"),
]


# representative (M, K, N) cells for the VMEM structural check — the
# working set is computed from the blocks select_block_shapes ACTUALLY
# chooses for them (the adaptive dispatch no longer always runs
# 128/128/512), via the kernel's own _vmem_working_set model.
VMEM_SHAPES = {
    "decode_m1": (1, 8192, 8192),
    "decode_m8": (8, 8192, 8192),
    "prefill_m256": (256, 8192, 8192),
}


def run(verbose=True) -> dict:
    results = []
    worst = 0.0
    for m, k, n, mode in SWEEP:
        # builtin hash() is salted by PYTHONHASHSEED — crc32 keeps the
        # sweep reproducible across processes
        key = jax.random.key(stable_seed(m, k, n, mode))
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        pw = ops.pack_weights(w, mode)
        # one plan per backend, same (shape, packing) request: the
        # registry sweep the parity contract is stated over
        y_kernel = execute(plan_matmul((m, k, n), packing=mode,
                                       backend="pallas", interpret=True),
                           x, pw)
        y_xla = execute(plan_matmul((m, k, n), packing=mode,
                                    backend="xla"), x, pw)
        y_oracle = ref.ternary_matmul_ref(x, pw.data, pw.scale, mode)
        err = float(jnp.max(jnp.abs(y_kernel - y_oracle)) /
                    (jnp.max(jnp.abs(y_oracle)) + 1e-9))
        err_x = float(jnp.max(jnp.abs(y_xla - y_oracle)) /
                      (jnp.max(jnp.abs(y_oracle)) + 1e-9))
        worst = max(worst, err, err_x)
        results.append({"shape": (m, k, n), "mode": mode, "rel_err": err,
                        "rel_err_xla": err_x})
    vmem = {f"{mode}:{domain}:{label}": _vmem_working_set(
                *select_block_shapes(m, k, n, mode, domain=domain),
                mode, domain)
            for mode in ("base3", "trit2")
            for domain in ("float", "int8")
            for label, (m, k, n) in VMEM_SHAPES.items()}
    density = {
        "bf16_bytes_per_weight": 2.0,
        # base3: one byte per 5-trit weight; trit2: ONE trit per weight
        # (TWN mode), 4 weights per byte
        "base3_bytes_per_weight": packed_bytes((1024,), "base3") / 1024,
        "trit2_bytes_per_weight": packed_bytes((1024,), "trit2",
                                               num_trits=1) / 1024,
    }
    out = {
        "sweep": results,
        "max_rel_err": worst,
        "all_match_oracle": bool(worst < 1e-5),
        "vmem_working_set_bytes": vmem,
        "vmem_fits_16MB": {k: bool(v < 16 * 2**20) for k, v in vmem.items()},
        "hbm_density": density,
    }
    if verbose:
        print(f"  {len(SWEEP)} shape/mode cells vs oracle: max rel err "
              f"{worst:.2e} (match: {out['all_match_oracle']})")
        worst_vmem = max(vmem.items(), key=lambda kv: kv[1])
        print(f"  VMEM working set (adaptive blocks): worst "
              f"{worst_vmem[0]} {worst_vmem[1]/1e3:.0f}KB (<16MB: "
              f"{out['vmem_fits_16MB'][worst_vmem[0]]})")
        print(f"  HBM bytes/weight: bf16 2.0, base3 "
              f"{density['base3_bytes_per_weight']:.2f} (2x, the paper's "
              f"5-trit), trit2 {density['trit2_bytes_per_weight']:.2f} (8x)")
    save_json("kernel_bench", out)
    return out


if __name__ == "__main__":
    run()
