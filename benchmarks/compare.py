"""Perf-regression gate: regenerated wallclock vs the tracked baseline.

``make bench`` regenerates ``experiments/benchmarks/wallclock.json``
(the fast sweep); this module diffs every throughput/latency metric in
it against the tracked repo-root ``BENCH_wallclock.json`` baseline and
exits nonzero when any metric regressed by more than the threshold
(default 15%) — the CI ``bench-compare`` step.

Metric collection is recursive over the artifact tree: every numeric
key starting with ``tok_per_s`` (higher is better) or ``step_time_s``
(lower is better) becomes one comparison, addressed by its JSON path —
including the ``serve_frontend`` section's throughput and
goodput-under-overload numbers (``tok_per_s_frontend``,
``tok_per_s_goodput_slo``; the adversarial FIFO baseline opts out via
``ungated_metrics``), so a >15% front-end goodput regression fails CI
like any kernel slowdown.
List elements that are shape cells (dicts carrying phase/m/k/n/mode)
are keyed SEMANTICALLY — ``shapes[decode:8x1024x1024:trit2]`` — not by
index: the fast candidate sweep measures fewer cells than the full
baseline, so positional keys would misalign the comparison.  Only the
key intersection is compared (coverage differences are reported, not
failed); near-zero baselines are skipped rather than divided by.

Exit codes: 0 within threshold, 1 regression(s), 2 unusable inputs
(missing/unparseable artifact, or no common metrics).

    python -m benchmarks.compare                    # default paths
    python -m benchmarks.compare --threshold 0.10
    make bench-compare
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# paths derived locally (NOT via .common, which imports jax + the model
# stack): the compare gate must run on artifacts alone
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_DIR = os.path.join(REPO_ROOT, "experiments", "benchmarks")

DEFAULT_BASELINE = os.path.join(REPO_ROOT, "BENCH_wallclock.json")
DEFAULT_CANDIDATE = os.path.join(OUT_DIR, "wallclock.json")
DEFAULT_THRESHOLD = 0.15

# metric-name prefix -> True when higher is better
METRIC_PREFIXES = {"tok_per_s": True, "step_time_s": False}

# a list element carrying these keys is a shape cell, keyed by content
SHAPE_CELL_KEYS = {"phase", "m", "k", "n", "mode"}


def _element_key(item, index: int) -> str:
    if isinstance(item, dict) and SHAPE_CELL_KEYS <= item.keys():
        return (f"{item['phase']}:{item['m']}x{item['k']}x{item['n']}"
                f":{item['mode']}")
    return str(index)


def collect_metrics(node, prefix: str = "") -> dict:
    """JSON-path -> float for every gated metric under ``node``.

    A dict may carry ``ungated_metrics``, a list of sibling keys the
    artifact itself declares non-claims (e.g. the fused read's tok/s
    under interpret emulation, where wallclock measures the emulator
    and the artifact's ``fused_claim_basis`` is byte traffic, or the
    front-end's deliberately adversarial FIFO-under-overload goodput);
    those keys are skipped, so either side of the comparison can opt a
    metric out (it drops from the key intersection)."""
    out = {}
    if isinstance(node, dict):
        ungated = set(node.get("ungated_metrics") or ())
        for k, v in node.items():
            if k in ungated:
                continue
            path = f"{prefix}.{k}" if prefix else k
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and any(k.startswith(p) for p in METRIC_PREFIXES):
                out[path] = float(v)
            else:
                out.update(collect_metrics(v, path))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(collect_metrics(v, f"{prefix}[{_element_key(v, i)}]"))
    return out


def _higher_is_better(path: str) -> bool:
    leaf = path.rsplit(".", 1)[-1]
    for pfx, higher in METRIC_PREFIXES.items():
        if leaf.startswith(pfx):
            return higher
    raise ValueError(f"metric path {path!r} matches no known prefix")


def compare(baseline: dict, candidate: dict,
            threshold: float = DEFAULT_THRESHOLD) -> dict:
    """Diff the two artifacts' metrics.  Returns::

        {"compared": [(path, base, cand, rel_change)],
         "regressions": [...subset worse than threshold...],
         "baseline_only": [...], "candidate_only": [...]}

    ``rel_change`` is signed so that NEGATIVE is always worse (tok/s
    drop, or step-time increase sign-flipped).
    """
    base = collect_metrics(baseline)
    cand = collect_metrics(candidate)
    common = sorted(base.keys() & cand.keys())
    compared, regressions = [], []
    for path in common:
        b, c = base[path], cand[path]
        if abs(b) < 1e-12:
            continue                    # near-zero baseline: no ratio
        rel = (c - b) / abs(b)
        if not _higher_is_better(path):
            rel = -rel
        row = (path, b, c, rel)
        compared.append(row)
        if rel < -threshold:
            regressions.append(row)
    return {
        "compared": compared,
        "regressions": regressions,
        "baseline_only": sorted(base.keys() - cand.keys()),
        "candidate_only": sorted(cand.keys() - base.keys()),
    }


def _load(path: str):
    if not os.path.exists(path):
        return None, f"missing artifact: {path}"
    try:
        with open(path) as f:
            return json.load(f), None
    except ValueError as e:
        return None, f"unparseable artifact {path}: {e}"


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m benchmarks.compare", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--baseline", default=DEFAULT_BASELINE,
                   help="tracked baseline artifact (default: "
                        "BENCH_wallclock.json)")
    p.add_argument("--candidate", default=DEFAULT_CANDIDATE,
                   help="regenerated artifact (default: experiments/"
                        "benchmarks/wallclock.json)")
    p.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                   help="max tolerated relative regression "
                        "(default 0.15 = 15%%)")
    args = p.parse_args(argv)

    baseline, err = _load(args.baseline)
    if err:
        print(f"bench-compare: {err}", file=sys.stderr)
        return 2
    candidate, err = _load(args.candidate)
    if err:
        print(f"bench-compare: {err}", file=sys.stderr)
        return 2

    result = compare(baseline, candidate, threshold=args.threshold)
    if not result["compared"]:
        print("bench-compare: no common metrics between the artifacts",
              file=sys.stderr)
        return 2

    print(f"bench-compare: {len(result['compared'])} metrics, "
          f"threshold {args.threshold:.0%}")
    for path, b, c, rel in result["compared"]:
        flag = " !! REGRESSION" if rel < -args.threshold else ""
        print(f"  {path}: {b:g} -> {c:g} ({rel:+.1%}){flag}")
    for side in ("baseline_only", "candidate_only"):
        if result[side]:
            print(f"  ({side.replace('_', '-')}: "
                  f"{', '.join(result[side])})")
    if result["regressions"]:
        print(f"FAIL: {len(result['regressions'])} metric(s) regressed "
              f"more than {args.threshold:.0%}", file=sys.stderr)
        return 1
    print("OK: within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
