"""Fig. 9(a) + §4.3 — peak throughput: TC(5t) vs BC(8b) = 1.3x, and the
256x250 TC array reaching BC parity with 21.9% fewer ADCs."""
from __future__ import annotations

import dataclasses

from repro.core.cim import MacroConfig
from repro.core.energy import macs_per_cycle, peak_throughput_ratio

from .common import save_json


def run(verbose=True) -> dict:
    ratio = peak_throughput_ratio()
    # §4.3: the 256x250 TC array — 250 SRAM cols = 125 trit cols = 25 ADCs
    small = dataclasses.replace(MacroConfig(), sram_cols=250)
    tc_small = macs_per_cycle(small.adcs, small.rows_active, 5)
    bc = macs_per_cycle(32, 32, 8)
    out = {
        "tc_macs_per_cycle": macs_per_cycle(32, 16, 5),
        "bc_macs_per_cycle": bc,
        "ratio": float(ratio),
        "claim_1p3x": bool(1.2 <= ratio <= 1.4),
        "tc_250col_macs_per_cycle": tc_small,
        "tc_250col_parity": bool(abs(tc_small / bc - 1.0) < 0.05),
        "adc_reduction_250col": 1 - small.adcs / 32,
        "claim_adc_minus_21p9": bool(abs((1 - small.adcs / 32) - 0.219)
                                     < 0.01),
        "paper_ref": "Fig. 9(a), §4.3",
    }
    if verbose:
        print(f"  TC 20.48 vs BC 16 MAC/cycle -> {ratio:.2f}x (paper 1.3x)")
        print(f"  250-col TC: {tc_small:.1f} MAC/cycle (parity: "
              f"{out['tc_250col_parity']}), ADCs -"
              f"{out['adc_reduction_250col']*100:.1f}% (paper -21.9%)")
    save_json("throughput", out)
    return out


if __name__ == "__main__":
    run()
