"""Fig. 6 — Monte-Carlo restore yield.

(a) TL-nvSRAM-CIM yield vs ReRAMs-per-cluster n: stays >= 94% up to n=60.
(b) yield vs cluster count m at n=60.
Contrast: SL-nvSRAM-CIM voltage-divider yield collapses as n grows
(the reason [12] stops at n=6).
"""
from __future__ import annotations

import jax

from repro.core.yield_model import (cluster_sweep, sl_restore_yield,
                                    tl_restore_yield, yield_sweep)

from .common import save_json, stable_seed

NS = (6, 12, 18, 30, 45, 60)


def run(verbose=True, num_mc=8192) -> dict:
    # every Monte-Carlo key derives from the point configuration via
    # stable_seed — no ad-hoc integer offsets (100+n style), so adding
    # a sweep point never reshuffles the draws of the others
    key = jax.random.key(stable_seed("restore_yield", 42))
    tl = {n: tl_restore_yield(
        jax.random.fold_in(key, stable_seed("tl", n, 4, num_mc)),
        n, 4, num_mc) for n in NS}
    sl = {n: sl_restore_yield(
        jax.random.fold_in(key, stable_seed("sl", n, num_mc)),
        n, num_mc) for n in NS}
    ms = cluster_sweep(
        jax.random.fold_in(key, stable_seed("cluster", 60, num_mc)),
        ms=(1, 2, 3, 4), n=60, num_mc=num_mc)
    out = {
        "tl_yield_vs_n": {n: v["weighted"] for n, v in tl.items()},
        "tl_min_state_vs_n": {n: v["min_state"] for n, v in tl.items()},
        "sl_yield_vs_n": {n: v["weighted"] for n, v in sl.items()},
        "tl_yield_vs_m": {m: v["weighted"] for m, v in ms.items()},
        "claim_tl_above_94_at_60": bool(tl[60]["weighted"] >= 0.94),
        "claim_sl_degrades": bool(sl[60]["weighted"] < sl[6]["weighted"]),
        "paper_ref": "Fig. 6",
    }
    if verbose:
        print("  n:      " + "  ".join(f"{n:6d}" for n in NS))
        print("  TL:     " + "  ".join(f"{out['tl_yield_vs_n'][n]:.4f}"
                                       for n in NS))
        print("  SL:     " + "  ".join(f"{out['sl_yield_vs_n'][n]:.4f}"
                                       for n in NS))
        print(f"  TL>=94% @ n=60: {out['claim_tl_above_94_at_60']}; "
              f"SL degrades: {out['claim_sl_degrades']}")
    save_json("restore_yield", out)
    return out


if __name__ == "__main__":
    run()
