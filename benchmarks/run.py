"""Run every paper-table/figure benchmark:  python -m benchmarks.run

Each module reproduces one table/figure of TL-nvSRAM-CIM (DAC'23) and
returns a dict with the measured values + per-claim pass booleans; the
aggregate summary is printed at the end and written to
experiments/benchmarks/summary.json.
"""
from __future__ import annotations

import json
import sys
import time

from . import (accuracy_yield, adc_noise, capacity_density, cell_metrics,
               energy_efficiency, kernel_bench, llm_capacity, quantization,
               restore_yield, roofline_table, throughput)
from .common import save_json

SUITES = [
    ("quantization (Table 3)", quantization.run),
    ("restore_yield (Fig. 6)", restore_yield.run),
    ("cell_metrics (Table 4)", cell_metrics.run),
    ("throughput (Fig. 9a)", throughput.run),
    ("energy_efficiency (Fig. 9b)", energy_efficiency.run),
    ("capacity_density (Fig. 11)", capacity_density.run),
    ("accuracy_yield (Fig. 10)", accuracy_yield.run),
    ("adc_noise (beyond-paper ablation)", adc_noise.run),
    ("llm_capacity (paper model @ assigned archs)", llm_capacity.run),
    ("kernel_bench (TPU adaptation)", kernel_bench.run),
    ("roofline_table (dry-run)", roofline_table.run),
]


def main() -> int:
    summary = {}
    failed = []
    for name, fn in SUITES:
        print(f"== {name}")
        t0 = time.monotonic()
        try:
            res = fn(verbose=True)
            claims = {k: v for k, v in res.items()
                      if k.startswith("claim_") or k.endswith("_reproduced")
                      or k in ("all_match_oracle", "all_claims_in_band")}
            bad = [k for k, v in claims.items() if v is False]
            summary[name] = {"seconds": round(time.monotonic() - t0, 1),
                             "claims": claims, "failed_claims": bad}
            if bad:
                failed.append((name, bad))
        except Exception as e:  # keep the suite running
            summary[name] = {"error": repr(e)}
            failed.append((name, [repr(e)]))
            import traceback
            traceback.print_exc()
        print()
    print("=" * 64)
    total_claims = sum(len(s.get("claims", {})) for s in summary.values())
    bad_claims = sum(len(s.get("failed_claims", [])) for s in summary.values())
    print(f"benchmarks: {len(SUITES)} suites, {total_claims} paper-claim "
          f"checks, {bad_claims} outside band")
    for name, bad in failed:
        print(f"  !! {name}: {bad}")
    save_json("summary", summary)
    return 0


if __name__ == "__main__":
    sys.exit(main())
