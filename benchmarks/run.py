"""Run every paper-table/figure benchmark:  python -m benchmarks.run

Each module reproduces one table/figure of TL-nvSRAM-CIM (DAC'23) and
returns a dict with the measured values + per-claim pass booleans; the
aggregate summary is printed at the end and written to
experiments/benchmarks/summary.json.

``--fast`` runs only the perf-trajectory suites (kernel_bench +
wallclock, reduced sweeps) and then asserts the tracked JSON artifacts
exist, are schema-valid, AND carry no ``claim_*`` key holding false
anywhere in the tree (a committed artifact asserting a failed claim
fails the gate) — the `make bench` CI contract.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
import time

from . import (accuracy_yield, adc_noise, capacity_density, cell_metrics,
               energy_efficiency, kernel_bench, llm_capacity, quantization,
               restore_yield, roofline_table, schema, throughput, wallclock)
from .common import OUT_DIR, REPO_ROOT, save_json

SUITES = [
    ("quantization (Table 3)", quantization.run),
    ("restore_yield (Fig. 6)", restore_yield.run),
    ("cell_metrics (Table 4)", cell_metrics.run),
    ("throughput (Fig. 9a)", throughput.run),
    ("energy_efficiency (Fig. 9b)", energy_efficiency.run),
    ("capacity_density (Fig. 11)", capacity_density.run),
    ("accuracy_yield (Fig. 10)", accuracy_yield.run),
    ("adc_noise (beyond-paper ablation)", adc_noise.run),
    ("llm_capacity (paper model @ assigned archs)", llm_capacity.run),
    ("kernel_bench (TPU adaptation)", kernel_bench.run),
    # write_root=False: only a direct `python -m benchmarks.wallclock`
    # rewrites the tracked BENCH_wallclock.json baseline
    ("wallclock (decode fast lane)",
     functools.partial(wallclock.run, write_root=False)),
    ("roofline_table (dry-run)", roofline_table.run),
]

FAST_SUITES = [
    ("kernel_bench (TPU adaptation)", kernel_bench.run),
    ("wallclock (decode fast lane)",
     functools.partial(wallclock.run, fast=True, write_root=False)),
]

# artifacts `--fast` asserts after the run (schema name derives from the
# BENCH_/.json filename inside schema.validate_file)
FAST_ARTIFACTS = [
    os.path.join(REPO_ROOT, "BENCH_wallclock.json"),
    os.path.join(REPO_ROOT, "BENCH_autotune.json"),
    os.path.join(OUT_DIR, "wallclock.json"),
    os.path.join(OUT_DIR, "kernel_bench.json"),
]


def _false_claims(node, prefix: str = "") -> list[str]:
    """Recursively collect ``claim_*`` keys holding False anywhere in a
    (parsed) artifact — a committed artifact asserting a failed claim
    must fail the gate, not just the suite run that produced it."""
    bad = []
    if isinstance(node, dict):
        for k, v in node.items():
            where = f"{prefix}.{k}" if prefix else k
            if k.startswith("claim_") and v is False:
                bad.append(where)
            else:
                bad.extend(_false_claims(v, where))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            bad.extend(_false_claims(v, f"{prefix}[{i}]"))
    return bad


def check_artifacts() -> list[str]:
    import json
    errors = []
    for path in FAST_ARTIFACTS:
        errors.extend(schema.validate_file(path))
        if os.path.exists(path):
            try:
                with open(path) as f:
                    payload = json.load(f)
            except ValueError:
                continue           # unparseable: already reported above
            errors.extend(f"{path}: {where} is false"
                          for where in _false_claims(payload))
    return errors


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true",
                   help="perf-trajectory suites only + artifact/schema "
                        "check (the `make bench` contract)")
    args = p.parse_args(argv)
    suites = FAST_SUITES if args.fast else SUITES

    summary = {}
    failed = []
    for name, fn in suites:
        print(f"== {name}")
        t0 = time.monotonic()
        try:
            res = fn(verbose=True)
            claims = {k: v for k, v in res.items()
                      if k.startswith("claim_") or k.endswith("_reproduced")
                      or k in ("all_match_oracle", "all_claims_in_band")}
            bad = [k for k, v in claims.items() if v is False]
            summary[name] = {"seconds": round(time.monotonic() - t0, 1),
                             "claims": claims, "failed_claims": bad}
            if bad:
                failed.append((name, bad))
        except Exception as e:  # keep the suite running
            summary[name] = {"error": repr(e)}
            failed.append((name, [repr(e)]))
            import traceback
            traceback.print_exc()
        print()
    print("=" * 64)
    total_claims = sum(len(s.get("claims", {})) for s in summary.values())
    bad_claims = sum(len(s.get("failed_claims", [])) for s in summary.values())
    print(f"benchmarks: {len(suites)} suites, {total_claims} paper-claim "
          f"checks, {bad_claims} outside band")
    for name, bad in failed:
        print(f"  !! {name}: {bad}")
    rc = 1 if failed else 0
    if args.fast:
        errors = check_artifacts()
        if errors:
            for e in errors:
                print(f"  !! schema: {e}")
            rc = 1
        elif not failed:
            print(f"artifacts OK: {', '.join(FAST_ARTIFACTS)}")
        save_json("summary_fast", summary)
    else:
        save_json("summary", summary)
    return rc


if __name__ == "__main__":
    sys.exit(main())
