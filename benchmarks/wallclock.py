"""Wall-clock bench harness for the decode-path fast lane.

Times prefill/decode matmul steps through the ternary kernels (xla
backend on CPU hosts — Pallas interpret mode measures the interpreter,
not the kernel; the pallas backend on real TPUs) and derives the
*structural* waste metrics of the chosen BlockSpecs: padded-FLOP waste
(MXU cycles spent on padding rows/cols) and HBM tile-traffic, for the
shape-adaptive block selection vs the old fixed 128/128/512 tiles.

Writes BENCH_wallclock.json at the repo root — the first point of the
perf trajectory every later "measurably faster" claim is judged against
(schema documented in ROADMAP.md §Performance).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import execute, ops, plan_matmul
from repro.kernels.ternary_matmul import (DEFAULT_BLOCKS, TRIT2_PER_BYTE,
                                          _round_up, select_block_shapes)

from .common import save_bench_json, stable_seed, time_fn

# (M, K, N) — decode: token batches through a d_model x d_ff projection;
# prefill: batch x seq rows through the same weight.
DECODE_SHAPES = [(1, 1024, 1024), (4, 1024, 1024), (8, 1024, 1024),
                 (16, 1024, 1024)]
PREFILL_SHAPES = [(128, 1024, 1024), (256, 512, 1024)]
MODES = ("base3", "trit2")


def padded_flops(m: int, k: int, n: int, blocks) -> int:
    """MAC-FLOPs the grid actually issues: every dim padded up to its
    block multiple (the kernel zero-pads and the MXU multiplies zeros)."""
    bm, bn, bk = blocks
    return 2 * _round_up(m, bm) * _round_up(k, bk) * _round_up(n, bn)


def hbm_tile_bytes(m: int, k: int, n: int, blocks, mode: str) -> int:
    """HBM bytes the BlockSpecs move: x/w tiles per grid step + out/scale.
    (x is re-streamed per N tile, w per M tile — the blocking cost model.)"""
    bm, bn, bk = blocks
    mt, nt, kt = (_round_up(m, bm) // bm, _round_up(n, bn) // bn,
                  _round_up(k, bk) // bk)
    x_tile = bm * bk * 4
    w_tile = (bk // TRIT2_PER_BYTE if mode == "trit2" else bk) * bn
    return (mt * nt * kt * (x_tile + w_tile)
            + mt * nt * bm * bn * 4 + nt * bn * 4)


def shape_cell(m: int, k: int, n: int, mode: str, phase: str,
               backend: str, time_it: bool = True) -> dict:
    adaptive = select_block_shapes(m, k, n, mode)
    # the int8 lane tiles M in 32-row (int8 sublane) quanta, so its
    # blocks — and waste — differ from the float lane's; record both so
    # step_time_s_int8 is paired with the blocking it actually ran
    adaptive_int8 = select_block_shapes(m, k, n, mode, domain="int8")
    fixed = DEFAULT_BLOCKS
    ideal = 2 * m * k * n
    # resolve the plans this cell actually executes (and record them:
    # the artifact must say which backend/domain/blocks produced each
    # step_time_s, not leave it implied by the host platform)
    plan_f = plan_matmul((m, k, n), phase, backend=backend, packing=mode)
    plan_i8 = plan_matmul((m, k, n), phase, backend=backend, packing=mode,
                          domain="int8")
    cell = {
        "phase": phase, "m": m, "k": k, "n": n, "mode": mode,
        "plan": plan_f.describe(), "plan_int8": plan_i8.describe(),
        "blocks_adaptive": list(adaptive), "blocks_fixed": list(fixed),
        "blocks_adaptive_int8": list(adaptive_int8),
        "flops_ideal": ideal,
        "flops_padded_adaptive": padded_flops(m, k, n, adaptive),
        "flops_padded_fixed": padded_flops(m, k, n, fixed),
        "flops_padded_adaptive_int8": padded_flops(m, k, n, adaptive_int8),
        "hbm_bytes_adaptive": hbm_tile_bytes(m, k, n, adaptive, mode),
        "hbm_bytes_fixed": hbm_tile_bytes(m, k, n, fixed, mode),
    }
    cell["flop_waste_adaptive"] = cell["flops_padded_adaptive"] / ideal
    cell["flop_waste_fixed"] = cell["flops_padded_fixed"] / ideal
    cell["flop_waste_reduction"] = (cell["flops_padded_fixed"]
                                    / cell["flops_padded_adaptive"])
    cell["flop_waste_reduction_int8"] = (cell["flops_padded_fixed"]
                                         / cell["flops_padded_adaptive_int8"])
    cell["hbm_waste_reduction"] = (cell["hbm_bytes_fixed"]
                                   / cell["hbm_bytes_adaptive"])
    if time_it:
        key = jax.random.key(stable_seed(m, k, n, mode))
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = 0.02 * jax.random.normal(kw, (k, n), jnp.float32)
        pw = ops.pack_weights(w, mode)
        # jit the whole step (a serving model runs these compiled):
        # eager per-op dispatch would dominate the small decode shapes
        # and make the baseline trivially beatable by adding jax.jit
        step = jax.jit(functools.partial(execute, plan_f))
        step_int8 = jax.jit(functools.partial(execute, plan_i8))
        cell["step_time_s"] = time_fn(step, x, pw)
        cell["step_time_s_int8"] = time_fn(step_int8, x, pw)
    return cell


def serve_loop_bench(max_new: int = 8, requests: int = 4,
                     arch: str = "internlm2-1.8b") -> dict:
    """Tokens/s + host-transfer counts of the on-device decode loop vs
    the legacy per-step driver on the smoke model."""
    import dataclasses
    import time as _time

    from repro import configs
    from repro.models import registry
    from repro.serve import Request, ServeEngine

    cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)

    def run(on_device: bool) -> tuple[dict, dict]:
        eng = ServeEngine(model, params, capacity=64, max_batch=requests,
                          on_device_loop=on_device)

        def submit():
            for i in range(requests):
                prompt = jax.random.randint(jax.random.fold_in(key, i),
                                            (8,), 0, cfg.vocab_size)
                eng.submit(Request(uid=i, prompt=prompt, max_new=max_new))

        submit()
        eng.run()                     # warmup: prefill + decode-loop jit
        # best-of-N timed replays on warm executables under a fixed
        # time budget (same pre-registered rule as common.time_fn):
        # one pass emits ~requests*max_new tokens in ~2ms, so a
        # handful of samples swings with host scheduling far beyond
        # the bench-compare gate's threshold
        best_dt, total, n = float("inf"), 0.0, 0
        tokens = steps = transfers = 0
        while n < 5 or (total < 0.5 and n < 50):
            base_tok, base_steps = eng.generated_tokens, eng.steps_run
            base_tr = eng.host_transfers
            submit()
            t0 = _time.perf_counter()
            eng.run()
            dt = _time.perf_counter() - t0
            best_dt, total, n = min(best_dt, dt), total + dt, n + 1
            tokens = eng.generated_tokens - base_tok
            steps = eng.steps_run - base_steps
            transfers = eng.host_transfers - base_tr
        stats = {"tok_per_s": round(tokens / max(best_dt, 1e-9), 1),
                 "wall_s": round(best_dt, 3),
                 "steps": steps,
                 "host_transfers": transfers,
                 "tokens": tokens}
        return stats, {r.uid: list(r.out_tokens)
                       for r in eng.completed[-requests:]}

    (device, device_out), (legacy, legacy_out) = run(True), run(False)
    return {
        "arch": arch, "requests": requests, "max_new": max_new,
        "device_loop": device, "legacy_loop": legacy,
        "buckets": 1,
        "claim_device_loop_single_transfer":
            device["host_transfers"] == 1,
        # per-request token VALUES, not counts — a wrong token with an
        # unchanged length must fail this claim
        "tokens_identical": device_out == legacy_out,
    }


def serve_continuous_bench(fast: bool = False,
                           arch: str = "internlm2-1.8b") -> dict:
    """Continuous-batching Scheduler vs the bucket driver under a bursty
    arrival trace: tok/s, p50/p99 request latency (completion -
    arrival), slot occupancy, and the per-chunk transfer accounting.

    The trace is adversarial for bucket-at-a-time serving in the ways
    production traffic is: three interleaved prompt lengths split each
    burst into under-filled per-length buckets that serialize, mixed
    max-new budgets leave bucket rows decoding dead air behind the
    straggler while the continuous pool retires and refills those
    slots, and the burst gap is shorter than the bucket driver's
    per-burst serve time, so its backlog grows where the pool absorbs
    the overload.  Both drivers replay the identical trace at the same
    pool width (slots == max_batch), on a widened smoke model
    (d_model 256) where a decode step costs the same in both drivers —
    so the delta measures scheduling, not kernel shape effects.

    Bursts arrive atomically (spread 0) and identical in composition
    (the length/max-new cycles divide the burst size), so the bucket
    driver only ever pops a fresh burst (per-length width 2) or a
    backlog of two (per-length width 4); the two warmup passes — one
    burst at t=0, then two bursts at t=0 — cover exactly those
    (batch width x prompt length x loop cap) compile-cache keys, and
    the timed replays run warm executables only.  `fast` reduces the
    best-of repeat count, not the trace.

    Per-request tokens must stay bitwise identical between the drivers
    (the chunked loop is a re-scheduling of the same per-request
    computation); `claim_continuous_tokens_identical` gates it.
    """
    import dataclasses
    import time as _time

    from repro import configs
    from repro.models import registry
    from repro.serve import (Request, Scheduler, ServeEngine,
                             bursty_arrivals, latency_stats, make_trace)

    cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32,
                              d_model=256, d_ff=768, num_layers=4)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)

    slots = 4
    chunk = 8
    n = 12
    gap_s = 0.15
    # the max-new cycle is laid out against the length cycle (both
    # divide the burst size of 6 — the warmup-coverage invariant below)
    # so every per-length bucket pairs a 24-token straggler with a
    # short row, while the pool spreads the stragglers across slots
    # and refills around them
    arrivals = bursty_arrivals(n, bursts=2, gap_s=gap_s, spread_s=0.0,
                               seed=7)
    trace = make_trace(arrivals, prompt_lens=[8, 12, 16],
                       max_news=[24, 6, 12, 6, 24, 12])

    def requests(records) -> list:
        out = []
        for i, rec in enumerate(records):
            prompt = jax.random.randint(jax.random.fold_in(key, i),
                                        (rec["prompt_len"],), 0,
                                        cfg.vocab_size)
            out.append(Request(uid=i, prompt=prompt,
                               max_new=rec["max_new"],
                               eos_id=rec["eos_id"],
                               arrival_s=rec["arrival_s"]))
        return out

    # warmup workloads: one burst at t=0 (fresh-burst widths), then two
    # bursts at t=0 (backlog widths) — together they hit every
    # (batch width x prompt length x loop cap) compile-cache key the
    # timed replay can reach, in- or out-of-overload
    warms = ([dict(rec, arrival_s=0.0) for rec in trace[: n // 2]],
             [dict(rec, arrival_s=0.0) for rec in trace])
    # best-of-N with a FIXED, pre-registered N (no adaptive stopping —
    # retrying only while a claim fails would bias the gate toward
    # passing): OS noise only ever slows a replay down, so the
    # per-metric minimum over N replays is the clean estimate for BOTH
    # drivers symmetrically
    repeats = 4 if fast else 6

    bucket = ServeEngine(model, params, capacity=64, max_batch=slots)
    for warm in warms:
        for r in requests(warm):
            bucket.submit(r)
        bucket.run_trace()

    def bucket_replay():
        done0, tok0 = len(bucket.completed), bucket.generated_tokens
        for r in requests(trace):
            bucket.submit(r)
        t0 = _time.perf_counter()
        bucket.run_trace()
        wall = _time.perf_counter() - t0
        done = bucket.completed[done0:]
        tokens = bucket.generated_tokens - tok0
        return {"tok_per_s": round(tokens / max(wall, 1e-9), 1),
                "wall_s": round(wall, 3), "tokens": tokens,
                **latency_stats(done)}, done

    sched = Scheduler(model, params, capacity=64, slots=slots, chunk=chunk)
    for warm in warms:
        for r in requests(warm):
            sched.submit(r)
        sched.run()

    def sched_replay():
        done0, tok0 = len(sched.completed), sched.generated_tokens
        base = (sched.chunks_run, sched.host_transfers,
                sched.decode_steps, sched.occupied_slot_steps)
        for r in requests(trace):
            sched.submit(r)
        t0 = _time.perf_counter()
        sched.run()
        wall = _time.perf_counter() - t0
        done = sched.completed[done0:]
        tokens = sched.generated_tokens - tok0
        chunks = sched.chunks_run - base[0]
        steps = sched.decode_steps - base[2]
        return {"tok_per_s": round(tokens / max(wall, 1e-9), 1),
                "wall_s": round(wall, 3), "tokens": tokens,
                **latency_stats(done),
                "chunks": chunks,
                "host_transfers": sched.host_transfers - base[1],
                "decode_steps": steps,
                "slot_occupancy": round(
                    (sched.occupied_slot_steps - base[3])
                    / max(slots * steps, 1), 3)}, done

    def best_of(replays):
        """Best-of merge: each timing metric takes its own best replay
        (min wall/latency, max tok/s — OS noise only ever worsens a
        replay, and p99 over 12 requests is a max statistic, so the
        min-wall replay is NOT necessarily the clean-p99 one); the
        deterministic accounting fields come from the min-wall replay.
        Applied identically to both drivers."""
        stats, done = min(replays, key=lambda r: r[0]["wall_s"])
        stats = dict(stats)
        for key_, pick in (("tok_per_s", max), ("wall_s", min),
                           ("p50_s", min), ("p99_s", min),
                           ("p999_s", min), ("mean_s", min)):
            stats[key_] = pick(r[0][key_] for r in replays)
        return stats, done

    # interleave the drivers' replays so a transient noise window on
    # the host degrades both pools alike rather than one wholesale
    bucket_replays, sched_replays = [], []
    for _ in range(repeats):
        bucket_replays.append(bucket_replay())
        sched_replays.append(sched_replay())
    bucket_stats, bucket_done = best_of(bucket_replays)
    sched_stats, sched_done = best_of(sched_replays)

    bucket_out = {r.uid: list(r.out_tokens) for r in bucket_done}
    sched_out = {r.uid: list(r.out_tokens) for r in sched_done}
    return {
        "arch": arch, "model": "smoke-wide-256", "requests": n,
        "slots": slots, "chunk": chunk, "gap_s": gap_s,
        "trace": trace,
        "bucket": bucket_stats,
        "continuous": sched_stats,
        "claim_continuous_beats_bucket_tokps":
            sched_stats["tok_per_s"] > bucket_stats["tok_per_s"],
        "claim_continuous_beats_bucket_p99":
            sched_stats["p99_s"] < bucket_stats["p99_s"],
        # per-request token VALUES across drivers (bitwise parity)
        "claim_continuous_tokens_identical": sched_out == bucket_out,
        # the O(1)-transfer-per-chunk contract, at the bench level
        "claim_chunk_transfer_accounting":
            sched_stats["host_transfers"] == sched_stats["chunks"],
    }


def serve_paged_bench(fast: bool = False,
                      arch: str = "internlm2-1.8b") -> dict:
    """Paged, prefix-shared KV pool vs the dense slot pool at equal pool
    width AND equal memory budget (the paged pool's ``num_pages``
    defaults to the dense-pool equivalent, so both schedulers may touch
    the same worst-case bytes — the paged one just doesn't resident
    them).

    The trace is the paper's density argument shaped as serving
    traffic: mostly short requests with a shared per-length prompt (a
    system-prompt stand-in — identical prefixes that the dense pool
    duplicates per slot) plus one long request per burst that forces
    the dense capacity to be provisioned at 64 positions for EVERY
    slot.  Gates: per-request tokens bitwise identical across pools,
    peak resident KV bytes >= 2x lower paged, and a nonzero
    prefix-hit rate.

    Fused-vs-gather (ISSUE 8): a third engine serves the same trace
    with ``fused_attn=True`` — the planned ``paged_attn`` executor
    reading the page pool in-kernel — against the ``slot_view`` gather
    path.  Both paths' tok/s are recorded, plus the MEASURED byte
    traffic of each compiled chunk fn (XLA cost analysis).  The
    beats-gather claim is judged on wallclock where the kernel lowers
    natively; on interpret-emulation hosts (CPU CI) wallclock compares
    an emulator against native XLA, so the claim rides the measured
    byte traffic instead — ``fused_claim_basis`` records which basis
    the committed artifact used.  Token parity is bitwise either way.
    """
    import dataclasses
    import time as _time

    from repro import configs
    from repro.models import registry
    from repro.serve import (PagedScheduler, Request, Scheduler,
                             bursty_arrivals, latency_stats, make_trace)

    cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32,
                              d_model=256, d_ff=768, num_layers=4)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)

    slots, chunk, capacity, page_size = 4, 8, 64, 8
    n = 16
    arrivals = bursty_arrivals(n, bursts=2, gap_s=0.1, spread_s=0.0,
                               seed=11)
    # cycle of 8 divides the burst size: every burst carries the same
    # mix — 6 short (8-token) requests, one 16, one 48-token straggler
    # whose budget (48 + 16 = 64) sets the dense per-slot capacity
    trace = make_trace(arrivals,
                       prompt_lens=[8, 8, 16, 8, 8, 16, 8, 48],
                       max_news=[8, 8, 8, 8, 8, 8, 8, 16])

    def requests(records) -> list:
        out = []
        for i, rec in enumerate(records):
            # one prompt per length class: identical prefixes across
            # same-length requests (the prefix-sharing workload)
            prompt = jax.random.randint(
                jax.random.fold_in(key, rec["prompt_len"]),
                (rec["prompt_len"],), 0, cfg.vocab_size)
            out.append(Request(uid=i, prompt=prompt,
                               max_new=rec["max_new"],
                               eos_id=rec["eos_id"],
                               arrival_s=rec["arrival_s"]))
        return out

    warm = [dict(rec, arrival_s=0.0) for rec in trace]
    # same replay count in both modes: the fast run's numbers feed the
    # bench-compare gate against the full-sweep baseline, and min-of-3
    # vs min-of-5 is a structural skew on a noisy host, not noise
    repeats = 5

    dense = Scheduler(model, params, capacity=capacity, slots=slots,
                      chunk=chunk)
    paged = PagedScheduler(model, params, capacity=capacity, slots=slots,
                           chunk=chunk, page_size=page_size)
    fused = PagedScheduler(model, params, capacity=capacity, slots=slots,
                           chunk=chunk, page_size=page_size,
                           fused_attn=True)
    # when 'auto' resolved the fused plan (native lowering), the gather
    # path needs its own engine; on interpret hosts 'auto' IS gather
    gather = paged if paged.attn_plan is None else PagedScheduler(
        model, params, capacity=capacity, slots=slots, chunk=chunk,
        page_size=page_size, fused_attn=False)

    def chunk_bytes(eng):
        """Bytes the compiled chunk fn actually touches, from XLA cost
        analysis over the live post-warmup operand shapes."""
        args = (eng.params, eng.tok, eng.pool,
                jnp.asarray(eng._page_table), eng.pos, eng.live,
                eng.made, eng.fresh, eng.max_new_row, eng.eos_row)
        try:
            ca = eng._chunk_fn.lower(*args).compile().cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            measured = ca.get("bytes accessed")
            if measured:
                return int(measured), "xla-cost-analysis"
            reason = "no 'bytes accessed' key"
        except Exception as e:      # backend without cost analysis
            reason = f"{type(e).__name__}: {e}"
        # analytic decode-read traffic model: per step per layer the
        # gather path reads the pool pages, materializes the dense
        # (slots, capacity) copy, and re-reads it in attention (3x the
        # pool traffic); the fused kernel streams the pool once
        kv_step = (2 * slots * capacity * cfg.num_kv_heads * cfg.hd
                   * jnp.dtype(cfg.dtype).itemsize * cfg.num_layers)
        mult = 1 if eng.attn_plan is not None else 3
        return (mult * kv_step,
                f"analytic-traffic-model (cost analysis unavailable: "
                f"{reason})")

    def replay(eng):
        done0, tok0 = len(eng.completed), eng.generated_tokens
        for r in requests(trace):
            eng.submit(r)
        t0 = _time.perf_counter()
        eng.run()
        wall = _time.perf_counter() - t0
        done = eng.completed[done0:]
        tokens = eng.generated_tokens - tok0
        return (round(tokens / max(wall, 1e-9), 1), round(wall, 3),
                tokens, {r.uid: list(r.out_tokens) for r in done},
                latency_stats(done))

    engines = [dense, paged, fused]
    if gather is not paged:
        engines.append(gather)
    for eng in engines:                  # warmup: compile every key
        for r in requests(warm):
            eng.submit(r)
        eng.run()
    # measure only the bursty replays: the all-at-t=0 warmup can
    # co-resident a different request mix than any replay reaches
    paged.allocator.reset_stats()

    bytes_fused, bytes_source = chunk_bytes(fused)
    bytes_gather, _ = chunk_bytes(gather)

    replays = {id(eng): [] for eng in engines}
    for _ in range(repeats):             # interleaved best-of (fixed N)
        for eng in engines:
            replays[id(eng)].append(replay(eng))
    dense_tokps = max(r[0] for r in replays[id(dense)])
    paged_tokps = max(r[0] for r in replays[id(paged)])
    fused_tokps = max(r[0] for r in replays[id(fused)])
    gather_tokps = max(r[0] for r in replays[id(gather)])
    # request-latency breakdown (p50/p99/p999 + queue-wait vs service)
    # from each pool's min-wall replay — the noise-clean estimate
    latency_dense = min(replays[id(dense)], key=lambda r: r[1])[4]
    latency_paged = min(replays[id(paged)], key=lambda r: r[1])[4]
    dense_out = replays[id(dense)][-1][3]
    paged_out = replays[id(paged)][-1][3]
    fused_out = replays[id(fused)][-1][3]
    gather_out = replays[id(gather)][-1][3]

    if fused.attn_plan.interpret:
        fused_basis = ("hbm-bytes (interpret-mode kernel emulation; "
                       "wallclock would compare an emulator against "
                       "native XLA)")
        fused_beats = bool(bytes_fused < bytes_gather)
    else:
        fused_basis = "wallclock"
        fused_beats = bool(fused_tokps >= gather_tokps)

    kv_dense = dense.kv_bytes()
    kv_paged_peak = paged.kv_bytes_resident_peak
    out = {
        "arch": arch, "model": "smoke-wide-256", "requests": n,
        "slots": slots, "chunk": chunk, "capacity": capacity,
        "page_size": page_size, "num_pages": paged.num_pages,
        "trace": trace,
        "tok_per_s_dense": dense_tokps,
        "tok_per_s_paged": paged_tokps,
        "kv_bytes_dense": kv_dense,
        "kv_bytes_paged_pool": paged.kv_bytes(),
        "kv_bytes_paged_peak": kv_paged_peak,
        "kv_bytes_reduction": round(kv_dense / max(kv_paged_peak, 1), 2),
        "pages_in_use_peak": paged.allocator.peak_in_use,
        "prefix_hit_rate": round(paged.prefix_hit_rate, 4),
        "prefix_hits": paged.allocator.prefix_hits,
        "latency_dense": latency_dense,
        "latency_paged": latency_paged,
        # fused-vs-gather decode read (ISSUE 8): the resolved attention
        # plan, both paths' tok/s, and the measured chunk byte traffic
        "attn_plan": fused.attn_plan.describe(),
        "tok_per_s_paged_fused": fused_tokps,
        "tok_per_s_paged_gather": gather_tokps,
        "hbm_bytes_chunk_fused": bytes_fused,
        "hbm_bytes_chunk_gather": bytes_gather,
        "hbm_bytes_reduction": round(bytes_gather
                                     / max(bytes_fused, 1), 3),
        "hbm_bytes_source": bytes_source,
        "fused_claim_basis": fused_basis,
        # metrics benchmarks/compare.py must NOT gate on this artifact:
        # under interpret emulation the fused tok/s measures the
        # emulator, not the kernel — the beats-gather claim runs on
        # byte traffic instead (fused_claim_basis)
        "ungated_metrics": ([] if fused_basis == "wallclock"
                            else ["tok_per_s_paged_fused"]),
        # per-request token VALUES across pools (bitwise parity)
        "claim_paged_tokens_identical": paged_out == dense_out,
        "claim_paged_kv_bytes_2x": kv_dense >= 2 * kv_paged_peak,
        "claim_paged_prefix_hits": paged.allocator.prefix_hits > 0,
        "claim_paged_fused_tokens_identical":
            fused_out == dense_out and gather_out == dense_out,
        "claim_paged_fused_beats_gather": fused_beats,
        "claim_paged_fused_hbm_lt_gather":
            bool(bytes_fused < bytes_gather),
    }
    return out


def serve_fidelity_bench(fast: bool = False,
                         arch: str = "internlm2-1.8b") -> dict:
    """Device-fidelity serving vs exact serving at the MEASURED TL
    restore yield: accuracy, throughput, and the restore-scrub repair
    gate (ISSUE: graceful degradation must be a measured repair, not a
    no-op).

    Three measurements, all under ONE fault campaign
    (``measured_fault_model`` — per-state restore yields from the
    Monte-Carlo yield model, lognormal conductance variation, and a
    per-chunk drift channel):

      * accuracy — the smoke classifier evaluated through the exact
        ternary kernels vs the ``fidelity='device'`` analog path
        (faulted trits, conductance-weighted discharge counts, 5-bit
        ADC).  The drop is gated by ``schema.FIDELITY_ACC_DROP_MAX`` —
        the schema-pinned bound the acceptance criterion names.
      * serving — exact vs device-fidelity continuous Schedulers over
        the same trace on the widened smoke model: tok/s both ways,
        per-request token agreement (device tokens are EXPECTED to
        diverge — that divergence is the fidelity being simulated), and
        the one-transfer-per-chunk contract with the ADC clip counters
        riding the chunk transfer.
      * scrub gate — two device engines, one scrubbing every 2 chunks,
        one never (``scrub_every=0``).  Served-vs-pristine trit error
        must COMPOUND without scrubbing (margin over the scrubbed
        engine) while the scrubbed engine stays bounded near the
        restore-yield residual — and that residual must be nonzero
        (a scrub is a re-restore through the confusion channel, not a
        silent reset to pristine).

    Energy: each scrub is one full-array restore cycle per mapped TL
    array (Table 5's ``e_restore_tl_array``), the DC-power-free repair
    cost the paper trades against DRAM refills.
    """
    import dataclasses
    import time as _time

    from repro import configs, faults
    from repro.core.energy import C as ECONST, arrays_to_fit
    from repro.core.cim_linear import CIMConfig, ternarize_params
    from repro.data import ClassTaskConfig
    from repro.models import registry
    from repro.serve import Request, Scheduler, make_trace

    from .common import eval_mlp, train_mlp
    from .schema import FIDELITY_ACC_DROP_MAX

    fm = faults.measured_fault_model(num_mc=1024 if fast else 4096,
                                     drift_rate=0.004)
    prev_fm = faults.set_fault_model(fm)
    try:
        # ---------------------------------------------- accuracy gate
        task = ClassTaskConfig(num_classes=10, dim=128, snr=2.5, seed=0)
        mlp = train_mlp(task)

        def kernel_mm(fidelity: str):
            def mm(x, w):
                pw = ops.pack_weights(w, "base3")
                plan = plan_matmul(
                    (int(x.shape[0]), int(x.shape[1]), int(w.shape[1])),
                    "decode", packing="base3", fidelity=fidelity)
                return execute(plan, x, pw)
            return mm

        eval_kw = dict(batches=2 if fast else 4, batch=256)
        acc_float = eval_mlp(mlp, task, **eval_kw)
        acc_exact = eval_mlp(mlp, task, matmul=kernel_mm("exact"),
                             **eval_kw)
        acc_device = eval_mlp(mlp, task, matmul=kernel_mm("device"),
                              **eval_kw)
        acc_drop = acc_exact - acc_device

        # ------------------------------------------- serving + scrub
        cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32,
                                  d_model=256, d_ff=768, num_layers=4)
        model = registry.build(cfg)
        fparams = model.init(jax.random.key(0))
        cim_exact = CIMConfig(mode="ternary", packing="base3")
        cim_device = CIMConfig(mode="ternary", packing="base3",
                               fidelity="device")
        pristine = ternarize_params(fparams, cim_exact)

        slots, chunk, n = 4, 4, 6
        trace = make_trace([0.0] * n, prompt_lens=[8, 12],
                           max_news=[12, 8])
        key = jax.random.key(1)

        def requests():
            out = []
            for i, rec in enumerate(trace):
                prompt = jax.random.randint(jax.random.fold_in(key, i),
                                            (rec["prompt_len"],), 0,
                                            cfg.vocab_size)
                out.append(Request(uid=i, prompt=prompt,
                                   max_new=rec["max_new"],
                                   eos_id=rec["eos_id"],
                                   arrival_s=rec["arrival_s"]))
            return out

        repeats = 2 if fast else 3

        def run_engine(eng):
            for r in requests():               # warmup: compile keys
                eng.submit(r)
            eng.run()
            tokps, out_tokens = 0.0, {}
            for _ in range(repeats):           # fixed-N best-of
                tok0, done0 = eng.generated_tokens, len(eng.completed)
                for r in requests():
                    eng.submit(r)
                t0 = _time.perf_counter()
                eng.run()
                wall = _time.perf_counter() - t0
                tokens = eng.generated_tokens - tok0
                tokps = max(tokps, tokens / max(wall, 1e-9))
                out_tokens = {r.uid: list(r.out_tokens)
                              for r in eng.completed[done0:]}
            return round(tokps, 1), out_tokens

        exact_eng = Scheduler(model, pristine, capacity=64, slots=slots,
                              chunk=chunk, cim=cim_exact)
        scrub_eng = Scheduler(model, pristine, capacity=64, slots=slots,
                              chunk=chunk, cim=cim_device, scrub_every=2)
        noscrub_eng = Scheduler(model, pristine, capacity=64, slots=slots,
                                chunk=chunk, cim=cim_device,
                                scrub_every=0)
        tokps_exact, tokens_exact = run_engine(exact_eng)
        tokps_device, tokens_device = run_engine(scrub_eng)
        run_engine(noscrub_eng)

        agree = total = 0
        for uid, toks in tokens_exact.items():
            dev = tokens_device.get(uid, [])
            agree += sum(a == b for a, b in zip(toks, dev))
            total += max(len(toks), len(dev))
        token_agreement = agree / max(total, 1)

        err_scrub = faults.packed_trit_error_rate(scrub_eng.params,
                                                  pristine)
        err_noscrub = faults.packed_trit_error_rate(noscrub_eng.params,
                                                    pristine)
        residual_bound = 1.0 - min(fm.restore_yield)

        # scrub restore energy: one full-array restore cycle per mapped
        # TL array per scrub (8-bit weight bytes = param count)
        n_arrays = arrays_to_fit(cfg.param_count(), "tl")
        scrub_energy_j = (scrub_eng.scrubs_run * n_arrays
                          * ECONST.e_restore_tl_array)
        tokens_served = scrub_eng.generated_tokens
    finally:
        faults.set_fault_model(prev_fm)

    return {
        "arch": arch, "model": "smoke-wide-256", "requests": n,
        "slots": slots, "chunk": chunk, "trace": trace,
        "fault_model": fm.describe(),
        "plan_exact": plan_matmul((1, 256, 768), "decode",
                                  packing="base3").describe(),
        "plan_device": plan_matmul((1, 256, 768), "decode",
                                   packing="base3",
                                   fidelity="device").describe(),
        "acc_float": acc_float, "acc_exact": acc_exact,
        "acc_device": acc_device,
        "acc_drop": round(acc_drop, 4),
        "acc_drop_max": FIDELITY_ACC_DROP_MAX,
        "tok_per_s_exact": tokps_exact,
        "tok_per_s_device": tokps_device,
        "token_agreement": round(token_agreement, 4),
        "err_with_scrub": round(err_scrub, 5),
        "err_no_scrub": round(err_noscrub, 5),
        "scrub_residual_bound": round(residual_bound, 5),
        "scrubs_run": scrub_eng.scrubs_run,
        "adc_clip_lo": scrub_eng.adc_clip_lo,
        "adc_clip_hi": scrub_eng.adc_clip_hi,
        "host_transfers_device": scrub_eng.host_transfers,
        "chunks_device": scrub_eng.chunks_run,
        "scrub_energy_j": scrub_energy_j,
        "scrub_energy_j_per_token": scrub_energy_j / max(tokens_served, 1),
        "claim_fidelity_accuracy_within_bound":
            acc_device >= acc_exact - FIDELITY_ACC_DROP_MAX,
        # degradation is real: the unscrubbed engine's served weights
        # drift measurably past the scrubbed engine's error
        "claim_fidelity_degrades_without_scrub":
            err_noscrub >= err_scrub + 0.01,
        # repair is real AND not a no-op: bounded near the restore
        # yield residual, but nonzero (scrub re-restores through the
        # confusion channel — it cannot silently return pristine bits)
        "claim_fidelity_scrub_repairs":
            0.0 < err_scrub <= 3.0 * residual_bound,
        # the one-transfer-per-chunk contract holds in device mode
        # (ADC clip counters ride the chunk transfer)
        "claim_fidelity_transfer_accounting":
            scrub_eng.host_transfers == scrub_eng.chunks_run,
    }


def serve_frontend_bench(fast: bool = False) -> dict:
    """The SLO-aware serving front-end (``repro.frontend``) over a
    two-model registry, measured three ways:

      * **parity + throughput** — an open-loop trace replayed through
        ``FrontendServer`` (bounded queue, streaming, round-robin over
        per-model ``PagedScheduler`` pools) vs the SAME records driven
        straight into the same pools' ``run()``.  Per-request tokens
        must be bitwise identical (the front-end re-orders admission,
        never re-implements scheduling), and streaming must add zero
        transfers (``host_transfers == chunks`` across every pool).
      * **backpressure** — the burst replayed into a ``queue_limit=2``
        server: the pending queue never exceeds the bound and every
        submit is accounted for (completed + rejected, each reject
        with a reason).
      * **goodput under overload** — a 12-request burst of interactive
        requests (priority 0, a deadline calibrated to ~0.6x the
        measured warm makespan) interleaved with no-deadline batch
        requests, served under ``SLOAdmission`` vs the FIFO baseline.
        The currency is GOODPUT: deadline-met tokens per second —
        tokens of requests that miss their deadline earn nothing.  SLO
        admission serves the interactive class first (and sheds
        pending requests whose deadline became unmeetable), so its
        goodput must beat FIFO's, whose late interactive requests blow
        their deadlines behind batch traffic.  FIFO-under-overload is
        adversarial by design, so its goodput is in
        ``ungated_metrics`` — benchmarks/compare.py gates the SLO
        number only.

    Deadlines calibrate against the measured warm parity-replay
    makespan, so the overload scenario tracks host speed instead of
    hard-coding seconds.  Fixed pre-registered best-of-N throughout,
    interleaved across the compared sides.
    """
    from repro.frontend import (FIFOAdmission, FrontendServer,
                                ModelRegistry, ModelSpec, SLOAdmission,
                                replay, replay_direct, trace_requests)
    from repro.serve import make_trace

    models = ["internlm2-1.8b", "qwen3-14b"]
    slots, chunk, queue_limit = 2, 4, 32
    reg = ModelRegistry()
    for name in models:
        reg.register(ModelSpec(name=name, arch=name, smoke=True,
                               kind="paged", capacity=64, slots=slots,
                               chunk=chunk, page_size=16))

    # same replay count in both modes (cf. serve_paged: the fast run's
    # numbers feed the bench-compare gate against the full-sweep
    # baseline, and min-of-N asymmetry is structural skew, not noise)
    repeats = 4

    # ------------------------- parity + throughput vs direct pools
    n = 8
    trace = make_trace([0.0] * n, [8, 12], [8, 12])
    records = trace_requests(trace, reg, models, seed=0)
    server = FrontendServer(reg, FIFOAdmission(),
                            queue_limit=queue_limit)
    replay(server, records)            # warmup: compile every pool key
    replay_direct(reg, records)
    fe_tokps = dt_tokps = 0.0
    fe_best = dt_out = None
    for _ in range(repeats):           # interleaved fixed-N best-of
        r = replay(server, records, collect_tokens=True)
        fe_tokps = max(fe_tokps, r["tok_per_s"])
        if fe_best is None or r["wall_s"] < fe_best["wall_s"]:
            fe_best = r
        stats, toks = replay_direct(reg, records)
        dt_tokps = max(dt_tokps, stats["tok_per_s"])
        dt_out = toks
    # uids restart per direct epoch but grow monotonically across
    # server epochs; both sides list tokens in uid order == submission
    # order == record order, so the parity compare is positional
    fe_tokens = [fe_best["out_tokens"][k]
                 for k in sorted(fe_best["out_tokens"])]
    dt_tokens = [dt_out[k] for k in sorted(dt_out)]
    warm_wall = fe_best["wall_s"]

    # ---------------------------------- backpressure at the bound
    bp_server = FrontendServer(reg, FIFOAdmission(), queue_limit=2)
    bp = replay(bp_server, records)
    bp_bounded = (
        bp_server.max_pending_seen <= bp_server.queue_limit
        and bp_server.submitted == (len(bp_server.completed)
                                    + len(bp_server.rejected))
        and bp["rejects_by_reason"].get("queue-full", 0) > 0)

    # --------------------------------- goodput: SLO vs FIFO admission
    # class cycle of 4 so each model (assigned round-robin by record
    # index) serves both classes: interactive (priority 0, short,
    # tight deadline) and batch (priority 1, long, no deadline)
    # interactive deadline at 1.1x the warm 8-request makespan: under
    # SLO admission the interactive class is served first and fully
    # drains near ~0.85x (its 6 requests alone, on the 2-slot pools) —
    # met with margin, so the GATED goodput number is stable — while
    # the overload trace's makespan is ~1.65x and FIFO's last
    # interactive per model lands near ~1.3x behind the 16-token batch
    # rows, a structural miss rather than a borderline one
    n_over = 12
    tight = round(1.1 * warm_wall, 4)
    floor = round(0.1 * warm_wall, 4)
    over_trace = make_trace([0.0] * n_over,
                            prompt_lens=[8, 12, 12, 8],
                            max_news=[6, 16, 16, 6],
                            priorities=[0, 1, 1, 0],
                            deadlines=[tight, None, None, tight])
    over_records = trace_requests(over_trace, reg, models, seed=1)

    def goodput_replay(policy):
        srv = FrontendServer(reg, policy, queue_limit=n_over)
        return replay(srv, over_records)

    policies = (("fifo", lambda: FIFOAdmission()),
                ("slo", lambda: SLOAdmission(service_floor_s=floor)))
    for _, mk in policies:             # warmup: the overload loop keys
        goodput_replay(mk())
    best: dict = {"fifo": None, "slo": None}
    for _ in range(repeats):           # interleaved fixed-N best-of
        for pname, mk in policies:
            r = goodput_replay(mk())
            if best[pname] is None or (r["tok_per_s_goodput"]
                                       > best[pname]["tok_per_s_goodput"]):
                best[pname] = r
    slo, fifo = best["slo"], best["fifo"]

    transfers = sum(reg.entry(m).scheduler.host_transfers
                    for m in reg.names())
    chunks = sum(reg.entry(m).scheduler.chunks_run for m in reg.names())

    epoch_keys = ("wall_s", "tokens", "p50_s", "p99_s", "p999_s",
                  "ttft_p50_s", "ttft_p99_s", "queue_wait_mean_s",
                  "service_mean_s", "host_transfers", "chunks")
    return {
        "models": models, "requests": n, "slots": slots, "chunk": chunk,
        "queue_limit": queue_limit, "overload_queue_limit": 2,
        "capacity_report": reg.capacity_report(),
        "trace": trace,
        "tok_per_s_frontend": fe_tokps,
        "tok_per_s_direct": dt_tokps,
        "frontend": {k: fe_best[k] for k in epoch_keys},
        "overload": {k: bp[k] for k in
                     ("submitted", "completed", "rejected",
                      "max_pending_seen", "rejects_by_reason")},
        "goodput_trace": over_trace,
        "deadline_tight_s": tight,
        "service_floor_s": floor,
        "tok_per_s_goodput_slo": slo["tok_per_s_goodput"],
        "tok_per_s_goodput_fifo": fifo["tok_per_s_goodput"],
        "deadline_met_slo": slo["deadline_met"],
        "deadline_met_fifo": fifo["deadline_met"],
        "deadline_total": slo["deadline_total"],
        "shed_slo": slo["shed"],
        "ttft_p50_s_slo": slo["ttft_p50_s"],
        "ttft_p50_s_fifo": fifo["ttft_p50_s"],
        # FIFO-under-overload is the adversarial baseline: how much it
        # loses is host-noise-sensitive by construction (borderline
        # deadlines), so it must not be regression-gated
        "ungated_metrics": ["tok_per_s_goodput_fifo"],
        # per-request token VALUES through the front-end (bitwise)
        "claim_frontend_tokens_identical": fe_tokens == dt_tokens,
        "claim_frontend_backpressure_bounded": bp_bounded,
        "claim_frontend_goodput_under_overload":
            slo["tok_per_s_goodput"] > fifo["tok_per_s_goodput"],
        # streaming adds no transfers, across every pool's lifetime
        "claim_frontend_transfer_accounting": transfers == chunks,
    }


def run(verbose: bool = True, fast: bool = False,
        write_root: bool | None = None) -> dict:
    """write_root=True rewrites the tracked repo-root baseline
    (BENCH_wallclock.json); default: only the full direct sweep
    (``python -m benchmarks.wallclock``) does — benchmarks.run passes
    False so neither suite mode touches the baseline."""
    if write_root is None:
        write_root = not fast
    backend = "auto" if jax.default_backend() == "tpu" else "xla"
    # serve benches run FIRST: the timed shape-cell sweep saturates the
    # host thread pools for minutes, and the latency-sensitive serving
    # comparison (arrival sleeps, chunk-boundary host work) degrades
    # asymmetrically on contended small hosts if it runs in that wake
    #
    # max_new is NOT reduced in fast mode: the device loop's tok/s
    # scales with tokens-per-transfer, so a shorter fast-mode decode
    # would read as a structural regression against the full-sweep
    # baseline in the bench-compare gate
    serve = serve_loop_bench(max_new=8)
    serve_continuous = serve_continuous_bench(fast=fast)
    serve_paged = serve_paged_bench(fast=fast)
    serve_fidelity = serve_fidelity_bench(fast=fast)
    serve_frontend = serve_frontend_bench(fast=fast)
    decode = DECODE_SHAPES[:2] if fast else DECODE_SHAPES
    prefill = PREFILL_SHAPES[:1] if fast else PREFILL_SHAPES
    shapes = []
    for m, k, n in decode:
        for mode in MODES:
            shapes.append(shape_cell(m, k, n, mode, "decode", backend))
    for m, k, n in prefill:
        for mode in MODES:
            shapes.append(shape_cell(m, k, n, mode, "prefill", backend))

    decode_cells = [c for c in shapes if c["phase"] == "decode"
                    and c["m"] <= 16]
    min_reduction = min(c["flop_waste_reduction"] for c in decode_cells)

    out = {
        "backend": backend,
        "platform": jax.default_backend(),
        "fast": fast,
        "shapes": shapes,
        "serve": serve,
        "serve_continuous": serve_continuous,
        "serve_paged": serve_paged,
        "serve_fidelity": serve_fidelity,
        "serve_frontend": serve_frontend,
        "min_decode_flop_waste_reduction": min_reduction,
        "claim_waste_reduction_ge_8x": bool(min_reduction >= 8.0),
        "claim_device_loop_single_transfer":
            serve["claim_device_loop_single_transfer"],
        "claim_loops_token_identical": serve["tokens_identical"],
        "claim_continuous_beats_bucket_tokps":
            serve_continuous["claim_continuous_beats_bucket_tokps"],
        "claim_continuous_beats_bucket_p99":
            serve_continuous["claim_continuous_beats_bucket_p99"],
        "claim_continuous_tokens_identical":
            serve_continuous["claim_continuous_tokens_identical"],
        "claim_chunk_transfer_accounting":
            serve_continuous["claim_chunk_transfer_accounting"],
        "claim_paged_tokens_identical":
            serve_paged["claim_paged_tokens_identical"],
        "claim_paged_kv_bytes_2x":
            serve_paged["claim_paged_kv_bytes_2x"],
        "claim_paged_prefix_hits":
            serve_paged["claim_paged_prefix_hits"],
        "claim_paged_fused_tokens_identical":
            serve_paged["claim_paged_fused_tokens_identical"],
        "claim_paged_fused_beats_gather":
            serve_paged["claim_paged_fused_beats_gather"],
        "claim_paged_fused_hbm_lt_gather":
            serve_paged["claim_paged_fused_hbm_lt_gather"],
        "claim_fidelity_accuracy_within_bound":
            serve_fidelity["claim_fidelity_accuracy_within_bound"],
        "claim_fidelity_degrades_without_scrub":
            serve_fidelity["claim_fidelity_degrades_without_scrub"],
        "claim_fidelity_scrub_repairs":
            serve_fidelity["claim_fidelity_scrub_repairs"],
        "claim_fidelity_transfer_accounting":
            serve_fidelity["claim_fidelity_transfer_accounting"],
        "claim_frontend_tokens_identical":
            serve_frontend["claim_frontend_tokens_identical"],
        "claim_frontend_backpressure_bounded":
            serve_frontend["claim_frontend_backpressure_bounded"],
        "claim_frontend_goodput_under_overload":
            serve_frontend["claim_frontend_goodput_under_overload"],
        "claim_frontend_transfer_accounting":
            serve_frontend["claim_frontend_transfer_accounting"],
    }
    if verbose:
        print(f"  {len(shapes)} shape cells ({backend} backend); decode "
              f"padded-FLOP waste reduction >= {min_reduction:.1f}x "
              f"(claim >= 8x: {out['claim_waste_reduction_ge_8x']})")
        d0 = decode_cells[0]
        print(f"  e.g. M={d0['m']}: blocks {d0['blocks_fixed']} -> "
              f"{d0['blocks_adaptive']}, waste {d0['flop_waste_fixed']:.0f}x"
              f" -> {d0['flop_waste_adaptive']:.0f}x, step "
              f"{d0.get('step_time_s', float('nan'))*1e3:.2f}ms")
        print(f"  serve loop: device {serve['device_loop']['tok_per_s']} "
              f"tok/s / {serve['device_loop']['host_transfers']} transfers"
              f" vs legacy {serve['legacy_loop']['tok_per_s']} tok/s / "
              f"{serve['legacy_loop']['host_transfers']} transfers "
              f"(tokens identical: {serve['tokens_identical']})")
        sc, sb = serve_continuous["continuous"], serve_continuous["bucket"]
        print(f"  continuous: {sc['tok_per_s']} tok/s p99 {sc['p99_s']}s "
              f"occ {sc['slot_occupancy']} vs bucket {sb['tok_per_s']} "
              f"tok/s p99 {sb['p99_s']}s (tokens identical: "
              f"{serve_continuous['claim_continuous_tokens_identical']}, "
              f"transfers==chunks: "
              f"{serve_continuous['claim_chunk_transfer_accounting']})")
        sp = serve_paged
        print(f"  paged KV: {sp['kv_bytes_dense']/1e3:.0f}kB dense -> "
              f"{sp['kv_bytes_paged_peak']/1e3:.0f}kB peak resident "
              f"({sp['kv_bytes_reduction']}x, >= 2x: "
              f"{sp['claim_paged_kv_bytes_2x']}), prefix hit rate "
              f"{sp['prefix_hit_rate']}, {sp['tok_per_s_paged']} tok/s "
              f"vs dense {sp['tok_per_s_dense']} (tokens identical: "
              f"{sp['claim_paged_tokens_identical']})")
        print(f"  fused read: {sp['tok_per_s_paged_fused']} tok/s vs "
              f"gather {sp['tok_per_s_paged_gather']}; chunk bytes "
              f"{sp['hbm_bytes_chunk_fused']/1e6:.1f}MB vs "
              f"{sp['hbm_bytes_chunk_gather']/1e6:.1f}MB "
              f"({sp['hbm_bytes_reduction']}x, beats gather on "
              f"{sp['fused_claim_basis'].split()[0]}: "
              f"{sp['claim_paged_fused_beats_gather']}; tokens "
              f"identical: {sp['claim_paged_fused_tokens_identical']})")
        sf = serve_fidelity
        print(f"  fidelity: acc {sf['acc_exact']:.3f} exact -> "
              f"{sf['acc_device']:.3f} device (drop {sf['acc_drop']:.3f}"
              f" <= {sf['acc_drop_max']}: "
              f"{sf['claim_fidelity_accuracy_within_bound']}); trit err "
              f"{sf['err_no_scrub']:.4f} unscrubbed vs "
              f"{sf['err_with_scrub']:.4f} scrubbed (degrades: "
              f"{sf['claim_fidelity_degrades_without_scrub']}, repairs: "
              f"{sf['claim_fidelity_scrub_repairs']}); "
              f"{sf['tok_per_s_device']} tok/s device vs "
              f"{sf['tok_per_s_exact']} exact, "
              f"{sf['scrub_energy_j']*1e9:.2f}nJ scrub energy")
        sfr = serve_frontend
        print(f"  frontend: {sfr['tok_per_s_frontend']} tok/s vs "
              f"direct {sfr['tok_per_s_direct']} (tokens identical: "
              f"{sfr['claim_frontend_tokens_identical']}, "
              f"transfers==chunks: "
              f"{sfr['claim_frontend_transfer_accounting']}); "
              f"overload goodput slo {sfr['tok_per_s_goodput_slo']} "
              f"vs fifo {sfr['tok_per_s_goodput_fifo']} tok/s "
              f"(deadlines met {sfr['deadline_met_slo']} vs "
              f"{sfr['deadline_met_fifo']} of {sfr['deadline_total']}, "
              f"shed {sfr['shed_slo']}; beats fifo: "
              f"{sfr['claim_frontend_goodput_under_overload']}); "
              f"backpressure bounded: "
              f"{sfr['claim_frontend_backpressure_bounded']}")
    if write_root:
        save_bench_json("wallclock", out)
    else:
        from .common import save_json
        save_json("wallclock", out)
    return out


if __name__ == "__main__":
    run()
