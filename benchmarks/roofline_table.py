"""§Roofline — aggregate the dry-run JSONs into the per-cell roofline
table (terms in ms, dominant bottleneck, useful-flops ratio, roofline
fraction) and emit markdown for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| bottleneck | useful | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|")


def load_cells(mesh: str | None = None, include_tagged: bool = False):
    cells = []
    if not os.path.isdir(DRYRUN_DIR):
        return cells
    for name in sorted(os.listdir(DRYRUN_DIR)):
        if not name.endswith(".json"):
            continue
        parts = name[:-5].split("__")
        if not include_tagged and len(parts) > 3:
            continue                      # perf-iteration snapshots
        with open(os.path.join(DRYRUN_DIR, name)) as f:
            d = json.load(f)
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def row(d: dict) -> str:
    if "skipped" in d:
        return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | "
                f"skipped | — | — |")
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{d['t_compute']*1e3:.1f} | {d['t_memory']*1e3:.1f} | "
            f"{d['t_collective']*1e3:.1f} | {d['bottleneck']} | "
            f"{d['useful_ratio']:.2f} | {d['peak_fraction']:.3f} |")


def run(verbose=True, mesh="16x16") -> dict:
    cells = load_cells(mesh)
    lines = [HEADER] + [row(c) for c in cells]
    table = "\n".join(lines)
    ran = [c for c in cells if "skipped" not in c]
    skipped = [c for c in cells if "skipped" in c]
    by_bottleneck = {}
    for c in ran:
        by_bottleneck.setdefault(c["bottleneck"], []).append(
            f"{c['arch']}/{c['shape']}")
    worst = sorted(ran, key=lambda c: c["peak_fraction"])[:5]
    most_coll = sorted(ran, key=lambda c: -c["t_collective"] /
                       max(c["t_compute"] + c["t_memory"], 1e-12))[:5]
    out = {
        "mesh": mesh,
        "cells_ran": len(ran),
        "cells_skipped": len(skipped),
        "bottleneck_histogram": {k: len(v) for k, v in
                                 by_bottleneck.items()},
        "worst_roofline_fraction": [
            {"cell": f"{c['arch']}/{c['shape']}",
             "frac": c["peak_fraction"]} for c in worst],
        "most_collective_bound": [
            {"cell": f"{c['arch']}/{c['shape']}",
             "coll_ms": c["t_collective"] * 1e3} for c in most_coll],
        "table_markdown": table,
    }
    if verbose:
        print(f"  {len(ran)} cells ran, {len(skipped)} skipped "
              f"({mesh}); bottlenecks: {out['bottleneck_histogram']}")
        for w in out["worst_roofline_fraction"][:3]:
            print(f"  worst roofline: {w['cell']} frac={w['frac']:.3f}")
    from .common import save_json
    save_json(f"roofline_table_{mesh}", out)
    return out


if __name__ == "__main__":
    run()
    run(mesh="2x16x16")
