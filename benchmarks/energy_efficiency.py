"""Fig. 9(b) — inference energy efficiency of TL-nvSRAM-CIM vs the four
baselines on ResNet-18 and VGG-9 (paper: 2.5x/2.9x vs b1, 1.7x/1.9x vs
b2, 2.0x vs b3, 1.2x vs b4; 1.15x vs b4 at equal CIM energy)."""
from __future__ import annotations

import dataclasses

from repro.core.energy import C, EnergyConstants, efficiency_ratios, \
    inference_energy
from repro.core.mapping import resnet18_cifar, vgg9_cifar

from .common import save_json


def run(verbose=True) -> dict:
    out = {"paper_ref": "Fig. 9(b)"}
    claims = {
        "resnet18": {"sram_dram": (2.3, 3.1), "sram_reram": (1.5, 2.1),
                     "reram_cim": (1.7, 2.3), "sl": (1.05, 1.45)},
        "vgg9": {"sram_dram": (2.3, 3.1), "sram_reram": (1.5, 2.1),
                 "reram_cim": (1.7, 2.3), "sl": (1.05, 1.45)},
    }
    all_ok = True
    for name, layers in (("resnet18", resnet18_cifar()),
                         ("vgg9", vgg9_cifar())):
        ratios = efficiency_ratios(layers)
        ok = {b: claims[name][b][0] <= r <= claims[name][b][1]
              for b, r in ratios.items()}
        all_ok &= all(ok.values())
        out[name] = {"ratios": {k: float(v) for k, v in ratios.items()},
                     "in_paper_band": ok}
        if verbose:
            print(f"  {name}: " + "  ".join(
                f"{b}={r:.2f}x{'' if ok[b] else ' (!)'}"
                for b, r in ratios.items()))

    # equal-CIM-energy scenario: TL still 1.15x vs SL
    c_eq = dataclasses.replace(C, e_cbl_tl_cim=C.e_col_sram_cim)
    layers = resnet18_cifar()
    tl = inference_energy(layers, "tl", c=c_eq).total
    sl = inference_energy(layers, "sl", c=c_eq).total
    out["equal_cim_energy_vs_sl"] = float(sl / tl)
    out["claim_1p15x_equal_cim_energy"] = bool(1.05 <= sl / tl <= 1.3)
    out["all_claims_in_band"] = bool(all_ok)
    if verbose:
        print(f"  equal-CIM-energy vs SL: {sl/tl:.2f}x (paper 1.15x)")
    save_json("energy_efficiency", out)
    return out


if __name__ == "__main__":
    run()
