"""Model registry: every assigned architecture as a composable model.

A model instance exposes a uniform interface used by train/, serve/ and
launch/dryrun:

  param_defs             — pytree of ParamDef (shapes + logical axes)
  forward(p, batch)      — full-sequence logits (training / eval)
  cache_defs(B, cap)     — pytree of ParamDef for the decode state
  prefill(p, batch, cap) — consume a prompt, return (last_logits, state)
  decode(p, token, st)   — one-token step against the state

All stacks scan over layers (params carry a leading L axis) so HLO size
is O(1) in depth — a hard requirement for 100-layer dry-run compiles.
Every weight matmul routes through layers.dense() and therefore through
the paper's CIM execution modes (float | ternary packed | macro-exact).

Families:
  TransformerLM  — dense / moe / vlm (cross-attn every k-th layer)
  EncDecModel    — whisper (stub frame embeddings -> enc; dec self+cross)
  XLSTMModel     — alternating mLSTM/sLSTM pairs
  ZambaModel     — Mamba2 backbone + ONE shared (tied) attention block
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .config import ModelConfig, ParamDef, init_params, is_def
from . import layers as layers_mod
from .layers import (attn_defs, dense, gelu_mlp, mlp_defs, norm_def, rms_norm,
                     sinusoidal_positions, swiglu)


# =====================================================================
# helpers
# =====================================================================

def _embed_defs(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab
    # the lookup table uses 'vocab_in' (never sharded over 'model'):
    # gathering from a vocab-sharded table forces SPMD into a full
    # rematerialization (all-gather of the whole table); keeping vocab
    # replicated and sharding the embed dim over 'data' (FSDP) keeps the
    # gather local.  The unembed projection stays TP over 'vocab'.
    return {
        "embed": ParamDef((v, cfg.d_model), ("vocab_in", "embed"), "embed"),
        "unembed": ParamDef((cfg.d_model, v), ("embed", "vocab")),
        "final_norm": norm_def(cfg),
    }


def _take_embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    from repro.dist.sharding import constrain_act
    return constrain_act(jnp.take(table, tokens, axis=0))


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _slice_tree(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


class BaseModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.param_defs = self._param_defs()

    # --- overridables -------------------------------------------------
    def _param_defs(self) -> Any:
        raise NotImplementedError

    def forward(self, params, batch: dict, cim=None, return_aux: bool = False):
        raise NotImplementedError

    def cache_defs(self, batch: int, capacity: int) -> Any:
        raise NotImplementedError

    def prefill(self, params, batch: dict, capacity: int, cim=None):
        raise NotImplementedError

    def decode(self, params, token: jax.Array, state: Any, cim=None):
        raise NotImplementedError

    # --- paged KV (serve.PagedScheduler) ------------------------------
    @property
    def supports_paged_kv(self) -> bool:
        """Whether decode can run against the paged KV block pool
        (models/paged_kv.py).  Families whose decode state is not a
        plain per-position KV cache (SSM carries, tied cross caches)
        serve from the dense slot pool."""
        return False

    def decode_paged(self, params, token, pool, page_table, pos,
                     cim=None):
        """Read-only one-token decode against a gathered page view:
        returns (logits, k_new, v_new) — the cache write is the
        scheduler's page scatter (paged_kv.append_tokens)."""
        raise NotImplementedError(
            f"paged KV decode is not implemented for "
            f"{type(self).__name__} (family {self.cfg.family!r}); "
            f"serve it from the dense slot pool")

    def decode_paged_fused(self, params, tokens, pool, page_table, pos,
                           cim=None, attn_plan=None):
        """Batched one-token decode consuming the page pool in place
        through a planned ``op='attention'`` executor — no gathered
        dense copy.  Same read-only contract as :meth:`decode_paged`,
        but over ALL slots at once: ``tokens (S,)``, ``page_table
        (S, W)``, ``pos (S,)``; returns (logits (S, 1, V), kts
        (L, S, KV, hd), vts)."""
        raise NotImplementedError(
            f"fused paged decode is not implemented for "
            f"{type(self).__name__} (family {self.cfg.family!r}); "
            f"serve it through the slot_view gather path")

    # --- common -------------------------------------------------------
    def init(self, key: jax.Array, dtype=None):
        return init_params(key, self.param_defs, dtype or self.cfg.dtype)

    def init_cache(self, batch: int, capacity: int):
        defs = self.cache_defs(batch, capacity)

        def mk(d: ParamDef):
            dt = d.dtype or self.cfg.dtype
            if d.init == "ones":
                return jnp.ones(d.shape, dt)
            return jnp.zeros(d.shape, dt)
        return jax.tree.map(mk, defs, is_leaf=is_def)

    def loss(self, params, batch: dict, cim=None) -> jax.Array:
        """Mean next-token cross-entropy (+ MoE load-balance aux loss)."""
        logits, aux = self.forward(params, batch, cim=cim, return_aux=True)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
        return ce + aux


# =====================================================================
# TransformerLM — dense / moe / vlm
# =====================================================================

class TransformerLM(BaseModel):
    """Decoder-only transformer.  MoE when cfg.num_experts > 0; gated
    cross-attention blocks every cfg.cross_attn_every layers (vlm)."""

    def _block_defs(self, L: int) -> dict:
        cfg = self.cfg
        d = {
            "ln1": norm_def(cfg, L),
            "ln2": norm_def(cfg, L),
            **attn_defs(cfg, L),
        }
        if cfg.num_experts:
            d.update(moe_mod.moe_defs(cfg, L))
        else:
            d.update(mlp_defs(cfg, L))
        return d

    def _param_defs(self):
        cfg = self.cfg
        p = {**_embed_defs(cfg), "blocks": self._block_defs(cfg.num_layers)}
        if cfg.cross_attn_every:
            n_cross = cfg.num_layers // cfg.cross_attn_every
            p["cross_blocks"] = {
                "ln": norm_def(cfg, n_cross),
                "gate": ParamDef((n_cross,), ("layers",), "zeros",
                                 jnp.float32),
                **attn_defs(cfg, n_cross, cross=True),
            }
        return p

    # ----- shared layer bodies ----------------------------------------
    def _mlp(self, x, wl, cim):
        cfg = self.cfg
        if cfg.num_experts:
            return moe_mod.moe_block(x, wl, cfg, cim)
        return swiglu(x, wl["w1"], wl["w3"], wl["w2"], cim), 0.0

    def _self_block(self, x, wl, cim, positions=None):
        cfg = self.cfg
        h = attn.self_attention(rms_norm(x, wl["ln1"], cfg.norm_eps), wl, cfg,
                                positions=positions, cim_cfg=cim)
        x = x + h
        m, aux = self._mlp(rms_norm(x, wl["ln2"], cfg.norm_eps), wl, cim)
        return x + m, aux

    def _cross_block(self, x, kv_src, wc, cim):
        cfg = self.cfg
        h = attn.cross_attention(rms_norm(x, wc["ln"], cfg.norm_eps), kv_src,
                                 wc, cfg, cim_cfg=cim)
        return x + jnp.tanh(wc["gate"]).astype(x.dtype) * h

    # ----- forward (train) --------------------------------------------
    def forward(self, params, batch, cim=None, return_aux: bool = False):
        cfg = self.cfg
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        aux = jnp.zeros((), jnp.float32)
        if cfg.cross_attn_every:
            x = self._forward_vlm(x, params, batch, cim)
        else:
            def body(carry, wl):
                x, aux = carry
                x, a = self._self_block(x, wl, cim)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, aux),
                                       params["blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["unembed"], cim)
        return (logits, aux) if return_aux else logits

    def _forward_vlm(self, x, params, batch, cim):
        cfg = self.cfg
        k = cfg.cross_attn_every
        ng = cfg.num_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["blocks"])
        patches = batch["patches"].astype(cfg.dtype)

        def group(x, wg):
            w_self, w_cross = wg
            inner = _maybe_remat(
                lambda x, wl: (self._self_block(x, wl, cim)[0], None), cfg)
            x, _ = jax.lax.scan(inner, x, w_self)
            x = self._cross_block(x, patches, w_cross, cim)
            return x, None

        x, _ = jax.lax.scan(group, x, (grouped, params["cross_blocks"]))
        return x

    # ----- serve --------------------------------------------------------
    def cache_defs(self, batch: int, capacity: int):
        cfg = self.cfg
        L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
        cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
        kvshape = (L, batch, cap, kv, hd)
        kvaxes = ("layers", "batch", "cache_seq", "kv", "none")
        kvdt = jnp.int8 if cfg.kv_cache_dtype == "int8" else None
        defs = {"k": ParamDef(kvshape, kvaxes, dtype=kvdt),
                "v": ParamDef(kvshape, kvaxes, dtype=kvdt),
                "pos": ParamDef((), (), "zeros", jnp.int32)}
        if cfg.kv_cache_dtype == "int8":
            saxes = ("layers", "batch", "cache_seq", "kv")
            defs["k_scale"] = ParamDef((L, batch, cap, kv), saxes, "zeros",
                                       jnp.float32)
            defs["v_scale"] = ParamDef((L, batch, cap, kv), saxes, "zeros",
                                       jnp.float32)
        if cfg.cross_attn_every:
            ng = cfg.num_layers // cfg.cross_attn_every
            # cross k/v computed once from patch embeddings at prefill
            p = (ng, batch, self.cfg.encoder_seq or 1024, kv, hd)
            pax = ("layers", "batch", "seq", "kv", "none")
            defs["xk"] = ParamDef(p, pax)
            defs["xv"] = ParamDef(p, pax)
        return defs

    def _scan_cached(self, x, params, state, step_fn, cim):
        """Scan over layers threading per-layer KV cache slices
        (prefill: the whole cache is legitimately materialized once)."""
        cfg = self.cfg
        if not cfg.cross_attn_every:
            def body(x, inp):
                wl, k_l, v_l = inp
                cache = attn.KVCache(k_l, v_l, state["pos"])
                x, newc = step_fn(x, wl, cache, None, cim)
                return x, (newc.k, newc.v)
            x, (ks, vs) = jax.lax.scan(
                body, x, (params["blocks"], state["k"], state["v"]))
            return x, ks, vs
        k = cfg.cross_attn_every
        ng = cfg.num_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["blocks"])
        kg = state["k"].reshape((ng, k) + state["k"].shape[1:])
        vg = state["v"].reshape((ng, k) + state["v"].shape[1:])

        def group(x, inp):
            wg, wc, k_g, v_g, xk_g, xv_g = inp

            def body(x, inner):
                wl, k_l, v_l = inner
                cache = attn.KVCache(k_l, v_l, state["pos"])
                x, newc = step_fn(x, wl, cache, None, cim)
                return x, (newc.k, newc.v)
            x, (ks, vs) = jax.lax.scan(body, x, (wg, k_g, v_g))
            h = attn._gqa_attend(
                attn.dense(rms_norm(x, wc["ln"], cfg.norm_eps), wc["wq"], cim)
                .reshape(x.shape[0], x.shape[1], cfg.num_heads, cfg.hd),
                xk_g, xv_g, None, cfg)
            h = dense(h, wc["wo"], cim, x_axes=layers_mod.ATTN_OUT)
            x = x + jnp.tanh(wc["gate"]).astype(x.dtype) * h
            return x, (ks, vs)

        x, (ks, vs) = jax.lax.scan(
            group, x, (grouped, params["cross_blocks"], kg, vg,
                       state["xk"], state["xv"]))
        ks = ks.reshape((ng * k,) + ks.shape[2:])
        vs = vs.reshape((ng * k,) + vs.shape[2:])
        return x, ks, vs

    def _precompute_cross(self, params, patches, cim):
        """Project patch embeddings to per-cross-layer K/V once."""
        cfg = self.cfg
        kv, hd = cfg.num_kv_heads, cfg.hd
        b, p, _ = patches.shape

        def one(wc):
            k = dense(patches.astype(cfg.dtype), wc["wk"], cim).reshape(
                b, p, kv, hd)
            v = dense(patches.astype(cfg.dtype), wc["wv"], cim).reshape(
                b, p, kv, hd)
            return k, v
        xk, xv = jax.lax.map(one, params["cross_blocks"])
        return xk, xv

    def prefill(self, params, batch, capacity: int, cim=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        state = self.init_cache(b, capacity)
        if cfg.cross_attn_every:
            state["xk"], state["xv"] = self._precompute_cross(
                params, batch["patches"], cim)
        x = _take_embed(params["embed"], tokens).astype(cfg.dtype)
        state["pos"] = jnp.zeros((), jnp.int32)

        def step(x, wl, cache, _, cim):
            xa = rms_norm(x, wl["ln1"], cfg.norm_eps)
            out, newc = attn.prefill_attention(xa, wl, cfg, cache, cim)
            x = x + out
            m, _ = self._mlp(rms_norm(x, wl["ln2"], cfg.norm_eps), wl, cim)
            return x + m, newc

        scratch = state
        if cfg.kv_cache_dtype == "int8":
            # prefill builds the cache in compute dtype, then quantizes
            z = jnp.zeros(state["k"].shape, cfg.dtype)
            scratch = dict(state, k=z, v=z)
        x, ks, vs = self._scan_cached(x, params, scratch, step, cim)
        if cfg.kv_cache_dtype == "int8":
            state["k"], state["k_scale"] = attn.quantize_kv(ks)
            state["v"], state["v_scale"] = attn.quantize_kv(vs)
        else:
            state["k"], state["v"] = ks, vs
        state["pos"] = jnp.asarray(s, jnp.int32)
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), state

    def _decode_read_scan(self, params, x, state, cim):
        """Read-only decode layer scan: attend over the (stale) cached
        KV + the fresh token, collect every layer's new k/v for ONE
        batched write.  ``state`` is either the dense cache dict or a
        paged gather-view (paged_kv.slot_view) — same layout, so the
        dense and paged decode paths share this graph bit-for-bit."""
        cfg = self.cfg
        int8_kv = cfg.kv_cache_dtype == "int8"

        def body(x, inp):
            if int8_kv:
                wl, k_l, v_l, ks_l, vs_l = inp
                cache = attn.KVCache(k_l, v_l, state["pos"], ks_l, vs_l)
            else:
                wl, k_l, v_l = inp
                cache = attn.KVCache(k_l, v_l, state["pos"])
            xa = rms_norm(x, wl["ln1"], cfg.norm_eps)
            out, kt, vt = attn.decode_attention_read(xa, wl, cfg, cache,
                                                     cim)
            x = x + out
            m, _ = self._mlp(rms_norm(x, wl["ln2"], cfg.norm_eps), wl, cim)
            return x + m, (kt, vt)

        xs = (params["blocks"], state["k"], state["v"])
        if int8_kv:
            xs = xs + (state["k_scale"], state["v_scale"])
        x, (kts, vts) = jax.lax.scan(body, x, xs)
        return x, kts, vts

    @property
    def supports_paged_kv(self) -> bool:
        # the vlm grouped path fuses its cache write into the layer
        # scan, and sliding-window models decode against a ROLLING
        # cache (slot = pos % window, engaged only when cap == window)
        # that a page-gathered view's capacity would silently disarm;
        # only the plain full-cache read-then-write decode pages cleanly
        return not self.cfg.cross_attn_every and \
            not self.cfg.sliding_window

    def decode_paged(self, params, token, pool, page_table, pos,
                     cim=None):
        """One-token decode against the paged page pool: gather the
        slot's page-table row into the dense cache layout
        (paged_kv.slot_view) and run the shared read-only scan.
        Returns (logits, kts (L, 1, 1, KV, hd), vts) in COMPUTE dtype —
        the scheduler scatters them into pages (and quantizes for
        int8-KV pools), mirroring ``decode``'s dense write."""
        if not self.supports_paged_kv:
            return super().decode_paged(params, token, pool, page_table,
                                        pos, cim)
        from . import paged_kv
        cfg = self.cfg
        view = paged_kv.slot_view(pool, page_table, pos)
        x = _take_embed(params["embed"], token).astype(cfg.dtype)
        x, kts, vts = self._decode_read_scan(params, x, view, cim)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), kts, vts

    def decode_paged_fused(self, params, tokens, pool, page_table, pos,
                           cim=None, attn_plan=None):
        """Batched one-token decode straight off the page pool: the
        layer scan carries each layer's raw page arrays and the planned
        attention executor (``attn_plan``, an ``op='attention'``
        ExecutionPlan) reads them through the page table in-kernel —
        the ``slot_view`` gather copy is never materialized.  Returns
        (logits (S, 1, V), kts (L, S, KV, hd), vts) in compute dtype;
        the scheduler's page scatter is unchanged."""
        if not self.supports_paged_kv:
            return super().decode_paged_fused(params, tokens, pool,
                                              page_table, pos, cim,
                                              attn_plan)
        if attn_plan is None:
            raise ValueError("decode_paged_fused needs a resolved "
                             "attention plan (PagedScheduler resolves "
                             "one per pool geometry)")
        from . import paged_kv
        cfg = self.cfg
        k_pages, v_pages = paged_kv.raw_pool_view(pool)
        x = _take_embed(params["embed"], tokens[:, None]).astype(cfg.dtype)

        def body(x, inp):
            wl, k_l, v_l = inp
            xa = rms_norm(x, wl["ln1"], cfg.norm_eps)
            out, kt, vt = attn.paged_decode_attention_read(
                xa, wl, cfg, k_l, v_l, page_table, pos, attn_plan, cim)
            x = x + out
            m, _ = self._mlp(rms_norm(x, wl["ln2"], cfg.norm_eps), wl,
                             cim)
            return x + m, (kt, vt)

        x, (kts, vts) = jax.lax.scan(body, x, (params["blocks"],
                                               k_pages, v_pages))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), kts, vts

    def decode(self, params, token, state, cim=None):
        cfg = self.cfg
        x = _take_embed(params["embed"], token).astype(cfg.dtype)

        if cfg.cross_attn_every:                 # vlm: grouped path
            def step(x, wl, cache, _, cim):
                xa = rms_norm(x, wl["ln1"], cfg.norm_eps)
                out, newc = attn.decode_attention(xa, wl, cfg, cache, cim)
                x = x + out
                m, _ = self._mlp(rms_norm(x, wl["ln2"], cfg.norm_eps), wl,
                                 cim)
                return x + m, newc

            x, ks, vs = self._scan_cached(x, params, state, step, cim)
            new_state = dict(state, k=ks, v=vs, pos=state["pos"] + 1)
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            return dense(x, params["unembed"], cim), new_state

        # read-only layer scan + ONE batched in-place cache write
        int8_kv = cfg.kv_cache_dtype == "int8"
        x, kts, vts = self._decode_read_scan(params, x, state, cim)
        cap = state["k"].shape[2]
        rolling = cfg.sliding_window and cap == cfg.sliding_window
        pos = state["pos"]
        slot = (pos % cap if rolling else pos).astype(jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        idx = (zero, zero, slot, zero, zero)
        new_state = dict(state, pos=pos + 1)
        if int8_kv:
            kq, ksc = attn.quantize_kv(kts)          # (L,B,1,kv,*) codes
            vq, vsc = attn.quantize_kv(vts)
            new_state["k"] = jax.lax.dynamic_update_slice(state["k"], kq,
                                                          idx)
            new_state["v"] = jax.lax.dynamic_update_slice(state["v"], vq,
                                                          idx)
            new_state["k_scale"] = jax.lax.dynamic_update_slice(
                state["k_scale"], ksc, idx[:-1])
            new_state["v_scale"] = jax.lax.dynamic_update_slice(
                state["v_scale"], vsc, idx[:-1])
        else:
            new_state["k"] = jax.lax.dynamic_update_slice(
                state["k"], kts.astype(state["k"].dtype), idx)
            new_state["v"] = jax.lax.dynamic_update_slice(
                state["v"], vts.astype(state["v"].dtype), idx)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), new_state


# =====================================================================
# EncDec — whisper backbone (conv frontend stubbed: frames are embeddings)
# =====================================================================

class EncDecModel(BaseModel):
    def _param_defs(self):
        cfg = self.cfg
        Le, Ld = cfg.encoder_layers, cfg.num_layers
        return {
            **_embed_defs(cfg),
            "enc_blocks": {"ln1": norm_def(cfg, Le), "ln2": norm_def(cfg, Le),
                           **attn_defs(cfg, Le), **mlp_defs(cfg, Le, gated=False)},
            "enc_norm": norm_def(cfg),
            "dec_blocks": {"ln1": norm_def(cfg, Ld), "ln2": norm_def(cfg, Ld),
                           "ln3": norm_def(cfg, Ld),
                           **attn_defs(cfg, Ld),
                           **{f"x_{k}": v for k, v in
                              attn_defs(cfg, Ld, cross=True).items()},
                           **mlp_defs(cfg, Ld, gated=False)},
        }

    def encode(self, params, frames, cim=None):
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cfg.dtype)

        def body(x, wl):
            h = attn.self_attention(rms_norm(x, wl["ln1"], cfg.norm_eps), wl,
                                    cfg, causal=False, cim_cfg=cim)
            x = x + h
            m = gelu_mlp(rms_norm(x, wl["ln2"], cfg.norm_eps),
                         wl["w1"], wl["w2"], cim)
            return x + m, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_blocks"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _dec_block(self, x, enc, wl, cim, cache=None, mode="train"):
        cfg = self.cfg
        xa = rms_norm(x, wl["ln1"], cfg.norm_eps)
        if mode == "train":
            h = attn.self_attention(xa, wl, cfg, cim_cfg=cim)
            newc = None
        elif mode == "prefill":
            h, newc = attn.prefill_attention(xa, wl, cfg, cache, cim)
        else:
            h, newc = attn.decode_attention(xa, wl, cfg, cache, cim)
        x = x + h
        wx = {k[2:]: v for k, v in wl.items() if k.startswith("x_")}
        h = attn.cross_attention(rms_norm(x, wl["ln2"], cfg.norm_eps), enc,
                                 wx, cfg, cim_cfg=cim)
        x = x + h
        m = gelu_mlp(rms_norm(x, wl["ln3"], cfg.norm_eps), wl["w1"], wl["w2"],
                     cim)
        return x + m, newc

    def forward(self, params, batch, cim=None, return_aux: bool = False):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], cim)
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        body = _maybe_remat(
            lambda x, wl: (self._dec_block(x, enc, wl, cim)[0], None), cfg)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["unembed"], cim)
        return (logits, jnp.zeros((), jnp.float32)) if return_aux else logits

    def cache_defs(self, batch: int, capacity: int):
        cfg = self.cfg
        L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
        enc_s = cfg.encoder_seq
        kvshape = (L, batch, capacity, kv, hd)
        kvaxes = ("layers", "batch", "cache_seq", "kv", "none")
        xshape = (L, batch, enc_s, kv, hd)
        xaxes = ("layers", "batch", "seq", "kv", "none")
        return {"k": ParamDef(kvshape, kvaxes), "v": ParamDef(kvshape, kvaxes),
                "xk": ParamDef(xshape, xaxes), "xv": ParamDef(xshape, xaxes),
                "pos": ParamDef((), (), "zeros", jnp.int32)}

    def _cross_kv(self, params, enc, cim):
        cfg = self.cfg
        b, t, _ = enc.shape
        kv, hd = cfg.num_kv_heads, cfg.hd

        def one(wl):
            k = dense(enc, wl["x_wk"], cim).reshape(b, t, kv, hd)
            v = dense(enc, wl["x_wv"], cim).reshape(b, t, kv, hd)
            return k, v
        return jax.lax.map(one, params["dec_blocks"])

    def _run_dec(self, params, x, state, mode, cim):
        cfg = self.cfg

        def body(x, inp):
            wl, k_l, v_l, xk_l, xv_l = inp
            cache = attn.KVCache(k_l, v_l, state["pos"])
            wx = {k[2:]: v for k, v in wl.items() if k.startswith("x_")}
            xa = rms_norm(x, wl["ln1"], cfg.norm_eps)
            if mode == "prefill":
                h, newc = attn.prefill_attention(xa, wl, cfg, cache, cim)
            else:
                h, newc = attn.decode_attention(xa, wl, cfg, cache, cim)
            x = x + h
            # cross-attn against precomputed enc K/V
            q = dense(rms_norm(x, wl["ln2"], cfg.norm_eps), wx["wq"], cim)
            q = q.reshape(x.shape[0], x.shape[1], cfg.num_heads, cfg.hd)
            h = attn._gqa_attend(q, xk_l, xv_l, None, cfg)
            x = x + dense(h, wx["wo"], cim, x_axes=layers_mod.ATTN_OUT)
            m = gelu_mlp(rms_norm(x, wl["ln3"], cfg.norm_eps), wl["w1"],
                         wl["w2"], cim)
            return x + m, (newc.k, newc.v)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], state["k"], state["v"],
                      state["xk"], state["xv"]))
        return x, ks, vs

    def prefill(self, params, batch, capacity: int, cim=None):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        enc = self.encode(params, batch["frames"], cim)
        state = self.init_cache(b, capacity)
        state["xk"], state["xv"] = self._cross_kv(params, enc, cim)
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        x, ks, vs = self._run_dec(params, x, state, "prefill", cim)
        state["k"], state["v"] = ks, vs
        state["pos"] = jnp.asarray(s, jnp.int32)
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), state

    def decode(self, params, token, state, cim=None):
        cfg = self.cfg
        x = _take_embed(params["embed"], token).astype(cfg.dtype)
        x, ks, vs = self._run_dec(params, x, state, "decode", cim)
        new_state = dict(state, k=ks, v=vs, pos=state["pos"] + 1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), new_state


# =====================================================================
# xLSTM — alternating (mLSTM, sLSTM) pairs
# =====================================================================

class XLSTMModel(BaseModel):
    @property
    def n_pairs(self) -> int:
        return self.cfg.num_layers // 2

    def _param_defs(self):
        cfg = self.cfg
        n = self.n_pairs
        return {
            **_embed_defs(cfg),
            "m_ln": norm_def(cfg, n),
            "s_ln": norm_def(cfg, n),
            "mlstm": ssm.mlstm_defs(cfg, n),
            "slstm": ssm.slstm_defs(cfg, n),
        }

    def _pair(self, x, wl, cim, m_state=None, s_state=None):
        cfg = self.cfg
        wm, ws, lm, ls = wl["mlstm"], wl["slstm"], wl["m_ln"], wl["s_ln"]
        h, new_m = ssm.mlstm_block(rms_norm(x, lm, cfg.norm_eps), wm, cfg,
                                   m_state, cim)
        x = x + h
        h, new_s = ssm.slstm_block(rms_norm(x, ls, cfg.norm_eps), ws, cfg,
                                   s_state, cim)
        return x + h, new_m, new_s

    def forward(self, params, batch, cim=None, return_aux: bool = False):
        cfg = self.cfg
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        stack = {"mlstm": params["mlstm"], "slstm": params["slstm"],
                 "m_ln": params["m_ln"], "s_ln": params["s_ln"]}
        body = _maybe_remat(
            lambda x, wl: (self._pair(x, wl, cim)[0], None), cfg)
        x, _ = jax.lax.scan(body, x, stack)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["unembed"], cim)
        return (logits, jnp.zeros((), jnp.float32)) if return_aux else logits

    def cache_defs(self, batch: int, capacity: int):
        cfg = self.cfg
        n = self.n_pairs
        d_up, heads, hd = ssm.xlstm_dims(cfg)
        sh, shd = cfg.num_heads, cfg.d_model // cfg.num_heads
        f32 = jnp.float32
        ax4 = ("layers", "batch", "heads", "none", "none")
        ax3 = ("layers", "batch", "heads", "none")
        ax2 = ("layers", "batch", "heads")
        return {
            "m_C": ParamDef((n, batch, heads, hd, hd), ax4, "zeros", f32),
            "m_n": ParamDef((n, batch, heads, hd), ax3, "zeros", f32),
            "m_m": ParamDef((n, batch, heads), ax2, "zeros", f32),
            "s_c": ParamDef((n, batch, sh, shd), ax3, "zeros", f32),
            "s_n": ParamDef((n, batch, sh, shd), ax3, "ones", f32),
            "s_m": ParamDef((n, batch, sh), ax2, "zeros", f32),
            "s_h": ParamDef((n, batch, sh, shd), ax3, "zeros", f32),
            "pos": ParamDef((), (), "zeros", jnp.int32),
        }

    def _scan_pairs(self, params, x, state, cim, use_state: bool):
        stack = {"mlstm": params["mlstm"], "slstm": params["slstm"],
                 "m_ln": params["m_ln"], "s_ln": params["s_ln"]}

        def body(x, inp):
            wl, st = inp
            if use_state:
                m_st = ssm.XLSTMState(st["m_C"], st["m_n"], st["m_m"],
                                      jnp.zeros_like(st["s_h"][..., :0]),
                                      state["pos"])
                s_st = ssm.XLSTMState(st["s_c"][..., None], st["s_n"],
                                      st["s_m"], st["s_h"], state["pos"])
            else:
                m_st = s_st = None
            x, new_m, new_s = self._pair(x, wl, cim, m_st, s_st)
            out = {"m_C": new_m.C, "m_n": new_m.n, "m_m": new_m.m,
                   "s_c": new_s.C[..., 0], "s_n": new_s.n, "s_m": new_s.m,
                   "s_h": new_s.h}
            return x, out

        st_in = {k: state[k] for k in
                 ("m_C", "m_n", "m_m", "s_c", "s_n", "s_m", "s_h")}
        x, st_out = jax.lax.scan(body, x, (stack, st_in))
        return x, st_out

    def prefill(self, params, batch, capacity: int, cim=None):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        state = self.init_cache(b, capacity)
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        x, st = self._scan_pairs(params, x, state, cim, use_state=False)
        state.update(st)
        state["pos"] = jnp.asarray(s, jnp.int32)
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), state

    def decode(self, params, token, state, cim=None):
        cfg = self.cfg
        x = _take_embed(params["embed"], token).astype(cfg.dtype)
        x, st = self._scan_pairs(params, x, state, cim, use_state=True)
        new_state = dict(state, **st, pos=state["pos"] + 1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), new_state


# =====================================================================
# Zamba2 — Mamba2 backbone + one SHARED attention block every k layers
# =====================================================================

class ZambaModel(BaseModel):
    """cfg.num_layers Mamba2 layers; after every cfg.attn_every of them the
    single shared (weight-tied) attention block runs — tied weights, but a
    separate KV cache per invocation."""

    @property
    def n_groups(self) -> int:
        return self.cfg.num_layers // self.cfg.attn_every

    @property
    def n_tail(self) -> int:
        return self.cfg.num_layers - self.n_groups * self.cfg.attn_every

    def _param_defs(self):
        cfg = self.cfg
        L = cfg.num_layers
        return {
            **_embed_defs(cfg),
            "mamba_ln": norm_def(cfg, L),
            "mamba": ssm.mamba2_defs(cfg, L),
            "shared_ln": norm_def(cfg),
            "shared_attn": {k: ParamDef(v.shape[1:], v.axes[1:], v.init,
                                        v.dtype)
                            for k, v in attn_defs(cfg, 1).items()},
            # Zamba2's shared block is attention + MLP (both weight-tied);
            # d_ff comes from the assigned config (14336 for zamba2-7b).
            "shared_mlp_ln": norm_def(cfg),
            "shared_mlp": {k: ParamDef(v.shape[1:], v.axes[1:], v.init,
                                       v.dtype)
                           for k, v in mlp_defs(cfg, 1).items()},
        }

    def _shared_mlp(self, x, params, cim):
        cfg = self.cfg
        wm = params["shared_mlp"]
        return swiglu(rms_norm(x, params["shared_mlp_ln"], cfg.norm_eps),
                      wm["w1"], wm["w3"], wm["w2"], cim)

    def _mamba_scan(self, x, stack, cim, states=None):
        cfg = self.cfg

        def body(x, inp):
            if states is None:
                wl, ln = inp
                st = None
            else:
                wl, ln, st = inp
            h, new_st = ssm.mamba2_block(rms_norm(x, ln, cfg.norm_eps), wl,
                                         cfg, st, cim)
            out = None if states is None else new_st
            return x + h, out
        xs = (stack["mamba"], stack["mamba_ln"]) if states is None else (
            stack["mamba"], stack["mamba_ln"], states)
        return jax.lax.scan(_maybe_remat(body, cfg) if states is None
                            else body, x, xs)

    def _grouped(self, params):
        cfg = self.cfg
        k, ng = cfg.attn_every, self.n_groups
        head = jax.tree.map(lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]),
                            {"mamba": params["mamba"],
                             "mamba_ln": params["mamba_ln"]})
        tail = jax.tree.map(lambda a: a[ng * k:],
                            {"mamba": params["mamba"],
                             "mamba_ln": params["mamba_ln"]})
        return head, tail

    def forward(self, params, batch, cim=None, return_aux: bool = False):
        cfg = self.cfg
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        head, tail = self._grouped(params)
        shared = params["shared_attn"]

        def group(x, wg):
            x, _ = self._mamba_scan(x, wg, cim)
            h = attn.self_attention(
                rms_norm(x, params["shared_ln"], cfg.norm_eps), shared, cfg,
                cim_cfg=cim)
            x = x + h
            return x + self._shared_mlp(x, params, cim), None

        x, _ = jax.lax.scan(group, x, head)
        if self.n_tail:
            x, _ = self._mamba_scan(x, tail, cim)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["unembed"], cim)
        return (logits, jnp.zeros((), jnp.float32)) if return_aux else logits

    def cache_defs(self, batch: int, capacity: int):
        cfg = self.cfg
        L, ng = cfg.num_layers, self.n_groups
        d_inner, heads, hd, st, groups, conv_dim = ssm.mamba2_dims(cfg)
        kv, ahd = cfg.num_kv_heads, cfg.hd
        f32 = jnp.float32
        return {
            "h": ParamDef((L, batch, heads, hd, st),
                          ("layers", "batch", "heads", "none", "none"),
                          "zeros", f32),
            "conv": ParamDef((L, batch, 3, conv_dim),
                             ("layers", "batch", "none", "inner"), "zeros"),
            "k": ParamDef((ng, batch, capacity, kv, ahd),
                          ("layers", "batch", "cache_seq", "kv", "none")),
            "v": ParamDef((ng, batch, capacity, kv, ahd),
                          ("layers", "batch", "cache_seq", "kv", "none")),
            "pos": ParamDef((), (), "zeros", jnp.int32),
        }

    def _run(self, params, x, state, mode, cim):
        cfg = self.cfg
        k, ng = cfg.attn_every, self.n_groups
        head, tail = self._grouped(params)
        shared = params["shared_attn"]
        # broadcast the scalar position over the layer axis so the state
        # pytree slices uniformly through the grouped scans
        L = cfg.num_layers
        mamba_states = ssm.Mamba2State(
            state["h"], state["conv"],
            jnp.broadcast_to(state["pos"], (L,)))
        head_states = jax.tree.map(
            lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]),
            mamba_states)
        tail_states = jax.tree.map(lambda a: a[ng * k:], mamba_states)

        def group(x, inp):
            wg, sg, k_l, v_l = inp
            x, new_sg = self._mamba_scan(x, wg, cim, states=sg)
            cache = attn.KVCache(k_l, v_l, state["pos"])
            xa = rms_norm(x, params["shared_ln"], cfg.norm_eps)
            if mode == "prefill":
                h, newc = attn.prefill_attention(xa, shared, cfg, cache, cim)
            else:
                h, newc = attn.decode_attention(xa, shared, cfg, cache, cim)
            x = x + h
            x = x + self._shared_mlp(x, params, cim)
            return x, (new_sg, newc.k, newc.v)

        x, (new_head, ks, vs) = jax.lax.scan(
            group, x, (head, head_states, state["k"], state["v"]))
        if self.n_tail:
            x, new_tail = self._mamba_scan(x, tail, cim, states=tail_states)
        else:
            new_tail = tail_states
        flat_head = jax.tree.map(
            lambda a: a.reshape((ng * k,) + a.shape[2:]), new_head)
        merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                              flat_head, new_tail)
        return x, merged, ks, vs

    def prefill(self, params, batch, capacity: int, cim=None):
        cfg = self.cfg
        b, s = batch["tokens"].shape
        state = self.init_cache(b, capacity)
        x = _take_embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
        x, mstates, ks, vs = self._run(params, x, state, "prefill", cim)
        state.update(h=mstates.h, conv=mstates.conv, k=ks, v=vs,
                     pos=jnp.asarray(s, jnp.int32))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), state

    def decode(self, params, token, state, cim=None):
        cfg = self.cfg
        x = _take_embed(params["embed"], token).astype(cfg.dtype)
        x, mstates, ks, vs = self._run(params, x, state, "decode", cim)
        new_state = dict(state, h=mstates.h, conv=mstates.conv, k=ks, v=vs,
                         pos=state["pos"] + 1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["unembed"], cim), new_state


# =====================================================================

@functools.lru_cache(maxsize=None)
def build(cfg: ModelConfig) -> BaseModel:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if fam == "audio":
        return EncDecModel(cfg)
    if fam == "ssm" and cfg.ssm_kind == "xlstm":
        return XLSTMModel(cfg)
    if fam == "hybrid":
        return ZambaModel(cfg)
    raise ValueError(f"unknown family {fam!r} / ssm_kind {cfg.ssm_kind!r}")
