"""Grouped-query attention: training, prefill, decode (full/SWA/cross).

Layout: q (B,S,H,hd), kv (B,T,KV,hd).  GQA is computed with grouped
einsums (no materialized head repetition).  Decode updates a KV cache via
dynamic_update_slice; sliding-window decode uses a rolling buffer of size
`window` so the long_500k cell stays O(window) in memory for SWA archs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import ATTN_OUT, apply_rope, dense, rms_norm


class KVCache(NamedTuple):
    k: jax.Array          # (B, C, KV, hd);  C = max_seq or window
    v: jax.Array
    index: jax.Array      # scalar int32 — next write position (absolute)
    # int8-KV mode (beyond-paper: the paper's dense-storage/restore idea
    # applied to activations): k/v are int8, scales are per-(B, C, KV)
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, S, KV, hd) -> (int8 codes, (B, S, KV) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def init_cache(batch: int, capacity: int, kv_heads: int, hd: int,
               dtype=jnp.bfloat16, kv_cache_dtype: str = "") -> KVCache:
    """Zero-initialized KV cache.  ``kv_cache_dtype='int8'`` allocates
    the int8 code buffers AND their per-(B, C, KV) f32 scale buffers up
    front — ``registry`` gates its int8 read path on the scales being
    present, so a cache built without them would fail mid-decode."""
    shape = (batch, capacity, kv_heads, hd)
    if kv_cache_dtype == "int8":
        # distinct buffers: k/v scale leaves are donated independently
        return KVCache(jnp.zeros(shape, jnp.int8),
                       jnp.zeros(shape, jnp.int8),
                       jnp.zeros((), jnp.int32),
                       jnp.zeros(shape[:-1], jnp.float32),
                       jnp.zeros(shape[:-1], jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def _project_qkv(x, w, cfg: ModelConfig, x_kv=None, positions=None,
                 rope: bool = True, cim_cfg=None):
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    src = x if x_kv is None else x_kv
    q = dense(x, w["wq"], cim_cfg).reshape(b, s, h, hd)
    k = dense(src, w["wk"], cim_cfg).reshape(b, src.shape[1], kv, hd)
    v = dense(src, w["wv"], cim_cfg).reshape(b, src.shape[1], kv, hd)
    if cfg.qk_norm and "q_norm" in w:
        q = rms_norm(q, w["q_norm"], cfg.norm_eps)
        k = rms_norm(k, w["k_norm"], cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        kpos = positions if x_kv is None else jnp.arange(src.shape[1])
        k = apply_rope(k, kpos, cfg.rope_theta)
    return q, k, v


def _gqa_attend(q, k, v, mask, cfg: ModelConfig):
    """q (B,S,H,hd) x k/v (B,T,KV,hd), additive mask (B,1,1,S,T) or None."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, hd)
    scores = jnp.einsum("bskrd,btkd->bkrst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if mask is not None:
        scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrst,btkd->bskrd", probs, v)
    return out.reshape(b, s, h * hd)


def flash_attention(q, k, v, cfg: ModelConfig, causal: bool = True,
                    q_offset=0, chunk: int = 512) -> jax.Array:
    """Memory-bounded attention: lax.scan over KV chunks with running
    (max, denom, acc) — the flash-attention recurrence in pure jnp.  Never
    materializes the (S, T) score matrix; per-step footprint is
    O(B·H·S·chunk).  Required for the 32k cells (32k² scores would be TBs).

    q (B,S,H,hd); k/v (B,T,KV,hd) or ``paged_kv.PagedKV`` gather-views.
    Paged operands are materialized up front — the gather costs one
    dense copy of K/V, so the paged layout's residency saving does NOT
    extend through this function; a per-chunk page gather (a
    layout-specialized ``kv_layout='paged'`` executor) is the seam for
    that.  Masks (causal and/or sliding window) are rebuilt per chunk
    from positions, so no (S,T) mask exists either.
    """
    from . import paged_kv
    k = paged_kv.materialize(k)
    v = paged_kv.materialize(v)
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    rep = h // kv
    window = cfg.sliding_window
    if t % chunk:
        pad = -t % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k.shape[1] // chunk
    qg = (q.reshape(b, s, kv, rep, hd).astype(jnp.float32)
          / jnp.sqrt(jnp.asarray(hd, jnp.float32)))
    kc = k.reshape(b, nc, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    qpos = (jnp.arange(s) + q_offset)[:, None]           # (S,1)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, c0 = inp
        sc = jnp.einsum("bskrd,bukd->bskru", qg, kb.astype(jnp.float32))
        kpos = (c0 + jnp.arange(chunk))[None, :]         # (1,chunk)
        ok = kpos <= qpos if causal else (kpos < t)
        ok &= kpos < t                                   # mask padding
        if window:
            ok &= kpos > (qpos - window)
        sc = jnp.where(ok[None, :, None, None, :], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskru,bukd->bskrd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, kv, rep), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, s, kv, rep), jnp.float32)
    a0 = jnp.zeros((b, s, kv, rep, hd), jnp.float32)
    starts = jnp.arange(nc) * chunk
    # checkpoint the chunk step: without it, grad-of-scan stacks every
    # chunk's (S x chunk) probs in f32 for the backward pass — O(S*T)
    # memory, exactly what flash attention exists to avoid.  With it,
    # backward replays each chunk (the standard flash-bwd recompute).
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, h * hd).astype(q.dtype)


FLASH_THRESHOLD = 2048  # direct attention below this (smoke tests, decode)


def _constrain_qkv(q, k, v):
    """Anchor attention operand shardings (batch over DP, q-heads over TP
    where divisible, kv-seq per mode) — attention has no dense() inside,
    so without this XLA replicates the whole score computation."""
    from repro.dist.sharding import constrain_act
    q = constrain_act(q, ("batch", "seq", "head_count", "none"))
    k = constrain_act(k, ("batch", "kv_seq", "none", "none"))
    v = constrain_act(v, ("batch", "kv_seq", "none", "none"))
    return q, k, v


def attend(q, k, v, cfg: ModelConfig, causal: bool = True, q_offset=0):
    """Dispatch: direct masked attention for short sequences, flash
    above.  ``k``/``v`` may be ``paged_kv.PagedKV`` gather-views — the
    paged layout gathers into the dense (B, T, KV, hd) operand here, so
    both branches (and their outputs) are identical to dense K/V."""
    from . import paged_kv
    k = paged_kv.materialize(k)
    v = paged_kv.materialize(v)
    q, k, v = _constrain_qkv(q, k, v)
    s, t = q.shape[1], k.shape[1]
    if max(s, t) <= FLASH_THRESHOLD:
        off = q_offset if s != t else 0
        mask = causal_mask(s, t, cfg.sliding_window, off) if causal else None
        return _gqa_attend(q, k, v, mask, cfg)
    return flash_attention(q, k, v, cfg, causal=causal, q_offset=q_offset)


def causal_mask(s: int, t: int, window: int = 0, offset: int = 0) -> jax.Array:
    """Additive (1,1,1,s,t) mask; offset = absolute position of query 0."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > (qpos - window)
    return jnp.where(ok, 0.0, -1e30)[None, None, None]


def self_attention(x, w, cfg: ModelConfig, positions=None, causal=True,
                   cim_cfg=None) -> jax.Array:
    """Training/prefill self-attention (full or sliding-window)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(x, w, cfg, positions=positions, cim_cfg=cim_cfg)
    out = attend(q, k, v, cfg, causal=causal)
    return dense(out, w["wo"], cim_cfg, x_axes=ATTN_OUT)


def cross_attention(x, x_kv, w, cfg: ModelConfig, cim_cfg=None) -> jax.Array:
    q, k, v = _project_qkv(x, w, cfg, x_kv=x_kv, rope=False, cim_cfg=cim_cfg)
    out = attend(q, k, v, cfg, causal=False)
    return dense(out, w["wo"], cim_cfg, x_axes=ATTN_OUT)


def prefill_attention(x, w, cfg: ModelConfig, cache: KVCache,
                      cim_cfg=None) -> tuple[jax.Array, KVCache]:
    """Prefill: run causal attention AND populate the cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(x, w, cfg, positions=positions, cim_cfg=cim_cfg)
    out = attend(q, k, v, cfg, causal=True)
    cap = cache.k.shape[1]
    if cfg.sliding_window and cap == cfg.sliding_window:
        keep = min(s, cap)
        newk = jax.lax.dynamic_slice_in_dim(k, s - keep, keep, 1)
        newv = jax.lax.dynamic_slice_in_dim(v, s - keep, keep, 1)
        # rolling buffer laid out so that slot = absolute_pos % window
        roll = (s - keep) % cap
        newk = jnp.roll(jnp.pad(newk, ((0, 0), (0, cap - keep), (0, 0), (0, 0))),
                        roll, axis=1)
        newv = jnp.roll(jnp.pad(newv, ((0, 0), (0, cap - keep), (0, 0), (0, 0))),
                        roll, axis=1)
        cache = KVCache(newk.astype(cache.k.dtype), newv.astype(cache.v.dtype),
                        jnp.asarray(s, jnp.int32))
    else:
        newk = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), 0, 1)
        newv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), 0, 1)
        cache = KVCache(newk, newv, jnp.asarray(s, jnp.int32))
    return dense(out, w["wo"], cim_cfg, x_axes=ATTN_OUT), cache


def decode_attention_read(x, w, cfg: ModelConfig, cache: KVCache,
                          cim_cfg=None):
    """One-token decode that does NOT write the cache: attends over the
    (stale) cache slice + the freshly projected k/v of the current token,
    and returns them for a single model-level cache update.

    Rationale (measured on the 32k-decode dry-run): per-layer
    dynamic-update-slice of the cache makes XLA stage full-cache copies
    inside the layer scan (~2x the whole KV cache of HBM traffic per
    decoded token); reading the cache once and batching all layers'
    updates into ONE top-level in-place DUS leaves only the unavoidable
    params + cache read.

    Returns (out, k_new (B,1,KV,hd), v_new)."""
    b, s, _ = x.shape
    assert s == 1, "decode_attention is single-token"
    pos = cache.index
    q, k, v = _project_qkv(x, w, cfg, positions=pos[None, None],
                           cim_cfg=cim_cfg)
    cap = cache.k.shape[1]
    rolling = cfg.sliding_window and cap == cfg.sliding_window
    slot = pos % cap if rolling else pos
    slots = jnp.arange(cap)
    if rolling:
        # previously written slots, excluding the one the new token will
        # overwrite (it holds the entry that just left the window)
        valid = (slots < jnp.minimum(pos, cap)) & (slots != slot)
    else:
        valid = slots < pos
    q, ck, cv = _constrain_qkv(q, cache.k, cache.v)
    # NO concatenation: a (cap+1)-long axis breaks the cache's sequence
    # sharding (measured: it all-gathers the whole cache).  Instead merge
    # the cache block and the new token with the flash two-block rule.
    b, _, h, hd = q.shape
    kv = ck.shape[2]
    rep = h // kv
    int8_kv = cache.k_scale is not None
    # keep the cache operands in their storage dtype: .astype(f32) on the
    # (B, cap, KV, hd) cache would materialize an f32 copy of the whole
    # cache every layer — the dots accumulate in f32 instead.  int8-KV:
    # per-(position, head) scales factor OUT of the dots (s = scale·q·k8,
    # acc = Σ (p·v_scale)·v8), so no dequantized cache copy ever exists.
    qg = (q / jnp.sqrt(jnp.asarray(hd, q.dtype))).reshape(b, 1, kv, rep, hd)
    dot_k = ck.astype(q.dtype) if int8_kv else ck
    sc = jnp.einsum("bskrd,btkd->bkrst", qg, dot_k,
                    preferred_element_type=jnp.float32)
    if int8_kv:
        sc = sc * cache.k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    sc = sc + jnp.where(valid, 0.0, -1e30)[None, None, None, None, :]
    m_c = jnp.max(sc, axis=-1)                            # (b,kv,rep,1)
    p_c = jnp.exp(sc - m_c[..., None])
    l_c = jnp.sum(p_c, axis=-1)
    if int8_kv:
        p_eff = (p_c * cache.v_scale.transpose(0, 2, 1)
                 [:, :, None, None, :]).astype(q.dtype)
        acc_c = jnp.einsum("bkrst,btkd->bkrsd", p_eff, cv.astype(q.dtype),
                           preferred_element_type=jnp.float32)
    else:
        acc_c = jnp.einsum("bkrst,btkd->bkrsd", p_c.astype(ck.dtype), cv,
                           preferred_element_type=jnp.float32)
    # new-token block: score (b, kv, rep, 1), value (b, kv, hd)
    s_n = jnp.einsum("bskrd,bukd->bkrs", qg, k,
                     preferred_element_type=jnp.float32)
    v_n = v.astype(jnp.float32)[:, 0]                     # (b, kv, hd)
    m = jnp.maximum(m_c, s_n)
    w_c = jnp.exp(m_c - m)
    w_n = jnp.exp(s_n - m)
    acc = acc_c * w_c[..., None] + \
        w_n[..., None] * v_n[:, :, None, None, :]
    l = l_c * w_c + w_n
    out = (acc / l[..., None]).astype(q.dtype)            # (b,kv,rep,1,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd)
    return (dense(out, w["wo"], cim_cfg, x_axes=ATTN_OUT),
            k.astype(cache.k.dtype), v.astype(cache.v.dtype))


def paged_decode_attention_read(x, w, cfg: ModelConfig, k_pages, v_pages,
                                page_table, pos, plan, cim_cfg=None):
    """Batched one-token decode read straight off one layer's page pool:
    the planned ``attention`` executor (``kernels.paged_attention``)
    consumes the page table in-kernel, so the gathered dense KV copy the
    ``slot_view`` path materializes never exists here.  The executor
    returns partial flash statistics over the pooled context; the fresh
    token's own k/v merge in with the same two-block rule (and the same
    masking constant) as ``decode_attention_read``, so the two paths
    agree to f32 round-off — and bitwise at the sampled argmax.

    ``x`` is (S, 1, d) — all S slots at once, not vmapped: the executor
    runs one grid over every (slot, page) cell.  Returns
    (out (S, 1, d), k_new (S, KV, hd), v_new)."""
    from repro.kernels import execute
    from repro.kernels.paged_attention import PagedAttentionKV
    s_dim, s1, _ = x.shape
    assert s1 == 1, "paged decode read is single-token"
    q, k, v = _project_qkv(x, w, cfg, positions=pos[:, None],
                           cim_cfg=cim_cfg)
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    rep = h // kvh
    qg = (q / jnp.sqrt(jnp.asarray(hd, q.dtype))).reshape(
        s_dim, kvh, rep, hd)
    acc_c, m_c, l_c = execute(
        plan, qg, PagedAttentionKV(k_pages, v_pages, page_table, pos))
    # new-token block, then the flash two-block merge (the dense decode
    # read's rule verbatim): slots with no live context come back with
    # m_c = -1e30, l_c = 0 and renormalize onto the fresh token alone
    s_n = jnp.einsum("skrd,skd->skr", qg, k[:, 0].astype(qg.dtype),
                     preferred_element_type=jnp.float32)
    v_n = v.astype(jnp.float32)[:, 0]                     # (S, KV, hd)
    m = jnp.maximum(m_c, s_n)
    w_c = jnp.exp(m_c - m)
    w_n = jnp.exp(s_n - m)
    acc = acc_c * w_c[..., None] + w_n[..., None] * v_n[:, :, None, :]
    l = l_c * w_c + w_n
    out = (acc / l[..., None]).astype(q.dtype)            # (S,KV,rep,hd)
    out = out.reshape(s_dim, 1, h * hd)
    return (dense(out, w["wo"], cim_cfg, x_axes=ATTN_OUT),
            k[:, 0], v[:, 0])


def decode_attention(x, w, cfg: ModelConfig, cache: KVCache,
                     cim_cfg=None) -> tuple[jax.Array, KVCache]:
    """One-token decode against the cache (full or rolling window)."""
    b, s, _ = x.shape
    assert s == 1, "decode_attention is single-token"
    pos = cache.index                                  # absolute position
    q, k, v = _project_qkv(x, w, cfg, positions=pos[None, None],
                           cim_cfg=cim_cfg)
    cap = cache.k.shape[1]
    slot = pos % cap if cfg.sliding_window and cap == cfg.sliding_window else pos
    newk = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                               slot, 1)
    newv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                               slot, 1)
    # validity mask over cache slots
    slots = jnp.arange(cap)
    if cfg.sliding_window and cap == cfg.sliding_window:
        valid = slots < jnp.minimum(pos + 1, cap)      # rolling: all written
    else:
        valid = slots <= pos
    mask = jnp.where(valid, 0.0, -1e30)[None, None, None, None, :]
    q, ck, cv = _constrain_qkv(q, newk, newv)
    out = _gqa_attend(q, ck, cv, mask, cfg)
    return (dense(out, w["wo"], cim_cfg, x_axes=ATTN_OUT),
            KVCache(newk, newv, pos + 1))
