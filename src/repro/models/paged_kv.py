"""Paged, prefix-shared KV storage for the serving slot pool.

The paper's pitch is density: fit more model state in the same storage
by packing it tighter (7.8x at the cell level).  The serving analogue is
KV-cache density — the dense slot pool (serve.init_slot_pool) gives
every slot a ``(1, capacity, KV, hd)`` cache padded to full capacity, so
resident KV scales as ``slots x max_seq`` even when most slots hold
short requests, and identical prompt prefixes are duplicated per slot.

This module replaces the per-slot dense cache with a **block pool**:

  * :class:`PagedKVCache` — fixed-size pages on a leading ``page`` axis
    (``k_pages (L, P, page_size, KV, hd)``; int8-KV scale pages ride
    alongside with the same paging).  Page 0 is a reserved null page —
    never allocated, the target of masked/dead writes and of unused
    page-table entries.
  * :func:`slot_view` — the gather: a slot's page-table row gathered
    back into the dense ``(L, 1, cap, KV, hd)`` cache layout the
    existing attention read path consumes.  Positions at or beyond the
    slot's ``pos`` are masked by the same validity rule as the dense
    cache, and masked float contributions are EXACTLY zero
    (``exp(-1e30 - m) == 0.0``), so paged attention is **bitwise
    identical** to the dense pool (pinned in tests/test_paged.py).
  * :func:`append_tokens` — the per-decode-step scatter of every slot's
    new K/V token into its current page (dead slots are routed to the
    null page so a freed-and-reused page is never clobbered).
  * :func:`write_prompt_pages` — admission-time scatter of a prefill's
    KV slab into freshly allocated pages (pages whose hashed prefix
    already resides in the pool are mapped shared instead — see
    :class:`PageAllocator`).
  * :class:`PagedKV` + :func:`materialize` — a gather-view wrapper so
    ``attend``/``flash_attention`` accept paged K/V operands directly.
  * :class:`PageAllocator` — host-side free list with refcounted
    prefix sharing: full prompt pages are registered under a hash of
    the token prefix that determines their contents (causal attention:
    page j's KV depends exactly on tokens ``[0, (j+1)*page_size)``), so
    a later prompt with the same prefix maps the existing pages
    read-only instead of writing duplicates.  Shared pages are freed
    when their refcount drops to zero.

Which executors may run under the paged layout is a kernel-registry
capability (``kv_layout`` on ``ExecutionPlan``/``BackendSpec`` —
src/repro/kernels/README.md), not a kwarg threaded through ops/serve.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig


class PagedKVCache(NamedTuple):
    """The device-side page pool (shared by every slot of a scheduler).

    k_pages/v_pages: (L, P, page_size, KV, hd) in the cache storage
    dtype; k_scale_pages/v_scale_pages: (L, P, page_size, KV) f32,
    present only for int8-KV models (allocated up front, like the dense
    pool's scale buffers).
    """
    k_pages: jax.Array
    v_pages: jax.Array
    k_scale_pages: Optional[jax.Array] = None
    v_scale_pages: Optional[jax.Array] = None

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def num_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def page_bytes(self) -> int:
        """Device bytes one page occupies across k/v (+ scales)."""
        per = 0
        for leaf in self:
            if leaf is not None:
                per += leaf.nbytes // leaf.shape[1]
        return per


def init_page_pool(cfg: ModelConfig, num_pages: int,
                   page_size: int) -> PagedKVCache:
    """Allocate the page pool for a TransformerLM-family config.  Page 0
    is the reserved null page: never allocated, the landing zone for
    dead-slot scratch writes — its contents are garbage-by-design and
    every read of it is position-masked (do NOT assume it stays
    zero)."""
    if num_pages < 2:
        raise ValueError(f"num_pages must be >= 2 (page 0 is the "
                         f"reserved null page), got {num_pages}")
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    int8 = cfg.kv_cache_dtype == "int8"
    dt = jnp.int8 if int8 else cfg.dtype
    shape = (L, num_pages, page_size, kv, hd)
    k = jnp.zeros(shape, dt)
    v = jnp.zeros(shape, dt)
    if not int8:
        return PagedKVCache(k, v)
    # distinct buffers: k/v scale pages are donated independently
    return PagedKVCache(k, v, jnp.zeros(shape[:-1], jnp.float32),
                        jnp.zeros(shape[:-1], jnp.float32))


def slot_view(pool: PagedKVCache, page_table: jax.Array,
              pos: jax.Array) -> dict:
    """Gather one slot's pages into the dense decode-state layout.

    ``page_table`` (W,) int32 page ids (unused entries may point
    anywhere valid — the contents are masked by ``pos``); ``pos`` the
    slot's scalar next-write position.  Returns the ``{"k", "v", "pos"
    [, "k_scale", "v_scale"]}`` state-view ``registry`` decode reads —
    batch 1, capacity ``W * page_size``.
    """
    def gather(pages):
        g = pages[:, page_table]                 # (L, W, ps, ...)
        return g.reshape((g.shape[0], 1, g.shape[1] * g.shape[2])
                         + g.shape[3:])
    view = {"k": gather(pool.k_pages), "v": gather(pool.v_pages),
            "pos": pos}
    if pool.k_scale_pages is not None:
        view["k_scale"] = gather(pool.k_scale_pages)
        view["v_scale"] = gather(pool.v_scale_pages)
    return view


def raw_pool_view(pool: PagedKVCache) -> tuple:
    """The raw ``(L, P, page_size, KV, hd)`` page arrays, for
    layout-specialized executors that consume the page table in-kernel
    (``kernels.paged_attention``) instead of gathering a dense copy.

    Float-KV pools only: int8 pools carry per-position scale pages the
    fused read path does not consume — callers (PagedScheduler) fall
    back to the :func:`slot_view` gather there."""
    if pool.k_scale_pages is not None:
        raise ValueError(
            "raw pool view is float-KV only: int8 page pools carry "
            "scale pages the fused attention read does not consume; "
            "use the slot_view gather path")
    return pool.k_pages, pool.v_pages


def append_tokens(pool: PagedKVCache, kts: jax.Array, vts: jax.Array,
                  page_table: jax.Array, pos: jax.Array,
                  live: jax.Array) -> PagedKVCache:
    """Scatter every slot's freshly projected K/V token into its current
    page — the paged counterpart of the dense pool's one batched
    dynamic-update-slice per decode step.

    kts/vts: (slots, L, KV, hd) compute-dtype token projections (the
    ``decode_paged`` read returns them); page_table (slots, W) int32;
    pos (slots,) the per-slot write positions; ``live`` masks the
    scatter — dead slots (retired, or scratch-decoding past their
    budget) are routed to the null page so they can never corrupt a
    page that was freed and reallocated to another slot.
    """
    ps = pool.page_size
    slots = kts.shape[0]
    rows = jnp.arange(slots)
    # clamp the page index for scratch decodes past the table width
    pidx = jnp.minimum(pos // ps, page_table.shape[1] - 1)
    pid = jnp.where(live, page_table[rows, pidx], 0)
    off = jnp.where(live, pos % ps, 0)
    k_t = jnp.moveaxis(kts, 0, 1)                # (L, slots, KV, hd)
    v_t = jnp.moveaxis(vts, 0, 1)
    if pool.k_scale_pages is not None:
        from .attention import quantize_kv
        kq, ksc = quantize_kv(k_t)
        vq, vsc = quantize_kv(v_t)
        return pool._replace(
            k_pages=pool.k_pages.at[:, pid, off].set(kq),
            v_pages=pool.v_pages.at[:, pid, off].set(vq),
            k_scale_pages=pool.k_scale_pages.at[:, pid, off].set(ksc),
            v_scale_pages=pool.v_scale_pages.at[:, pid, off].set(vsc))
    return pool._replace(
        k_pages=pool.k_pages.at[:, pid, off].set(
            k_t.astype(pool.k_pages.dtype)),
        v_pages=pool.v_pages.at[:, pid, off].set(
            v_t.astype(pool.v_pages.dtype)))


def write_prompt_pages(pool: PagedKVCache, state: dict,
                       pool_ids: jax.Array,
                       src_pages: jax.Array) -> PagedKVCache:
    """Admission: copy a batch-1 prefill state's KV into the pool.

    ``state`` is the dense prefill state (``k (L, 1, cap, KV, hd)`` in
    storage dtype, scales included for int8-KV models; ``cap`` must be
    a page multiple).  ``src_pages[i]`` names the page-aligned chunk of
    the slab that lands in pool page ``pool_ids[i]`` — prefix-shared
    pages are simply absent from both arrays (their contents already
    reside in the pool, bit-for-bit).
    """
    ps = pool.page_size

    def put(pages, slab):
        cap = slab.shape[1]
        view = slab.reshape((slab.shape[0], cap // ps, ps)
                            + slab.shape[2:])
        return pages.at[:, pool_ids].set(
            view[:, src_pages].astype(pages.dtype))

    new = pool._replace(k_pages=put(pool.k_pages, state["k"][:, 0]),
                        v_pages=put(pool.v_pages, state["v"][:, 0]))
    if pool.k_scale_pages is not None:
        new = new._replace(
            k_scale_pages=put(pool.k_scale_pages, state["k_scale"][:, 0]),
            v_scale_pages=put(pool.v_scale_pages, state["v_scale"][:, 0]))
    return new


# ---------------------------------------------------------------------
# attend()/flash_attention() wiring: paged K/V operands
# ---------------------------------------------------------------------

class PagedKV(NamedTuple):
    """A paged K or V operand for ``models.attention.attend`` /
    ``flash_attention``: per-batch-row page tables over a shared page
    pool.  ``pages (P, page_size, KV, hd)``; ``page_table (B, n)``.
    The attention entry points gather (:func:`materialize`) before
    computing, so the paged layout needs no second attention
    implementation — and stays bitwise identical to dense operands.
    """
    pages: jax.Array
    page_table: jax.Array


def materialize(x):
    """Gather a :class:`PagedKV` view into a dense (B, T, KV, hd) array
    (identity on anything else)."""
    if not isinstance(x, PagedKV):
        return x
    b, n = x.page_table.shape
    g = x.pages[x.page_table]                    # (B, n, ps, KV, hd)
    return g.reshape((b, n * g.shape[2]) + g.shape[3:])


# ---------------------------------------------------------------------
# host-side page accounting
# ---------------------------------------------------------------------

class PageAllocator:
    """Free-list + refcounted prefix registry for one page pool.

    Pure host bookkeeping: page *contents* never leave the device; this
    tracks which pool indices are free, how many slots reference each
    shared page, and which hashed token prefixes already reside in the
    pool.  ``alloc`` is all-or-nothing so admission can be deferred
    atomically when the pool is exhausted (the scheduler retries after
    the next retire).
    """

    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        # page 0 reserved as the null page; hand out ascending ids
        self._free = list(range(num_pages - 1, 0, -1))
        self._refcount: dict = {}          # page id -> live references
        self._prefix: dict = {}            # prefix key -> page id
        self._key_of: dict = {}            # page id -> prefix key
        self.prefix_hits = 0
        self.prefix_lookups = 0
        self.peak_in_use = 0

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def _note_peak(self):
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)

    def alloc(self, n: int):
        """n fresh private pages (refcount 1), or None if the pool
        cannot satisfy all of them."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for pid in ids:
            self._refcount[pid] = 1
        self._note_peak()
        return ids

    def lookup_prefix(self, key):
        """Map a shared page if its prefix key resides in the pool
        (refcount++); returns the page id or None."""
        self.prefix_lookups += 1
        pid = self._prefix.get(key)
        if pid is None:
            return None
        self._refcount[pid] += 1
        self.prefix_hits += 1
        return pid

    def register_prefix(self, key, pid: int) -> None:
        """Publish a freshly written prompt page for future sharing."""
        self._prefix[key] = pid
        self._key_of[pid] = key

    def release(self, pids) -> None:
        """Drop one reference per page; pages reaching refcount 0 return
        to the free list (and leave the prefix registry)."""
        for pid in pids:
            self._refcount[pid] -= 1
            if self._refcount[pid] == 0:
                del self._refcount[pid]
                key = self._key_of.pop(pid, None)
                if key is not None:
                    del self._prefix[key]
                self._free.append(pid)

    def reset_stats(self) -> None:
        """Zero the measurement counters (peak watermark re-anchored to
        the current occupancy) without touching allocation state — so a
        bench can warm up, reset, and then measure only its replays."""
        self.peak_in_use = self.pages_in_use
        self.prefix_hits = 0
        self.prefix_lookups = 0

    @property
    def prefix_hit_rate(self) -> float:
        if not self.prefix_lookups:
            return 0.0
        return self.prefix_hits / self.prefix_lookups


def prefix_key(prompt_np, page: int, page_size: int):
    """Hashable identity of prompt page ``page``: the token prefix that
    (causally) determines the page's KV contents."""
    return (page, prompt_np[: (page + 1) * page_size].tobytes())
