"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (sLSTM+mLSTM).

Mamba2 uses the chunked SSD algorithm: intra-chunk quadratic einsums with
log-domain decay masks + an inter-chunk lax.scan over states — O(S·c)
compute, O(heads·hd·state) decode state (why zamba2/xlstm run long_500k).
mLSTM uses the stabilized parallel form for train/prefill and the
recurrent matrix-memory form for decode.  sLSTM is inherently sequential
(lax.scan over time).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, ParamDef
from .layers import dense, rms_norm

CHUNK = 256


# ===================================================================
# Mamba2
# ===================================================================

class Mamba2State(NamedTuple):
    h: jax.Array           # (B, heads, hd, state)
    conv: jax.Array        # (B, conv_width-1, conv_dim)
    index: jax.Array


def mamba2_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    heads = d_inner // 64
    hd = 64
    state = cfg.ssm_state or 64
    groups = 1                      # B/C shared across heads (n_groups=1)
    conv_dim = d_inner + 2 * groups * state
    return d_inner, heads, hd, state, groups, conv_dim


def mamba2_defs(cfg: ModelConfig, layers: int) -> dict:
    d = cfg.d_model
    d_inner, heads, hd, state, groups, conv_dim = mamba2_dims(cfg)
    L = (layers,)
    in_dim = 2 * d_inner + 2 * groups * state + heads   # z, x, B, C, dt
    return {
        "in_proj": ParamDef(L + (d, in_dim), ("layers", "embed", "inner")),
        "conv_w": ParamDef(L + (4, conv_dim), ("layers", "none", "inner"), "normal"),
        "conv_b": ParamDef(L + (conv_dim,), ("layers", "inner"), "zeros"),
        "a_log": ParamDef(L + (heads,), ("layers", "none"), "zeros"),
        "dt_bias": ParamDef(L + (heads,), ("layers", "none"), "zeros"),
        "d_skip": ParamDef(L + (heads,), ("layers", "none"), "ones"),
        "norm": ParamDef(L + (d_inner,), ("layers", "inner"), "ones"),
        "out_proj": ParamDef(L + (d_inner, d), ("layers", "inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, width 4. x (B,S,C); w (4,C).
    Returns (y, new_state) where state caches the last 3 inputs."""
    width = w.shape[0]
    if state is None:
        pads = [jnp.pad(x, ((0, 0), (width - 1 - i, 0), (0, 0)))[:, :x.shape[1]]
                for i in range(width)]
        # pads[i] = x shifted so that pads[i][t] = x[t - (width-1-i)]
        y = sum(pads[i] * w[i] for i in range(width)) + b
        new_state = x[:, -(width - 1):, :] if x.shape[1] >= width - 1 else \
            jnp.pad(x, ((0, 0), (width - 1 - x.shape[1], 0), (0, 0)))
    else:
        buf = jnp.concatenate([state, x], axis=1)       # (B, width, C) for S=1
        y = sum(buf[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
        new_state = buf[:, -(width - 1):, :]
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, dt, a_log, B, C):
    """Chunked SSD scan.
    xh (B,S,H,hd), dt (B,S,H) (already softplus'd), B/C (B,S,state).
    Returns y (B,S,H,hd) and final state (B,H,hd,state)."""
    b, s, h, hd = xh.shape
    st = B.shape[-1]
    c = min(CHUNK, s)
    nch = s // c
    assert nch * c == s, (s, c)
    loga = -jnp.exp(a_log.astype(jnp.float32))          # (H,) negative
    # per-token log decay: (B,S,H)
    dl = dt.astype(jnp.float32) * loga
    dlc = dl.reshape(b, nch, c, h)
    cum = jnp.cumsum(dlc, axis=2)                       # within-chunk cumsum
    xc = xh.reshape(b, nch, c, h, hd).astype(jnp.float32)
    Bc = B.reshape(b, nch, c, st).astype(jnp.float32)
    Cc = C.reshape(b, nch, c, st).astype(jnp.float32)
    dtc = dt.reshape(b, nch, c, h).astype(jnp.float32)

    # --- intra-chunk (quadratic within c) ---
    # score[t,tau] = exp(cum_t - cum_tau) * (C_t . B_tau) * dt_tau, tau <= t
    gap = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (b,n,c,c,h)
    tri = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(gap), 0.0)
    cb = jnp.einsum("bncs,bnts->bnct", Cc, Bc)                   # (b,n,c_t,c_tau)
    w_intra = decay * cb[..., None] * dtc[:, :, None, :, :]      # (b,n,t,tau,h)
    y_intra = jnp.einsum("bntuh,bnuhd->bnthd", w_intra, xc)

    # --- chunk states ---
    # state_n = exp(cum_end - cum_tau) dt_tau B_tau x_tau^T summed over tau
    end_gap = cum[:, :, -1:, :] - cum                             # (b,n,c,h)
    contrib = jnp.einsum("bnch,bncs,bnchd->bnhds",
                         jnp.exp(end_gap) * dtc, Bc, xc)          # (b,n,h,hd,st)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (b,n,h)

    def step(hprev, inp):
        contrib_n, cd = inp
        hnew = cd[..., None, None] * hprev + contrib_n
        return hnew, hprev                                       # emit PREV

    h0 = jnp.zeros((b, h, hd, st))
    hlast, hprevs = jax.lax.scan(
        step, h0, (contrib.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                      # (b,n,h,hd,st)

    # --- inter-chunk: y_t += C_t . (exp(cum_t) * h_chunk_start) ---
    y_inter = jnp.einsum("bncs,bnch,bnhds->bnchd",
                         Cc, jnp.exp(cum), hprevs)
    y = (y_intra + y_inter).reshape(b, s, h, hd)
    return y, hlast


def mamba2_block(x, w, cfg: ModelConfig, state: Mamba2State | None = None,
                 cim_cfg=None):
    """x (B,S,D) -> (y, new_state).  state=None -> train/prefill path."""
    b, s, d = x.shape
    d_inner, heads, hd, st, groups, conv_dim = mamba2_dims(cfg)
    zxbcdt = dense(x, w["in_proj"], cim_cfg)
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + st, 2 * d_inner + 2 * st],
        axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = None if state is None else state.conv
    conv_out, new_conv = _causal_conv(conv_in, w["conv_w"], w["conv_b"],
                                      conv_state)
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + w["dt_bias"])   # (B,S,H)
    xh = xin.reshape(b, s, heads, hd)
    if state is None or s > 1:
        # train AND stateful prefill (s > 1) take the chunked path; the
        # final chunk state seeds subsequent decode steps.  (Prefill
        # always starts from an empty state in this framework, so the
        # incoming state.h is zeros and needs no folding-in.)
        pad = -s % CHUNK if s > CHUNK else 0
        xp, dtp, Bp, Cp = xh, dt, Bc, Cc
        if pad:
            xp = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bp = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
            Cp = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        y, hlast = _ssd_chunked(xp, dtp, w["a_log"], Bp, Cp)
        y = y[:, :s]
        new_state = Mamba2State(hlast, new_conv, jnp.asarray(s, jnp.int32))
    else:
        # recurrent single step (S == 1)
        loga = -jnp.exp(w["a_log"].astype(jnp.float32))
        a = jnp.exp(dt[:, 0] * loga)                              # (B,H)
        dBx = jnp.einsum("bh,bs,bhd->bhds", dt[:, 0], Bc[:, 0],
                         xh[:, 0].astype(jnp.float32))
        hnew = a[..., None, None] * state.h + dBx
        y = jnp.einsum("bs,bhds->bhd", Cc[:, 0], hnew)[:, None]
        new_state = Mamba2State(hnew, new_conv, state.index + 1)
    y = y.astype(x.dtype).reshape(b, s, d_inner)
    y = y + xh.reshape(b, s, d_inner) * jnp.repeat(w["d_skip"], hd).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), w["norm"], cfg.norm_eps)
    return dense(y, w["out_proj"], cim_cfg), new_state


def init_mamba2_state(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    d_inner, heads, hd, st, groups, conv_dim = mamba2_dims(cfg)
    return Mamba2State(jnp.zeros((batch, heads, hd, st), jnp.float32),
                       jnp.zeros((batch, 3, conv_dim), dtype),
                       jnp.zeros((), jnp.int32))


# ===================================================================
# xLSTM
# ===================================================================

class XLSTMState(NamedTuple):
    # mLSTM: matrix memory; sLSTM: scalar tuples — both padded into one
    C: jax.Array           # (B, H, hd, hd) mLSTM / (B, H, hd, 1) sLSTM c,n
    n: jax.Array           # (B, H, hd)
    m: jax.Array           # (B, H)
    h: jax.Array           # (B, H, hd)  (sLSTM recurrent h)
    index: jax.Array


def xlstm_dims(cfg: ModelConfig):
    heads = cfg.num_heads
    d_up = 2 * cfg.d_model
    hd = d_up // heads
    return d_up, heads, hd


def mlstm_defs(cfg: ModelConfig, layers: int) -> dict:
    d = cfg.d_model
    d_up, heads, hd = xlstm_dims(cfg)
    L = (layers,)
    return {
        "up": ParamDef(L + (d, 2 * d_up), ("layers", "embed", "inner")),
        "wq": ParamDef(L + (d_up, d_up), ("layers", "inner", "heads")),
        "wk": ParamDef(L + (d_up, d_up), ("layers", "inner", "heads")),
        "wv": ParamDef(L + (d_up, d_up), ("layers", "inner", "heads")),
        "wif": ParamDef(L + (d_up, 2 * heads), ("layers", "inner", "none")),
        "norm": ParamDef(L + (d_up,), ("layers", "inner"), "ones"),
        "down": ParamDef(L + (d_up, d), ("layers", "inner", "embed")),
    }


def slstm_defs(cfg: ModelConfig, layers: int) -> dict:
    d = cfg.d_model
    heads = cfg.num_heads
    hd = d // heads
    L = (layers,)
    return {
        "wx": ParamDef(L + (d, 4 * d), ("layers", "embed", "inner")),
        "wr": ParamDef(L + (heads, hd, 4 * hd), ("layers", "none", "none", "none")),
        "norm": ParamDef(L + (d,), ("layers", "embed"), "ones"),
        "up1": ParamDef(L + (d, 4 * d // 3), ("layers", "embed", "mlp")),
        "up2": ParamDef(L + (4 * d // 3, d), ("layers", "mlp", "embed")),
    }


def _mlstm_chunked(q, k, v, logi, logf, chunk: int = CHUNK):
    """Chunked mLSTM: O(S·c) memory instead of the O(S²) parallel form —
    required for 32k+ prefill.  Same gated-linear-attention recurrence as
    the parallel form; state (C, n, m) is carried across chunks with
    max-stabilization (the xLSTM paper's chunkwise formulation).

    q/k/v (B,S,H,hd) — k pre-scaled by 1/sqrt(hd); logi/logf (B,S,H).
    Returns y (B,S,H,hd) f32 and the final XLSTM-style (C, n, m).
    """
    b, s, h, hd = q.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nch = s // c
    qc = q.reshape(b, nch, c, h, hd).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nch, c, h, hd).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nch, c, h, hd).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    lic = logi.reshape(b, nch, c, h).transpose(1, 0, 2, 3)
    lfc = logf.reshape(b, nch, c, h).transpose(1, 0, 2, 3)

    def step(carry, inp):
        C, n, m = carry                       # (B,H,hd,hd), (B,H,hd), (B,H)
        qb, kb, vb, li, lf = inp
        F = jnp.cumsum(lf, axis=1)            # (B,c,H) within-chunk decay
        # intra-chunk parallel part (c x c)
        sc = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))[None, :, :, None]
        sc = jnp.where(tri, sc, -jnp.inf)
        # inter-chunk: query t sees carried state decayed by F_t, amp m
        m_inter = F + m[:, None, :]                         # (B,c,H)
        m_intra = jnp.max(sc, axis=2)                       # (B,c,H)
        m_tot = jnp.maximum(m_inter, m_intra)
        d_intra = jnp.exp(sc - m_tot[:, :, None, :])        # (B,c,c,H)
        d_inter = jnp.exp(m_inter - m_tot)                  # (B,c,H)
        qk = jnp.einsum("bthd,buhd->btuh", qb, kb)
        num = (jnp.einsum("btuh,buhd->bthd", qk * d_intra, vb)
               + d_inter[..., None] * jnp.einsum("bhde,bthe->bthd", C, qb))
        den = (jnp.einsum("btuh,buhd,bthd->bth", d_intra, kb, qb)
               + d_inter * jnp.einsum("bhe,bthe->bth", n, qb))
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))[..., None]
        # carry update: decay whole chunk into state
        Fend = F[:, -1, :]                                  # (B,H)
        m_new = jnp.maximum(Fend + m, jnp.max(Fend[:, None, :] - F + li, axis=1))
        wgt = jnp.exp(Fend[:, None, :] - F + li - m_new[:, None, :])
        C_new = (jnp.exp(Fend + m - m_new)[..., None, None] * C
                 + jnp.einsum("buh,buhd,buhe->bhde", wgt, vb, kb))
        n_new = (jnp.exp(Fend + m - m_new)[..., None] * n
                 + jnp.einsum("buh,buhd->bhd", wgt, kb))
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((b, h, hd, hd))
    n0 = jnp.zeros((b, h, hd))
    m0 = jnp.full((b, h), -1e30)
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return y, (C, n, m)


def mlstm_block(x, w, cfg: ModelConfig, state: XLSTMState | None = None,
                cim_cfg=None):
    """Parallel (train/prefill) or recurrent (decode) mLSTM."""
    b, s, d = x.shape
    d_up, heads, hd = xlstm_dims(cfg)
    u, gate = jnp.split(dense(x, w["up"], cim_cfg), 2, axis=-1)
    q = dense(u, w["wq"], cim_cfg).reshape(b, s, heads, hd)
    k = dense(u, w["wk"], cim_cfg).reshape(b, s, heads, hd) / jnp.sqrt(
        jnp.asarray(hd, x.dtype))
    v = dense(u, w["wv"], cim_cfg).reshape(b, s, heads, hd)
    i_f = dense(u, w["wif"], cim_cfg).astype(jnp.float32)
    logi, logf_raw = jnp.split(i_f.reshape(b, s, heads, 2), 2, axis=-1)
    logi, logf_raw = logi[..., 0], logf_raw[..., 0]
    logf = jax.nn.log_sigmoid(logf_raw)                 # (B,S,H)

    if state is None:
        if s > CHUNK and s % CHUNK == 0:
            # chunked path: O(S·c) memory — the only viable 32k+ prefill
            y, (C, n, m) = _mlstm_chunked(q, k, v, logi, logf)
            new_state = XLSTMState(C, n, m, jnp.zeros((b, heads, hd)),
                                   jnp.asarray(s, jnp.int32))
        else:
            F = jnp.cumsum(logf, axis=1)                # (B,S,H)
            # score[t,tau] = F_t - F_tau + logi_tau  (tau <= t)
            sc = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
            tri = jnp.tril(jnp.ones((s, s), bool))[None, :, :, None]
            sc = jnp.where(tri, sc, -jnp.inf)
            mstab = jnp.max(sc, axis=2, keepdims=True)  # (B,S,1,H)
            dmat = jnp.exp(sc - mstab)                  # stabilized decays
            qk = jnp.einsum("bthd,buhd->btuh", q.astype(jnp.float32),
                            k.astype(jnp.float32))
            att = qk * dmat
            norm = jnp.maximum(jnp.abs(att.sum(axis=2)),
                               jnp.exp(-mstab[:, :, 0, :]))  # (B,S,H)
            y = jnp.einsum("btuh,buhd->bthd", att, v.astype(jnp.float32))
            y = y / norm[..., None]
            new_state = _mlstm_final_state(k, v, logi, logf, b, heads, hd)
    else:
        m_prev, C_prev, n_prev = state.m, state.C, state.n
        m_new = jnp.maximum(logf[:, 0] + m_prev, logi[:, 0])      # (B,H)
        fdec = jnp.exp(logf[:, 0] + m_prev - m_new)
        iamp = jnp.exp(logi[:, 0] - m_new)
        C_new = (fdec[..., None, None] * C_prev
                 + iamp[..., None, None] * jnp.einsum(
                     "bhd,bhe->bhde", v[:, 0].astype(jnp.float32),
                     k[:, 0].astype(jnp.float32)))
        n_new = fdec[..., None] * n_prev + iamp[..., None] * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhde,bhe->bhd", C_new, q[:, 0].astype(jnp.float32))
        den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n_new,
                                             q[:, 0].astype(jnp.float32))),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]
        new_state = XLSTMState(C_new, n_new, m_new, state.h, state.index + 1)
    y = y.astype(x.dtype).reshape(b, s, d_up)
    y = rms_norm(y, w["norm"], cfg.norm_eps) * jax.nn.silu(gate)
    return dense(y, w["down"], cim_cfg), new_state


def _mlstm_final_state(k, v, logi, logf, b, heads, hd):
    """Recurrent state equivalent to having consumed the whole prefix."""
    s = k.shape[1]
    F = jnp.cumsum(logf, axis=1)
    tail = F[:, -1:, :] - F                            # decay from tau to end
    sc = tail + logi                                   # (B,S,H)
    m = jnp.max(sc, axis=1)                            # (B,H)
    wgt = jnp.exp(sc - m[:, None, :])
    C = jnp.einsum("buh,buhd,buhe->bhde", wgt, v.astype(jnp.float32),
                   k.astype(jnp.float32))
    n = jnp.einsum("buh,buhd->bhd", wgt, k.astype(jnp.float32))
    return XLSTMState(C, n, m, jnp.zeros((b, heads, hd)),
                      jnp.asarray(s, jnp.int32))


def slstm_block(x, w, cfg: ModelConfig, state: XLSTMState | None = None,
                cim_cfg=None):
    """sLSTM: sequential scan with exponential gating (per head)."""
    b, s, d = x.shape
    heads = cfg.num_heads
    hd = d // heads
    gates_x = dense(x, w["wx"], cim_cfg).astype(jnp.float32)      # (B,S,4d)
    gates_x = gates_x.reshape(b, s, 4, heads, hd)
    wr = w["wr"].astype(jnp.float32)                              # (H,hd,4hd)

    if state is None:
        c0 = jnp.zeros((b, heads, hd))
        n0 = jnp.ones((b, heads, hd))
        m0 = jnp.zeros((b, heads))
        h0 = jnp.zeros((b, heads, hd))
    else:
        c0, n0, m0, h0 = state.C[..., 0], state.n, state.m, state.h

    def step(carry, gx):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,hde->bhe", h, wr).reshape(b, heads, 4, hd)
        zi = gx[:, 0] + rec[:, :, 0]
        ii = gx[:, 1] + rec[:, :, 1]
        fi = gx[:, 2] + rec[:, :, 2]
        oi = gx[:, 3] + rec[:, :, 3]
        logf = jax.nn.log_sigmoid(fi).mean(-1)          # per-head scalar gate
        logi = ii.mean(-1)
        m_new = jnp.maximum(logf + m, logi)
        fdec = jnp.exp(logf + m - m_new)[..., None]
        iamp = jnp.exp(logi - m_new)[..., None]
        zt = jnp.tanh(zi)
        c_new = fdec * c + iamp * zt
        n_new = fdec * n + iamp
        h_new = jax.nn.sigmoid(oi) * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, m_new, h_new), h_new

    gseq = gates_x.transpose(1, 0, 2, 3, 4)             # (S,B,4,H,hd)
    (c, n, m, h), ys = jax.lax.scan(step, (c0, n0, m0, h0), gseq)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, w["norm"], cfg.norm_eps)
    y = dense(jax.nn.gelu(dense(y, w["up1"], cim_cfg)), w["up2"], cim_cfg)
    new_state = XLSTMState(c[..., None], n, m, h, (state.index + s) if state
                           else jnp.asarray(s, jnp.int32))
    return y, new_state


def init_xlstm_state(batch: int, cfg: ModelConfig, kind: str):
    if kind == "mlstm":
        d_up, heads, hd = xlstm_dims(cfg)
        return XLSTMState(jnp.zeros((batch, heads, hd, hd)),
                          jnp.zeros((batch, heads, hd)),
                          jnp.full((batch, heads), -1e30),
                          jnp.zeros((batch, heads, hd)),
                          jnp.zeros((), jnp.int32))
    heads = cfg.num_heads
    hd = cfg.d_model // heads
    return XLSTMState(jnp.zeros((batch, heads, hd, 1)),
                      jnp.ones((batch, heads, hd)),
                      jnp.zeros((batch, heads)),
                      jnp.zeros((batch, heads, hd)),
                      jnp.zeros((), jnp.int32))
