"""Shared neural building blocks (pure functions, no framework)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cim_linear
from .config import ModelConfig, ParamDef


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def dense(x: jax.Array, w, cim_cfg: Optional[cim_linear.CIMConfig] = None,
          x_axes: Optional[tuple] = None) -> jax.Array:
    """Linear layer; routes through the CIM execution modes when configured
    or when `w` is already a PackedTernary (ternary-served models).

    Every dense input re-anchors the activation sharding (no-op off-mesh):
    XLA drops the DP sharding through scan loop state + remat regions,
    silently replicating the batch on every device otherwise.  For
    row-parallel matmuls (wo, w2) pass `x_axes` naming the sharded
    contraction dim ('heads' / 'mlp') — the default (replicated last dim)
    would force an all-gather of the sharded intermediate."""
    from repro.dist.sharding import constrain_act
    from repro.kernels.ops import PackedTernary
    if x.ndim == 3:
        x = constrain_act(x, x_axes)
    if isinstance(w, PackedTernary):
        cfg = cim_cfg or cim_linear.CIMConfig(mode="ternary")
        return cim_linear.linear(x, w, cfg).astype(x.dtype)
    if cim_cfg is not None and cim_cfg.mode != "float":
        return cim_linear.linear(x, w, cim_cfg).astype(x.dtype)
    return x @ w


MLP_MID = ("batch", "seq", "mlp")      # row-parallel w2 contraction
ATTN_OUT = ("batch", "seq", "heads")   # row-parallel wo contraction


def swiglu(x: jax.Array, w1, w3, w2, cim_cfg=None) -> jax.Array:
    """SwiGLU MLP: (silu(x@w1) * (x@w3)) @ w2."""
    return dense(jax.nn.silu(dense(x, w1, cim_cfg)) * dense(x, w3, cim_cfg),
                 w2, cim_cfg, x_axes=MLP_MID)


def gelu_mlp(x: jax.Array, w1, w2, cim_cfg=None) -> jax.Array:
    return dense(jax.nn.gelu(dense(x, w1, cim_cfg)), w2, cim_cfg,
                 x_axes=MLP_MID)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                 # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if angles.ndim == 2:                                # (S, hd/2) -> (1,S,..)
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, dim, 2).astype(jnp.float32)
                  * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ------------------------------ param defs for common blocks -------------

def attn_defs(cfg: ModelConfig, layers: int, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    L = (layers,)
    # kv projections are ROW-parallel ('embed_rp' shards the contraction
    # over 'model'): GQA kv-head counts (2/8) rarely divide a 16-way TP
    # axis, so column-parallel kv would be computed fully replicated.
    defs = {
        "wq": ParamDef(L + (d, h * hd), ("layers", "embed", "heads")),
        "wk": ParamDef(L + (d, kv * hd), ("layers", "embed_rp", "kv")),
        "wv": ParamDef(L + (d, kv * hd), ("layers", "embed_rp", "kv")),
        "wo": ParamDef(L + (h * hd, d), ("layers", "heads", "embed")),
    }
    if cfg.qk_norm and not cross:
        defs["q_norm"] = ParamDef(L + (hd,), ("layers", "none"), "ones")
        defs["k_norm"] = ParamDef(L + (hd,), ("layers", "none"), "ones")
    return defs


def mlp_defs(cfg: ModelConfig, layers: int, d_ff: int | None = None,
             gated: bool = True) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L = (layers,)
    defs = {
        "w1": ParamDef(L + (d, f), ("layers", "embed", "mlp")),
        "w2": ParamDef(L + (f, d), ("layers", "mlp", "embed")),
    }
    if gated:
        defs["w3"] = ParamDef(L + (d, f), ("layers", "embed", "mlp"))
    return defs


def norm_def(cfg: ModelConfig, layers: int | None = None) -> ParamDef:
    if layers is None:
        return ParamDef((cfg.d_model,), ("embed",), "ones")
    return ParamDef((layers, cfg.d_model), ("layers", "embed"), "ones")
