"""Model configuration + parameter-definition system.

Every architecture is described by a ModelConfig; every parameter is
declared once as a ParamDef (shape + logical axes + initializer), from
which we derive (a) real initialized params for smoke tests/examples,
(b) ShapeDtypeStructs with NamedShardings for the multi-pod dry-run
(never allocating), and (c) PartitionSpecs for jit in_shardings.
Logical->physical axis rules live in repro.dist.sharding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention details
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    sliding_window: int = 0        # 0 = full attention
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_kind: str = ""             # mamba2 | xlstm
    ssm_heads: int = 0             # mamba2 value heads (0 -> d_model // 64)
    attn_every: int = 0            # hybrid: shared attn after every k ssm layers
    slstm_every: int = 0           # xlstm: sLSTM block interval (rest mLSTM)
    # encoder-decoder / multimodal
    encoder_layers: int = 0        # whisper
    encoder_seq: int = 0           # stub frontend tokens (frames/patches)
    cross_attn_every: int = 0      # vlm: every k-th layer cross-attends
    # numerics
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    remat: str = "none"            # none | full  (activation checkpointing)
    kv_cache_dtype: str = ""       # "" (= dtype) | "int8" (scaled KV cache)
    # long-context capability (sub-quadratic attention): SSM state and/or
    # rolling-window attention -> long_500k cell is runnable
    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 — MXU-aligned and 16-way
        TP-shardable.  Embedding/unembed tables use this; data pipelines
        sample < vocab_size so pad rows are never valid targets."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_model // 64)

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline bookkeeping)."""
        from . import registry
        shapes = registry.build(self).param_defs
        return sum(math.prod(d.shape) for d in jax.tree.leaves(
            shapes, is_leaf=lambda x: isinstance(x, ParamDef)))

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        total = self.param_count()
        if self.num_experts:
            from . import registry
            shapes = registry.build(self).param_defs
            expert = sum(
                math.prod(d.shape) for d in jax.tree.leaves(
                    shapes, is_leaf=lambda x: isinstance(x, ParamDef))
                if isinstance(d, ParamDef) and "expert" in d.axes)
            active_frac = self.experts_per_token / self.num_experts
            return int(total - expert + expert * active_frac)
        return total


class ParamDef(NamedTuple):
    """Declarative parameter: shape + logical axes + init style."""
    shape: tuple
    axes: tuple                    # logical names, len == ndim
    init: str = "normal"           # normal | zeros | ones | embed
    dtype: Any = None              # None -> config dtype

    def initializer(self, key: jax.Array, cfg_dtype) -> jax.Array:
        dtype = self.dtype or cfg_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        if self.init == "embed":
            fan_in = 1.0
        std = 1.0 / math.sqrt(fan_in)
        return (std * jax.random.normal(key, self.shape)).astype(dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs: Any, dtype=jnp.float32) -> Any:
    """Materialize real parameters (smoke tests / examples only)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [d.initializer(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: Any, dtype=jnp.bfloat16, sharding_fn=None) -> Any:
    """ShapeDtypeStructs (optionally with shardings) — the dry-run path."""
    def mk(d: ParamDef):
        dt = d.dtype or dtype
        sh = sharding_fn(d) if sharding_fn else None
        if sh is not None:
            return jax.ShapeDtypeStruct(d.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(d.shape, dt)
    return jax.tree.map(mk, defs, is_leaf=is_def)


def param_bytes(defs: Any, bytes_per_param: float = 2.0) -> float:
    return sum(math.prod(d.shape) for d in
               jax.tree.leaves(defs, is_leaf=is_def)) * bytes_per_param
