"""Mixture-of-Experts layer: top-k routing with capacity-bounded
dispatch/combine einsums (GShard style).

Tokens are routed in fixed-size GROUPS (default 1024): capacity is
per-group (C = g*k*cf/E), so dispatch tensors stay O(T * g * k * cf)
globally instead of O(T^2) — the standard GShard trick that keeps MoE
memory linear in tokens.  The group axis shards over 'data' (+'pod') and
the expert axis over 'model' (EP); XLA inserts the dispatch/combine
all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, ParamDef

MOE_GROUP = 1024


def moe_defs(cfg: ModelConfig, layers: int) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    L = (layers,)
    return {
        "router": ParamDef(L + (d, e), ("layers", "embed", "none"),
                           dtype=jnp.float32),
        "w1": ParamDef(L + (e, d, f), ("layers", "expert", "embed", "mlp")),
        "w3": ParamDef(L + (e, d, f), ("layers", "expert", "embed", "mlp")),
        "w2": ParamDef(L + (e, f, d), ("layers", "expert", "mlp", "embed")),
    }


def moe_block(x: jax.Array, w, cfg: ModelConfig, cim_cfg=None,
              group_size: int = MOE_GROUP):
    """x (B,S,D) -> (y, aux_loss). Per-group capacity; overflow dropped."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = min(group_size, t)
    pad = -t % g
    xt = x.reshape(t, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = xt.shape[0] // g
    xg = xt.reshape(ng, g, d)                                    # (G,g,D)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32),
                        w["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,g,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (G,g,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = max(1, int(g * k * cfg.moe_capacity_factor / e))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)        # (G,g,k,E)
    # capacity slot of each (token, choice) within its expert, per group:
    flat = onehot.reshape(ng, g * k, e)
    pos = (jnp.cumsum(flat, axis=1) * flat - 1).reshape(ng, g, k, e)
    within = (pos >= 0) & (pos < cap)

    dispatch = jnp.zeros((ng, g, e, cap), x.dtype)
    combine = jnp.zeros((ng, g, e, cap), x.dtype)
    for i in range(k):                                           # k <= 8
        sel = (onehot[:, :, i] * within[:, :, i]).astype(x.dtype)  # (G,g,E)
        oh_cap = jax.nn.one_hot(jnp.clip(pos[:, :, i], 0, cap - 1), cap,
                                dtype=x.dtype)                   # (G,g,E,C)
        d_i = oh_cap * sel[..., None]
        dispatch = dispatch + d_i
        combine = combine + d_i * gate_vals[:, :, i, None, None].astype(x.dtype)

    def expert_w(name):
        """Expert weights may be PackedTernary (paper 5-trit storage);
        dequant is elementwise and fuses into the einsum operand, so the
        HBM read stays at the packed width."""
        from repro.kernels.ops import PackedTernary, _dequant_xla
        ww = w[name]
        if isinstance(ww, PackedTernary):
            return _dequant_xla(ww, x.dtype)
        return ww

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)              # (G,E,C,D)
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", xe, expert_w("w1"))) * \
        jnp.einsum("necd,edf->necf", xe, expert_w("w3"))
    ye = jnp.einsum("necf,efd->necd", h, expert_w("w2"))         # (G,E,C,D)
    y = jnp.einsum("ngec,necd->ngd", combine, ye)
    y = y.reshape(t + pad, d)[:t].reshape(b, s, d)

    # load-balancing auxiliary loss (Switch/GShard)
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = onehot.sum(axis=2).astype(jnp.float32).mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce) * 1e-2
    return y, aux
