"""Measured block-shape autotuner for the block-tiled (pallas) backend.

``select_block_shapes`` is a static heuristic: it reasons about sublane
quanta and a VMEM budget but never runs anything.  This module closes
the loop: ``tune()`` benchmarks a small candidate set of aligned
``(bm, bn, bk)`` tiles per ``(shape, phase, platform, packing, domain)``
cell — once, on the platform that will serve them — and persists the
winners as a schema-validated JSON artifact (``BENCH_autotune.json`` at
the repo root, tracked like the wallclock baseline).

Plan resolution (``plan.\\_resolve``) consults the table through
:func:`lookup_blocks`: a warm hit resolves the measured blocks into the
plan (``block_source='autotune'`` in ``ExecutionPlan.describe()``); a
miss falls back to ``select_block_shapes`` and is logged (once per
cell) — never silent, never fatal.  A doctored or stale table is the
analysis gate's job: ``repro.analysis`` runs :func:`validate_table`
and fails ``make analyze`` loudly (AT001 structure, AT002 invariant,
AT003 duplicate-cell rules), while the serving path degrades to the
heuristic.

On CPU hosts the pallas backend runs in interpret mode, so the table
measures what CPU CI actually executes; re-run ``python -m
repro.kernels.autotune`` on a TPU host to add real-lowering cells (the
table is keyed by platform, entries merge).
"""
from __future__ import annotations

import json
import logging
import os
import time
from typing import Optional

TABLE_VERSION = 1
ENV_VAR = "REPRO_AUTOTUNE_TABLE"

# src/repro/kernels/ -> repo root (the PYTHONPATH=src layout)
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_TABLE_BASENAME = "BENCH_autotune.json"

# tuning sweep: mirrors benchmarks/wallclock.py DECODE/PREFILL_SHAPES
# (the shapes the tracked perf trajectory is measured on)
DECODE_SHAPES = ((1, 1024, 1024), (4, 1024, 1024), (8, 1024, 1024),
                 (16, 1024, 1024))
PREFILL_SHAPES = ((128, 1024, 1024), (256, 512, 1024))

ENTRY_KEYS = ("m", "k", "n", "phase", "platform", "packing", "domain",
              "blocks", "time_s", "heuristic_blocks", "heuristic_time_s")

_LOG = logging.getLogger("repro.kernels.autotune")

# path -> (key -> blocks) mapping; misses logged once per cell
_TABLE_CACHE: dict = {}
_MISSES_LOGGED: set = set()


def table_path() -> str:
    """The table consulted at plan-resolution time: ``$REPRO_AUTOTUNE_TABLE``
    if set (empty string disables the table entirely), else the tracked
    repo-root artifact."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env
    return os.path.join(_REPO_ROOT, DEFAULT_TABLE_BASENAME)


def cell_key(m: int, k: int, n: int, phase: str, platform: str,
             packing: str, domain: str) -> tuple:
    return (int(m), int(k), int(n), str(phase), str(platform),
            str(packing), str(domain))


def validate_table(payload) -> list:
    """Contract check for a (parsed) autotune table.  Returns a list of
    ``(rule, where, message)`` violations:

      * AT001 — structure: top-level/entry shape, key types, enum
        membership (phase/platform/packing/domain);
      * AT002 — invariants: blocks must be the alignments the pallas
        kernels' correctness rests on (bm a sublane multiple for the
        domain, bn/bk lane multiples, trit2 bk byte-whole) and fit the
        double-buffered VMEM budget the selector promises;
      * AT003 — duplicate cell keys (a table with two winners for one
        cell is ambiguous).

    Shared by the runtime loader (violations degrade to the heuristic),
    the analysis pass (violations fail ``make analyze``) and the bench
    schema gate."""
    from .plan import DOMAINS, PACKINGS, PHASES
    from .ternary_matmul import (INT8_SUBLANE, MXU_LANE, SUBLANE,
                                 TRIT2_PER_BYTE, VMEM_BUDGET_BYTES,
                                 _vmem_working_set)
    out = []
    if not isinstance(payload, dict):
        return [("AT001", "table", "payload is not a JSON object")]
    if payload.get("version") != TABLE_VERSION:
        out.append(("AT001", "table",
                    f"version {payload.get('version')!r} != "
                    f"{TABLE_VERSION}"))
    entries = payload.get("entries")
    if not isinstance(entries, list):
        out.append(("AT001", "table", "'entries' is not a list"))
        return out
    seen = {}
    for i, e in enumerate(entries):
        where = f"entries[{i}]"
        if not isinstance(e, dict):
            out.append(("AT001", where, "entry is not an object"))
            continue
        missing = [key for key in ENTRY_KEYS if key not in e]
        if missing:
            out.append(("AT001", where, f"missing keys {missing}"))
            continue
        ok = True
        for key in ("m", "k", "n"):
            if not isinstance(e[key], int) or e[key] < 1:
                out.append(("AT001", where,
                            f"{key}={e[key]!r} is not a positive int"))
                ok = False
        for key, choices in (("phase", PHASES), ("packing", PACKINGS),
                             ("domain", DOMAINS),
                             ("platform", ("cpu", "gpu", "tpu"))):
            if e[key] not in choices:
                out.append(("AT001", where,
                            f"{key}={e[key]!r} not in {sorted(choices)}"))
                ok = False
        for key in ("time_s", "heuristic_time_s"):
            if not isinstance(e[key], (int, float)) or e[key] <= 0:
                out.append(("AT001", where,
                            f"{key}={e[key]!r} is not a positive number"))
                ok = False
        for key in ("blocks", "heuristic_blocks"):
            b = e[key]
            if (not isinstance(b, list) or len(b) != 3
                    or not all(isinstance(v, int) and v > 0 for v in b)):
                out.append(("AT001", where,
                            f"{key}={b!r} is not a [bm, bn, bk] triple "
                            f"of positive ints"))
                ok = False
        if not ok:
            continue
        bm, bn, bk = e["blocks"]
        cell = (f"{where} ({e['m']},{e['k']},{e['n']}) {e['phase']} "
                f"{e['platform']} {e['packing']}/{e['domain']}")
        sublane = INT8_SUBLANE if e["domain"] == "int8" else SUBLANE
        if bm % sublane:
            out.append(("AT002", cell,
                        f"bm={bm} is not a multiple of the "
                        f"{e['domain']} sublane quantum {sublane}"))
        if bn % MXU_LANE:
            out.append(("AT002", cell,
                        f"bn={bn} is not lane-aligned ({MXU_LANE})"))
        if bk % MXU_LANE:
            out.append(("AT002", cell,
                        f"bk={bk} is not lane-aligned ({MXU_LANE})"))
        if e["packing"] == "trit2" and bk % TRIT2_PER_BYTE:
            out.append(("AT002", cell,
                        f"bk={bk} splits the trit2 packed byte"))
        used = _vmem_working_set(bm, bn, bk, e["packing"], e["domain"])
        if used > VMEM_BUDGET_BYTES and bk > MXU_LANE:
            out.append(("AT002", cell,
                        f"working set {used} B exceeds the "
                        f"{VMEM_BUDGET_BYTES} B VMEM budget with "
                        f"bk={bk} above the {MXU_LANE} floor"))
        key = cell_key(e["m"], e["k"], e["n"], e["phase"], e["platform"],
                       e["packing"], e["domain"])
        if key in seen:
            out.append(("AT003", cell,
                        f"duplicate cell (first at "
                        f"entries[{seen[key]}])"))
        else:
            seen[key] = i
    return out


def load_table(path: Optional[str] = None) -> dict:
    """Parse + validate the table at ``path`` into a ``cell_key ->
    (bm, bn, bk)`` mapping.  Missing file -> empty table (every lookup
    is a logged miss).  Invalid table -> empty table with a warning;
    failing loudly on a doctored artifact is ``make analyze``'s job,
    the serving path keeps working on the heuristic."""
    if path is None:
        path = table_path()
    if path in _TABLE_CACHE:
        return _TABLE_CACHE[path]
    table: dict = {}
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            _LOG.warning("autotune table %s unreadable (%s); using the "
                         "select_block_shapes heuristic", path, e)
            payload = None
        if payload is not None:
            violations = validate_table(payload)
            if violations:
                _LOG.warning(
                    "autotune table %s fails validation (%d violations, "
                    "first: %s); using the select_block_shapes heuristic",
                    path, len(violations), violations[0])
            else:
                for e in payload["entries"]:
                    key = cell_key(e["m"], e["k"], e["n"], e["phase"],
                                   e["platform"], e["packing"],
                                   e["domain"])
                    table[key] = tuple(e["blocks"])
    _TABLE_CACHE[path] = table
    return table


def lookup_blocks(m: int, k: int, n: int, phase: str, platform: str,
                  packing: str, domain: str) -> Optional[tuple]:
    """Measured ``(bm, bn, bk)`` for one cell, or None on a miss (the
    caller falls back to ``select_block_shapes``).  Misses are logged
    once per cell — the table's coverage gaps must be visible, not
    silent."""
    key = cell_key(m, k, n, phase, platform, packing, domain)
    blocks = load_table().get(key)
    if blocks is None and key not in _MISSES_LOGGED:
        _MISSES_LOGGED.add(key)
        _LOG.info("autotune table miss for shape=(%d,%d,%d) phase=%s "
                  "platform=%s packing=%s domain=%s; falling back to "
                  "select_block_shapes", m, k, n, phase, platform,
                  packing, domain)
    return blocks


def reload_table() -> None:
    """Drop the cached table (and the resolved plans built from it) so
    the next lookup re-reads ``table_path()`` — tests point
    ``$REPRO_AUTOTUNE_TABLE`` at fixtures and call this."""
    from .plan import plan_cache_clear
    _TABLE_CACHE.clear()
    _MISSES_LOGGED.clear()
    plan_cache_clear()


def canonical_bytes(entries: list) -> str:
    """Canonical JSON text for a set of entries: sorted by cell key,
    sorted keys, fixed indentation — so save -> load -> save is a
    byte-identical round trip (the determinism the persistence tests
    pin)."""
    entries = sorted(entries, key=lambda e: cell_key(
        e["m"], e["k"], e["n"], e["phase"], e["platform"], e["packing"],
        e["domain"]))
    payload = {"version": TABLE_VERSION, "entries": entries}
    return json.dumps(payload, indent=2, sort_keys=True) + "\n"


def save_table(entries: list, path: Optional[str] = None) -> str:
    """Write the canonical table; refuses to persist an invalid one."""
    if path is None:
        path = table_path()
    text = canonical_bytes(list(entries))
    violations = validate_table(json.loads(text))
    if violations:
        raise ValueError(f"refusing to save an invalid autotune table: "
                         f"{violations[0]}")
    with open(path, "w") as f:
        f.write(text)
    return path


def load_entries(path: Optional[str] = None) -> list:
    """The raw entry list at ``path`` (empty for a missing file)."""
    if path is None:
        path = table_path()
    if not (path and os.path.exists(path)):
        return []
    with open(path) as f:
        return json.load(f).get("entries", [])


def candidate_blocks(m: int, k: int, n: int, packing: str,
                     domain: str, limit: int = 8) -> list:
    """Aligned, VMEM-feasible candidate tiles for one cell: the
    heuristic choice first (the fallback must always be in the race),
    then lane/sublane-aligned variations over each axis."""
    from .ternary_matmul import (INT8_SUBLANE, MXU_LANE, SUBLANE,
                                 TRIT2_PER_BYTE, VMEM_BUDGET_BYTES,
                                 _round_up, _vmem_working_set,
                                 select_block_shapes)
    kdim = k + (-k % TRIT2_PER_BYTE) if packing == "trit2" else k
    heur = tuple(select_block_shapes(m, kdim, n, packing, domain=domain))
    sublane = INT8_SUBLANE if domain == "int8" else SUBLANE
    bm_opts = {heur[0], min(_round_up(m, sublane), 128)}
    bn_opts, bk_opts = {heur[1]}, {heur[2]}
    for c in (128, 256, 512):
        if c <= _round_up(n, MXU_LANE):
            bn_opts.add(c)
        if c <= _round_up(kdim, MXU_LANE):
            bk_opts.add(c)
    cands = []
    for bm in sorted(bm_opts):
        for bn in sorted(bn_opts):
            for bk in sorted(bk_opts):
                if (bm, bn, bk) == heur:
                    continue
                used = _vmem_working_set(bm, bn, bk, packing, domain)
                if used > VMEM_BUDGET_BYTES and bk > MXU_LANE:
                    continue
                cands.append((bm, bn, bk))
    return [heur] + cands[:max(0, limit - 1)]


def _time_best(fn, *args, iters: int = 3) -> float:
    import jax
    jax.block_until_ready(fn(*args))        # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_cell(m: int, k: int, n: int, phase: str, packing: str,
                 domain: str, iters: int = 3,
                 candidate_limit: int = 8) -> dict:
    """Race the candidate tiles through the real pallas execute path
    (jitted, same operand recipe as benchmarks/wallclock.py) and return
    the winning entry for this cell on the current platform."""
    import functools

    import jax
    import jax.numpy as jnp

    from . import ops
    from .plan import _platform, execute, plan_matmul

    platform = _platform()
    key = jax.random.key((m * 1_000_003 + k * 9176 + n) & 0x7FFFFFFF)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = 0.02 * jax.random.normal(kw, (k, n), jnp.float32)
    pw = ops.pack_weights(w, packing)

    timings = []
    cands = candidate_blocks(m, k, n, packing, domain,
                             limit=candidate_limit)
    for bm, bn, bk in cands:
        plan = plan_matmul((m, k, n), phase, backend="pallas",
                           packing=packing, domain=domain,
                           bm=bm, bn=bn, bk=bk)
        step = jax.jit(functools.partial(execute, plan))
        timings.append(((bm, bn, bk), _time_best(step, x, pw,
                                                 iters=iters)))
    (hblocks, htime) = timings[0]           # heuristic ran first
    blocks, best = min(timings, key=lambda t: t[1])
    return {"m": m, "k": k, "n": n, "phase": phase,
            "platform": platform, "packing": packing, "domain": domain,
            "blocks": list(blocks), "time_s": best,
            "heuristic_blocks": list(hblocks),
            "heuristic_time_s": htime}


def tune(fast: bool = False, iters: int = 3, verbose: bool = False,
         merge_with: Optional[list] = None) -> list:
    """Measure every ``(shape, phase, packing, domain)`` cell of the
    wallclock sweep on the current platform; returns the merged entry
    list (existing entries for OTHER platforms/cells are kept, this
    platform's sweep cells are replaced by fresh measurements)."""
    from .plan import DOMAINS, PACKINGS
    decode = DECODE_SHAPES[:2] if fast else DECODE_SHAPES
    prefill = PREFILL_SHAPES[:1] if fast else PREFILL_SHAPES
    limit = 4 if fast else 8
    cells = ([(s, "decode") for s in decode]
             + [(s, "prefill") for s in prefill])
    fresh = []
    for (m, k, n), phase in cells:
        for packing in PACKINGS:
            for domain in DOMAINS:
                entry = measure_cell(m, k, n, phase, packing, domain,
                                     iters=iters, candidate_limit=limit)
                fresh.append(entry)
                if verbose:
                    speedup = (entry["heuristic_time_s"]
                               / entry["time_s"])
                    print(f"  ({m},{k},{n}) {phase} {packing}/{domain}: "
                          f"{tuple(entry['blocks'])} "
                          f"{entry['time_s'] * 1e3:.3f} ms "
                          f"(heuristic {tuple(entry['heuristic_blocks'])}"
                          f" x{speedup:.2f})")
    fresh_keys = {cell_key(e["m"], e["k"], e["n"], e["phase"],
                           e["platform"], e["packing"], e["domain"])
                  for e in fresh}
    kept = [e for e in (merge_with or [])
            if cell_key(e["m"], e["k"], e["n"], e["phase"],
                        e["platform"], e["packing"],
                        e["domain"]) not in fresh_keys]
    return kept + fresh


def main(argv: Optional[list] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune",
        description="Measure (bm, bn, bk) tiles per wallclock-sweep "
                    "cell and persist the table plan resolution "
                    "consults.")
    p.add_argument("--out", default=None,
                   help="table path (default: the tracked repo-root "
                        "artifact, or $REPRO_AUTOTUNE_TABLE)")
    p.add_argument("--fast", action="store_true",
                   help="reduced sweep/candidates (CI smoke)")
    p.add_argument("--iters", type=int, default=3)
    args = p.parse_args(argv)

    out = args.out or table_path()
    existing = load_entries(out)
    entries = tune(fast=args.fast, iters=args.iters, verbose=True,
                   merge_with=existing)
    save_table(entries, out)
    print(f"wrote {len(entries)} entries -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
