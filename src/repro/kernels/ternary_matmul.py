"""Pallas TPU kernel: packed-ternary weight matmul with VMEM dequant-on-load.

The TPU image of the paper's density + DC-free-restore mechanism
(DESIGN.md §2): weights live in HBM in a packed ternary format, are
unpacked *inside* the kernel's VMEM tiles (the "restore"), and feed the
MXU in bf16/f32.  No dequantized copy of the weights ever exists in HBM.

Packing modes
  base3  — one uint8 per 5-trit weight (value+121; decode = subtract).
           Paper-faithful precision (Table 3), 2x denser than bf16.
  trit2  — four 1-trit weights per uint8 (2-bit fields).  Pure-ternary
           mode, 8x denser than bf16; the memory-roofline option for
           weight-bound decode shapes.

Grid: (M/bm, N/bn, K/bk), K innermost for in-place accumulation.
BlockSpecs keep x:(bm,bk), w:(bk|bk/4, bn), out:(bm,bn) in VMEM.  Block
shapes default to a shape-adaptive choice (:func:`select_block_shapes`):
128/128/512 for prefill-sized M, and a skinny-M variant for decode
(bm = next sublane multiple >= M, deeper bk) so a batch-8 decode step
does not pad M 16x up to the MXU tile.  Per-output-column scales are
applied once on the final K step.

Two arithmetic domains:
  float — dequant to f32 in VMEM, f32 MXU dot (the default; bit-matches
          the unpack-then-matmul oracle).
  int8  — ``ternary_matmul_int8``: activations arrive pre-quantized to
          int8 (per-row scales), weights decode to int8 in VMEM, the MXU
          runs an int8 x int8 -> int32 dot and ALL float scaling is
          deferred to the epilogue.  Integer accumulation is exact, so
          pallas == xla == oracle bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TRIT2_PER_BYTE = 4
BASE3_OFFSET = 121  # trit_range(5)

MXU_LANE = 128            # last-dim tile (all dtypes)
SUBLANE = 8               # f32 second-to-last-dim tile
INT8_SUBLANE = 32         # int8 second-to-last-dim tile
DEFAULT_BLOCKS = (128, 128, 512)
SKINNY_BK = 1024          # deeper K tile for decode shapes
VMEM_BUDGET_BYTES = 8 * 2**20   # half of 16MB: leave room for double-buffer


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _vmem_working_set(bm: int, bn: int, bk: int, mode: str,
                      domain: str = "float") -> int:
    """Per-step VMEM bytes of the BlockSpecs (x/w double-buffered)."""
    x_tile = bm * bk * (1 if domain == "int8" else 4)
    w_tile = (bk // TRIT2_PER_BYTE if mode == "trit2" else bk) * bn
    return 2 * (x_tile + w_tile) + 2 * bm * bn * 4 + bm * bn * 4 + bn * 4


def select_block_shapes(m: int, kdim: int, n: int, mode: str = "base3", *,
                        domain: str = "float",
                        vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                        ) -> tuple[int, int, int]:
    """Pick (bm, bn, bk) from the actual problem shape.

    Prefill-sized M keeps the MXU-square 128/128/512 tiles.  Decode /
    skinny M (< 128) shrinks bm to the next sublane multiple >= M — a
    batch-8 decode step then pads M 1x instead of 16x — and spends the
    freed VMEM on a deeper K tile so each weight DMA streams more of the
    reduction.  The sublane quantum and the x-tile byte width follow the
    arithmetic domain (f32: 8-row tiles, 4 B/elt; int8: 32-row tiles,
    1 B/elt).  bn/bk stay lane-aligned (128 multiples, so the trit2
    packed tile bk/4 stays whole); bk is clamped to the padded K extent
    and halved until the double-buffered working set fits the budget.
    """
    sublane = INT8_SUBLANE if domain == "int8" else SUBLANE
    bm_full, bn_full, bk_full = DEFAULT_BLOCKS
    if m >= bm_full:
        bm, bk = bm_full, bk_full
    else:
        bm = _round_up(max(m, 1), sublane)
        bk = SKINNY_BK
    bn = bn_full
    bk = min(bk, _round_up(kdim, MXU_LANE))
    while bk > MXU_LANE and _vmem_working_set(bm, bn, bk, mode,
                                              domain) > vmem_budget_bytes:
        bk = _round_up(bk // 2, MXU_LANE)   # keep the lane alignment
    return bm, bn, bk


def _decode_w(w_packed: jax.Array, mode: str, dtype) -> jax.Array:
    """uint8 packed tile -> (bk, bn) weight values in `dtype`.

    base3: [-121, 121] via a single subtract; trit2: {-1, 0, +1} from the
    2-bit fields (4 trits/byte).  All decoded values are small integers,
    so the float and int8 domains decode through the same exact path.
    """
    if mode == "base3":
        return (w_packed.astype(jnp.int32) - BASE3_OFFSET).astype(dtype)
    kp, bn = w_packed.shape
    fields = [(w_packed >> (2 * i)) & 0x3 for i in range(TRIT2_PER_BYTE)]
    codes = jnp.stack(fields, axis=1)                    # (bk/4, 4, bn)
    vals = (codes == 1).astype(dtype) - (codes == 2).astype(dtype)
    return vals.reshape(kp * TRIT2_PER_BYTE, bn)


def _checked_dims(x: jax.Array, w_packed: jax.Array,
                  mode: str) -> tuple[int, int, int]:
    """Validate x/w packing agreement; returns (M, K, N)."""
    m, kdim = x.shape
    kw, n = w_packed.shape
    if mode == "base3":
        assert kw == kdim, (kw, kdim)
    elif mode == "trit2":
        assert kw * TRIT2_PER_BYTE == kdim, (kw, kdim)
    else:
        raise ValueError(f"unknown packing mode {mode!r}; expected one of "
                         f"['base3', 'trit2']")
    return m, kdim, n


def _pad_to_blocks(x, w_packed, scale, mode: str, bm: int, bn: int, bk: int):
    """Pad operands to block multiples.  x pads with zeros; w pads with
    the byte that decodes to 0 so padded K rows contribute nothing."""
    m, kdim = x.shape
    n = w_packed.shape[1]
    mp, np_, kp = (-m % bm), (-n % bn), (-kdim % bk)
    if mp or kp:
        x = jnp.pad(x, ((0, mp), (0, kp)))
    if np_ or kp:
        kw_pad = kp if mode == "base3" else kp // TRIT2_PER_BYTE
        pad_val = BASE3_OFFSET if mode == "base3" else 0  # decode -> 0
        w_packed = jnp.pad(w_packed, ((0, kw_pad), (0, np_)),
                           constant_values=pad_val)
    if np_:
        scale = jnp.pad(scale, (0, np_))
    return x, w_packed, scale, mp


def _kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, mode: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_w(w_ref[...], mode, jnp.float32)         # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)                   # (bm, bk)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "interpret", "out_dtype"))
def ternary_matmul(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                   *, mode: str = "base3", bm: int | None = None,
                   bn: int | None = None, bk: int | None = None,
                   interpret: bool = False,
                   out_dtype=jnp.float32) -> jax.Array:
    """y[m,n] = sum_k x[m,k] * decode(w_packed)[k,n] * scale[n].

    x: (M, K) float; w_packed: (K, N) uint8 [base3] or (K/4, N) uint8
    [trit2]; scale: (N,) float (per-column) or scalar broadcastable.
    Block shapes default to the shape-adaptive choice; pass bm/bn/bk to
    pin them (tests, sweeps).
    """
    m, kdim, n = _checked_dims(x, w_packed, mode)
    abm, abn, abk = select_block_shapes(m, kdim, n, mode)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    scale = jnp.broadcast_to(jnp.asarray(scale, x.dtype).reshape(-1), (n,))
    x, w_packed, scale, _ = _pad_to_blocks(x, w_packed, scale, mode,
                                           bm, bn, bk)
    mt, nt, kt = x.shape[0] // bm, w_packed.shape[1] // bn, x.shape[1] // bk
    bkw = bk if mode == "base3" else bk // TRIT2_PER_BYTE

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode, nk=kt),
        grid=(mt, nt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkw, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w_packed.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scale)
    return out[:m, :n]


# ------------------------------------------------------------ int8 domain

def _kernel_int8(x_ref, xs_ref, w_ref, scale_ref, o_ref, acc_ref, *,
                 mode: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_w(w_ref[...], mode, jnp.int8)            # (bk, bn) int8
    x = x_ref[...]                                       # (bm, bk) int8
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * xs_ref[...].astype(jnp.float32)[:, None]
                      * scale_ref[...].astype(jnp.float32)[None, :]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "interpret", "out_dtype"))
def ternary_matmul_int8(x_int: jax.Array, x_scale: jax.Array,
                        w_packed: jax.Array, scale: jax.Array, *,
                        mode: str = "trit2", bm: int | None = None,
                        bn: int | None = None, bk: int | None = None,
                        interpret: bool = False,
                        out_dtype=jnp.float32) -> jax.Array:
    """Int-domain variant: y[m,n] = (sum_k x_int[m,k] * decode(w)[k,n])
    * x_scale[m] * scale[n], accumulated in int32 on the MXU.

    x_int: (M, K) int8 (pre-quantized activations); x_scale: (M,) f32
    per-row dequant scales; w_packed/scale as in :func:`ternary_matmul`.
    The integer accumulation is exact, so results bit-match the
    int-domain oracle regardless of blocking.
    """
    assert x_int.dtype == jnp.int8, x_int.dtype
    m, kdim, n = _checked_dims(x_int, w_packed, mode)
    abm, abn, abk = select_block_shapes(m, kdim, n, mode, domain="int8")
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(-1), (n,))
    x_scale = jnp.broadcast_to(jnp.asarray(x_scale, jnp.float32).reshape(-1),
                               (m,))
    x_int, w_packed, scale, mp = _pad_to_blocks(x_int, w_packed, scale,
                                                mode, bm, bn, bk)
    if mp:
        x_scale = jnp.pad(x_scale, (0, mp))
    mt, nt, kt = (x_int.shape[0] // bm, w_packed.shape[1] // bn,
                  x_int.shape[1] // bk)
    bkw = bk if mode == "base3" else bk // TRIT2_PER_BYTE

    out = pl.pallas_call(
        functools.partial(_kernel_int8, mode=mode, nk=kt),
        grid=(mt, nt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bkw, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_int.shape[0], w_packed.shape[1]),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_int, x_scale, w_packed, scale)
    return out[:m, :n]
