"""Pallas TPU kernel: packed-ternary weight matmul with VMEM dequant-on-load.

The TPU image of the paper's density + DC-free-restore mechanism
(DESIGN.md §2): weights live in HBM in a packed ternary format, are
unpacked *inside* the kernel's VMEM tiles (the "restore"), and feed the
MXU in bf16/f32.  No dequantized copy of the weights ever exists in HBM.

Packing modes
  base3  — one uint8 per 5-trit weight (value+121; decode = subtract).
           Paper-faithful precision (Table 3), 2x denser than bf16.
  trit2  — four 1-trit weights per uint8 (2-bit fields).  Pure-ternary
           mode, 8x denser than bf16; the memory-roofline option for
           weight-bound decode shapes.

Grid: (M/bm, N/bn, K/bk), K innermost for in-place accumulation.
BlockSpecs keep x:(bm,bk), w:(bk|bk/4, bn), out:(bm,bn) in VMEM; bm/bn/bk
default to MXU-aligned 128 multiples.  Per-output-column scales are
applied once on the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TRIT2_PER_BYTE = 4
BASE3_OFFSET = 121  # trit_range(5)


def _decode_base3(w_packed: jax.Array) -> jax.Array:
    """uint8 (bk, bn) -> f32 in [-121, 121]: a single subtract."""
    return w_packed.astype(jnp.float32) - float(BASE3_OFFSET)


def _decode_trit2(w_packed: jax.Array) -> jax.Array:
    """uint8 (bk/4, bn) -> f32 (bk, bn) in {-1, 0, +1}."""
    kp, bn = w_packed.shape
    fields = [(w_packed >> (2 * i)) & 0x3 for i in range(TRIT2_PER_BYTE)]
    codes = jnp.stack(fields, axis=1)                    # (bk/4, 4, bn)
    vals = (codes == 1).astype(jnp.float32) - (codes == 2).astype(jnp.float32)
    return vals.reshape(kp * TRIT2_PER_BYTE, bn)


def _kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, mode: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    decode = _decode_base3 if mode == "base3" else _decode_trit2
    w = decode(w_ref[...])                               # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)                   # (bm, bk)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "interpret", "out_dtype"))
def ternary_matmul(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                   *, mode: str = "base3", bm: int = 128, bn: int = 128,
                   bk: int = 512, interpret: bool = False,
                   out_dtype=jnp.float32) -> jax.Array:
    """y[m,n] = sum_k x[m,k] * decode(w_packed)[k,n] * scale[n].

    x: (M, K) float; w_packed: (K, N) uint8 [base3] or (K/4, N) uint8
    [trit2]; scale: (N,) float (per-column) or scalar broadcastable.
    """
    m, kdim = x.shape
    if mode == "base3":
        kw, n = w_packed.shape
        assert kw == kdim, (kw, kdim)
    elif mode == "trit2":
        kw, n = w_packed.shape
        assert kw * TRIT2_PER_BYTE == kdim, (kw, kdim)
    else:
        raise ValueError(mode)
    scale = jnp.broadcast_to(jnp.asarray(scale, x.dtype).reshape(-1), (n,))

    # pad to block multiples
    mp, np_, kp = (-m % bm), (-n % bn), (-kdim % bk)
    if mp or kp:
        x = jnp.pad(x, ((0, mp), (0, kp)))
    if np_ or kp:
        kw_pad = kp if mode == "base3" else kp // TRIT2_PER_BYTE
        pad_val = BASE3_OFFSET if mode == "base3" else 0  # decode -> 0
        w_packed = jnp.pad(w_packed, ((0, kw_pad), (0, np_)),
                           constant_values=pad_val)
    if np_:
        scale = jnp.pad(scale, (0, np_))
    mt, nt, kt = x.shape[0] // bm, w_packed.shape[1] // bn, x.shape[1] // bk
    bkw = bk if mode == "base3" else bk // TRIT2_PER_BYTE

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode, nk=kt),
        grid=(mt, nt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkw, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w_packed.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scale)
    return out[:m, :n]
