"""Pallas TPU kernel: macro-exact ternary CIM MAC with per-row-group ADC.

Bit-exact image of the TL-nvSRAM-CIM array (core/cim.py is the jnp
oracle): K is consumed in 16-row groups; each group's integer partial sum
per (input-trit i, weight-trit j) plane pair is pushed through the 5-bit
ADC transfer (count-domain clip -> MAC clip to [rows-2^b+1, rows]) before
the shift-&-add combines planes with powers of 3.

Zero-padding K to a multiple of 16 is exact: a partial group of r < 16
real rows yields |MAC| <= r <= 15, inside the clip window [-15, 16], so
the ADC never saturates on padded groups (see tests/test_kernels.py).

Grid: (M/bm, N/bn, K/bk); bk is a multiple of ROWS_PER_GROUP; the trit
planes ride inside the block (qi, bm, bk) / (qw, bk, bn) and the i/j/group
loops are unrolled in the kernel body (qi, qw <= 5, groups = bk/16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS_PER_GROUP = 16


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, adc_bits: int, nk: int,
            qi: int, qw: int, groups: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = ROWS_PER_GROUP - 2**adc_bits + 1   # -15 for the 5-bit ADC
    hi = ROWS_PER_GROUP                     # +16
    acc = acc_ref[...]
    for i in range(qi):
        for j in range(qw):
            w3 = 3 ** (i + j)
            for g in range(groups):
                s = slice(g * ROWS_PER_GROUP, (g + 1) * ROWS_PER_GROUP)
                xg = x_ref[i, :, s].astype(jnp.float32)   # (bm, 16)
                wg = w_ref[j, s, :].astype(jnp.float32)   # (16, bn)
                # per-group MAC is exact in f32 (|mac| <= 16); the shifted
                # accumulation must be int32 (3^8 * 16 * groups > 2^24).
                mac = jax.lax.dot(xg, wg, preferred_element_type=jnp.float32)
                acc += w3 * jnp.clip(mac, lo, hi).astype(jnp.int32)
    acc_ref[...] = acc

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("adc_bits", "bm", "bn", "bk",
                                             "interpret"))
def cim_mac(x_trits: jax.Array, w_trits: jax.Array, *, adc_bits: int = 5,
            bm: int = 128, bn: int = 128, bk: int = 128,
            interpret: bool = False) -> jax.Array:
    """(qi, M, K) int8 x (qw, K, N) int8 -> (M, N) int32 CIM MAC.

    Matches core.cim.cim_matmul_int (same ADC semantics) while tiling for
    the MXU; the 16-wide group dots underutilize the MXU by design — this
    kernel's job is bit-exact accuracy evaluation at speed, not peak FLOPs
    (use ternary_matmul for the production fast path)."""
    assert bk % ROWS_PER_GROUP == 0
    qi, m, kdim = x_trits.shape
    qw, k2, n = w_trits.shape
    assert kdim == k2
    mp, np_, kp = (-m % bm), (-n % bn), (-kdim % bk)
    if mp or kp:
        x_trits = jnp.pad(x_trits, ((0, 0), (0, mp), (0, kp)))
    if np_ or kp:
        w_trits = jnp.pad(w_trits, ((0, 0), (0, kp), (0, np_)))
    mt, nt, kt = x_trits.shape[1] // bm, w_trits.shape[2] // bn, x_trits.shape[2] // bk

    out = pl.pallas_call(
        functools.partial(_kernel, adc_bits=adc_bits, nk=kt, qi=qi, qw=qw,
                          groups=bk // ROWS_PER_GROUP),
        grid=(mt, nt, kt),
        in_specs=[
            pl.BlockSpec((qi, bm, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((qw, bk, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_trits.shape[1], w_trits.shape[2]),
                                       jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_trits, w_trits)
    return out[:m, :n]
