"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cim as cim_core
from repro.core.packing import unpack_base3, unpack_trits2


def ternary_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                       mode: str = "base3") -> jax.Array:
    """Oracle for kernels.ternary_matmul: unpack-then-matmul in fp32."""
    if mode == "base3":
        w = unpack_base3(w_packed).astype(jnp.float32)
    elif mode == "trit2":
        w = unpack_trits2(w_packed).astype(jnp.float32)
    else:
        raise ValueError(f"unknown packing mode {mode!r}; expected one of "
                         f"['base3', 'trit2']")
    y = x.astype(jnp.float32) @ w
    return y * jnp.asarray(scale, jnp.float32)


def ternary_matmul_int8_ref(x_int: jax.Array, x_scale: jax.Array,
                            w_packed: jax.Array, scale: jax.Array,
                            mode: str = "trit2") -> jax.Array:
    """Oracle for the int-domain fast lane: exact int32 accumulation of
    pre-quantized int8 activations against the unpacked weight, every
    float scale applied in the epilogue in the kernel's order."""
    if mode == "base3":
        w = unpack_base3(w_packed)                       # int32
    elif mode == "trit2":
        w = unpack_trits2(w_packed, k=x_int.shape[-1]).astype(jnp.int32)
    else:
        raise ValueError(f"unknown packing mode {mode!r}; expected one of "
                         f"['base3', 'trit2']")
    acc = x_int.astype(jnp.int32) @ w
    return (acc.astype(jnp.float32)
            * jnp.asarray(x_scale, jnp.float32)[..., None]
            * jnp.broadcast_to(jnp.asarray(scale, jnp.float32),
                               (w.shape[-1],))[None, :])


def cim_mac_ref(x_trits: jax.Array, w_trits: jax.Array,
                adc_bits: int = 5) -> jax.Array:
    """Oracle for kernels.cim_mac: the core functional macro model.

    core.cim.cim_matmul_int operates on (q, B, K) x (q, K, N); the kernel
    uses (q, M, K) x (q, K, N) — same layout, direct call."""
    cfg = cim_core.MacroConfig(adc_bits=adc_bits)
    return cim_core.cim_matmul_int(x_trits, w_trits, cfg)
