"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cim as cim_core
from repro.core.packing import unpack_base3, unpack_trits2


def ternary_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array,
                       mode: str = "base3") -> jax.Array:
    """Oracle for kernels.ternary_matmul: unpack-then-matmul in fp32."""
    if mode == "base3":
        w = unpack_base3(w_packed).astype(jnp.float32)
    elif mode == "trit2":
        w = unpack_trits2(w_packed).astype(jnp.float32)
    else:
        raise ValueError(mode)
    y = x.astype(jnp.float32) @ w
    return y * jnp.asarray(scale, jnp.float32)


def cim_mac_ref(x_trits: jax.Array, w_trits: jax.Array,
                adc_bits: int = 5) -> jax.Array:
    """Oracle for kernels.cim_mac: the core functional macro model.

    core.cim.cim_matmul_int operates on (q, B, K) x (q, K, N); the kernel
    uses (q, M, K) x (q, K, N) — same layout, direct call."""
    cfg = cim_core.MacroConfig(adc_bits=adc_bits)
    return cim_core.cim_matmul_int(x_trits, w_trits, cfg)
