"""Fused paged-attention executor: decode reads straight off the page pool.

The paged serving path (PR 5) stores KV in a block pool of
``(page, page_size, KV, hd)`` pages plus a per-slot page table; until
now every decode read first gathered the live pages back into a dense
``(slots, capacity, KV, hd)`` copy and ran dense attention over it —
re-materializing exactly the traffic the paged layout exists to avoid.

This module registers attention as a planned op: the Pallas kernel
consumes the page table *in-kernel* through scalar-prefetch BlockSpec
index maps — grid step ``(s, w)`` DMAs page ``page_table[s, w]`` of the
pool directly into VMEM, so the gathered dense copy is never built.
Page 0 is the pool's reserved null page: table rows are padded with 0,
and the positional mask (``kpos >= pos`` -> -1e30, the same identity
the dense read uses) provably zeroes whatever the null page holds —
``exp(-1e30 - m)`` underflows to exactly 0.0 in f32 once any real key
has been seen, and slots with no live context report ``m = -1e30,
l = 0`` which the caller's new-token merge renormalizes away.

The kernel runs the pool in per-page streaming (online-softmax) order
and returns the *partial* flash statistics ``(acc, m, l)`` rather than
a normalized output: the caller merges the current step's own (not yet
appended) KV with the standard two-block rule, exactly as the dense
``decode_attention_read`` does, so token parity against the gather
path is bitwise at the argmax.

Two backends register under the ExecutionPlan registry (never kwargs):

  * ``paged_attn``    — this Pallas kernel (interpret-mode on CPU CI,
    real lowering on TPU), priority 100;
  * ``paged_attn_ref`` — a gather-based XLA oracle computing the same
    statistics with global (single-pass) softmax, priority 10.

Both declare ``ops={'attention'}``, ``kv_layouts={'paged'}``,
``domains={'float'}`` (int8-KV pools carry scale pages the fused path
does not read yet — the scheduler falls back to the gather path there).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30          # the dense read's masking constant (attention.py)


class PagedAttentionKV(NamedTuple):
    """The raw page-pool view one attention layer reads: no gathered
    copy, just the pool pages plus the routing state.  A registered
    pytree (NamedTuple), so it flows through jit/scan/vmap; its
    ``shape`` property makes it a valid ``execute()`` weight operand —
    the plan shape is ``(S*KV*rep, hd, W*page_size)``: queries times
    head dim against the per-slot context capacity.

    Fields::

      k_pages, v_pages : (num_pages, page_size, KV, hd)  one layer's pool
      page_table       : (S, W) int32   pool page id per slot x window
      pos              : (S,) int32     live context length per slot
    """
    k_pages: jax.Array
    v_pages: jax.Array
    page_table: jax.Array
    pos: jax.Array

    @property
    def shape(self) -> tuple:
        # (K, N) of the weight operand: shape_of(q, kv) must equal the
        # plan's (M, K, N) = (S*KV*rep, hd, W*ps)
        return (int(self.k_pages.shape[-1]),
                int(self.page_table.shape[-1])
                * int(self.k_pages.shape[-3]))


def _dims(q, kv) -> tuple:
    if q.ndim != 4:
        raise ValueError(f"paged attention takes q (slots, KV, rep, hd); "
                         f"got ndim={q.ndim}")
    s, kvh, rep, hd = (int(d) for d in q.shape)
    num_pages, ps, kvh_p, hd_p = (int(d) for d in kv.k_pages.shape)
    w = int(kv.page_table.shape[-1])
    if (kvh_p, hd_p) != (kvh, hd) or kv.v_pages.shape != kv.k_pages.shape:
        raise ValueError(f"page pool {kv.k_pages.shape}/"
                         f"{kv.v_pages.shape} does not match q "
                         f"{q.shape}")
    if int(kv.page_table.shape[0]) != s or int(kv.pos.shape[0]) != s:
        raise ValueError(f"page table {kv.page_table.shape} / pos "
                         f"{kv.pos.shape} do not cover {s} slots")
    return s, kvh, rep, hd, num_pages, ps, w


def _fused_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref,
                  acc_ref, m_ref, l_ref, m_s, l_s, acc_s, *,
                  page_size: int, last_w: int):
    """One grid step = one (slot, page-window) cell.  ``k_ref``/``v_ref``
    hold page ``page_table[s, w]`` (the index map did the routing); the
    VMEM scratch carries the online-softmax state across the w axis."""
    s = pl.program_id(0)
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_s[...] = jnp.full(m_s.shape, -jnp.inf, m_s.dtype)
        l_s[...] = jnp.zeros(l_s.shape, l_s.dtype)
        acc_s[...] = jnp.zeros(acc_s.shape, acc_s.dtype)

    q = q_ref[0].astype(jnp.float32)                    # (KV, rep, hd)
    k = k_ref[0].astype(jnp.float32)                    # (ps, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    sc = jnp.einsum("krd,tkd->krt", q, k,
                    preferred_element_type=jnp.float32)
    kpos = w * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, page_size), 2)
    sc = jnp.where(kpos < pos_ref[s], sc, NEG_INF)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=-1))
    p = jnp.exp(sc - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=-1)
    acc_s[...] = acc_s[...] * corr[..., None] + jnp.einsum(
        "krt,tkd->krd", p, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(w == last_w)
    def _flush():
        acc_ref[0] = acc_s[...]
        m_ref[0] = m_s[...]
        l_ref[0] = l_s[...]


def paged_attention(q, kv: PagedAttentionKV, *,
                    interpret: bool = False) -> tuple:
    """Flash statistics of ``q`` against the paged context: returns
    ``(acc, m, l)`` with shapes ``(S, KV, rep, hd)`` / ``(S, KV, rep)``
    x2, all f32; ``out = acc / l[..., None]`` after the caller's
    new-token merge."""
    s, kvh, rep, hd, num_pages, ps, w = _dims(q, kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # (page_table, pos)
        grid=(s, w),
        in_specs=[
            pl.BlockSpec((1, kvh, rep, hd),
                         lambda i, j, pt, pos: (i, 0, 0, 0)),
            pl.BlockSpec((1, ps, kvh, hd),
                         lambda i, j, pt, pos: (pt[i, j], 0, 0, 0)),
            pl.BlockSpec((1, ps, kvh, hd),
                         lambda i, j, pt, pos: (pt[i, j], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kvh, rep, hd),
                         lambda i, j, pt, pos: (i, 0, 0, 0)),
            pl.BlockSpec((1, kvh, rep),
                         lambda i, j, pt, pos: (i, 0, 0)),
            pl.BlockSpec((1, kvh, rep),
                         lambda i, j, pt, pos: (i, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((kvh, rep), jnp.float32),
            pltpu.VMEM((kvh, rep), jnp.float32),
            pltpu.VMEM((kvh, rep, hd), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_fused_kernel, page_size=ps, last_w=w - 1),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, kvh, rep, hd), jnp.float32),
            jax.ShapeDtypeStruct((s, kvh, rep), jnp.float32),
            jax.ShapeDtypeStruct((s, kvh, rep), jnp.float32),
        ],
        interpret=interpret,
    )
    acc, m, l = fn(kv.page_table, kv.pos, q, kv.k_pages, kv.v_pages)
    return acc, m, l


def paged_attention_ref(q, kv: PagedAttentionKV) -> tuple:
    """Gather-based XLA oracle: materializes the dense copy the fused
    kernel avoids, computes the same ``(acc, m, l)`` statistics with a
    global (single-pass) softmax.  ``m`` matches the kernel bitwise;
    ``acc``/``l`` to f32 round-off (summation order differs)."""
    s, kvh, rep, hd, num_pages, ps, w = _dims(q, kv)
    kg = kv.k_pages[kv.page_table].reshape(s, w * ps, kvh, hd)
    vg = kv.v_pages[kv.page_table].reshape(s, w * ps, kvh, hd)
    q32 = q.astype(jnp.float32)
    sc = jnp.einsum("skrd,stkd->skrt", q32, kg.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    valid = jnp.arange(w * ps, dtype=jnp.int32)[None, :] < kv.pos[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    m = sc.max(axis=-1)
    p = jnp.exp(sc - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("skrt,stkd->skrd", p, vg.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return acc, m, l


def _check_operand(plan, x, w) -> None:
    if not isinstance(w, PagedAttentionKV):
        raise ValueError(f"attention plans take a PagedAttentionKV "
                         f"weight operand; got {type(w).__name__}")
    if plan.kv_layout != "paged":
        raise ValueError(f"backend {plan.backend!r} only reads the "
                         f"paged layout; plan has {plan.kv_layout!r}")


def run_pallas(plan, x, w):
    _check_operand(plan, x, w)
    return paged_attention(x, w, interpret=plan.interpret)


def run_gather(plan, x, w):
    _check_operand(plan, x, w)
    return paged_attention_ref(x, w)


EVAL_PAGE_SIZE = 8


def eval_operands(shape) -> tuple:
    """Abstract ``(q, PagedAttentionKV)`` operands whose ``shape_of``
    matches plan shape ``(m, k, n)`` — factored as S=m single-KV-head
    queries of head dim k over n context slots (the capability pass
    pushes these through ``jax.eval_shape``)."""
    m, k, n = (int(v) for v in shape)
    ps = EVAL_PAGE_SIZE if n % EVAL_PAGE_SIZE == 0 else 1
    w = n // ps
    q = jax.ShapeDtypeStruct((m, 1, 1, k), jnp.float32)
    pages = jax.ShapeDtypeStruct((w + 1, ps, 1, k), jnp.float32)
    kv = PagedAttentionKV(
        pages, pages,
        jax.ShapeDtypeStruct((m, w), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.int32))
    return q, kv


def eval_output(shape) -> tuple:
    """Expected ``(acc, m, l)`` shapes for :func:`eval_operands`."""
    m, k, n = (int(v) for v in shape)
    return ((m, 1, 1, k), (m, 1, 1), (m, 1, 1))
