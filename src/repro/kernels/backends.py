"""Built-in execution backends for the plan/registry API.

Each backend declares its capabilities (ops, arithmetic domains, packing
modes, platforms) through :class:`plan.BackendSpec` and provides one
``runner(plan, x, w)``.  The runner bodies are the exact dispatch paths
the pre-plan ``ops.ternary_matmul`` / ``ternary_matmul_int8`` /
``cim_matmul`` wrappers ran, so migrated call sites stay bitwise
identical to the old kwarg routing (pinned in tests/test_fastlane.py).

  pallas — kernels/ternary_matmul.py + kernels/cim_mac.py (VMEM
           dequant-on-load); the real TPU path, interpret mode on CPU.
           Block-tiled: the plan carries the resolved (bm, bn, bk).
  xla    — fused jnp dequant + dot.  The dry-run backend (Pallas TPU
           kernels cannot lower on the CPU host platform); handles
           layer-stacked weights.
  ref    — the pure-jnp oracles from kernels/ref.py, exposed as a
           backend so parity harnesses sweep (pallas, xla, ref) through
           one execute() call.  Lowest priority: never auto-selected
           while a production backend is capable.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ops, ref
from . import cim_mac as _cim_mac_kernel
from . import ternary_matmul as _tm_kernel
from .plan import BackendSpec, register_backend

TRIT2_PER_BYTE = _tm_kernel.TRIT2_PER_BYTE


def _maybe_pad_trit2_k(x2, mode):
    """trit2 packing pads K to a byte multiple; zero-pad x to match."""
    k = x2.shape[-1]
    if mode == "trit2" and k % TRIT2_PER_BYTE:
        return jnp.pad(x2, ((0, 0), (0, -k % TRIT2_PER_BYTE)))
    return x2


# ------------------------------------------------------------- pallas

def _run_pallas(plan, x, w):
    if plan.op == "cim":
        return _run_cim_pallas(plan, x, w)
    bm, bn, bk = plan.blocks or (None, None, None)
    lead = x.shape[:-1]
    if plan.domain == "int8":
        xi, x_scale = ops.quantize_acts_int8(x)
        xi2 = _maybe_pad_trit2_k(xi.reshape(-1, xi.shape[-1]), w.mode)
        y = _tm_kernel.ternary_matmul_int8(
            xi2, x_scale.reshape(-1), w.data, w.scale, mode=w.mode,
            bm=bm, bn=bn, bk=bk, interpret=plan.interpret)
    else:
        x2 = _maybe_pad_trit2_k(x.reshape(-1, x.shape[-1]), w.mode)
        y = _tm_kernel.ternary_matmul(
            x2, w.data, w.scale, mode=w.mode, bm=bm, bn=bn, bk=bk,
            interpret=plan.interpret)
    return y.reshape(*lead, w.data.shape[-1])


def _run_cim_pallas(plan, x, w):
    from repro.core.packing import unpack_base3_to_planes
    from repro.core.ternary import encode_inputs, ternarize
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xt = encode_inputs(x2, plan.num_trits)
    if isinstance(w, ops.PackedTernary):
        if w.mode != "base3":
            raise ValueError("cim plans need base3 (multi-trit) weights; "
                             f"got packing {w.mode!r}")
        w_trits = unpack_base3_to_planes(w.data, plan.num_trits)
        w_scale = w.scale
    else:
        # per-tensor scale: exactly mirrors core.cim.cim_matmul
        tt = ternarize(w, plan.num_trits)
        w_trits, w_scale = tt.trits, tt.scale
    bm, bn, bk = plan.blocks
    y_int = _cim_mac_kernel.cim_mac(xt.trits, w_trits,
                                    adc_bits=plan.adc_bits, bm=bm, bn=bn,
                                    bk=bk, interpret=plan.interpret)
    y = y_int.astype(jnp.float32) * xt.scale * w_scale
    return y.reshape(*lead, w_trits.shape[-1])


# ---------------------------------------------------------------- xla

def _run_xla(plan, x, w):
    if plan.domain == "int8":
        xi, x_scale = ops.quantize_acts_int8(x)
        return ops.ternary_matmul_int8_xla(xi, x_scale, w)
    return ops.ternary_matmul_xla(x, w)


# ---------------------------------------------------------------- ref

def _run_ref(plan, x, w):
    if plan.domain == "int8":
        xi, x_scale = ops.quantize_acts_int8(x)
        return ref.ternary_matmul_int8_ref(xi, x_scale, w.data, w.scale,
                                           w.mode)
    kpad = w.kdim - x.shape[-1]
    if kpad:          # trit2 packing pads K; zero rows contribute nothing
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, kpad)])
    return ref.ternary_matmul_ref(x, w.data, w.scale, w.mode)


# All built-ins are plan-aware for both KV layouts: the matmul kernels
# themselves are layout-agnostic (the paged pool's gather/scatter wraps
# AROUND the dense()/attention matmuls — models/paged_kv.py), so they
# declare {dense, paged} and a paged serving loop can be planned on any
# of them.  A future layout-specialized executor (e.g. a fused paged-
# attention kernel) would declare only the layouts it implements.
# All built-ins are exact-fidelity: the bitwise kernel contract.  The
# fault-injected analog path registers separately (repro.faults) and
# declares fidelity 'device' only, so it can never shadow an exact
# request and an exact backend never silently serves a device request.
_ALL_KV_LAYOUTS = frozenset({"dense", "paged"})
_EXACT = frozenset({"exact"})

register_backend(BackendSpec(
    name="pallas",
    ops=frozenset({"ternary", "cim"}),
    domains=frozenset({"float", "int8"}),
    packings=frozenset({"base3", "trit2"}),
    platforms=frozenset({"cpu", "tpu"}),     # cpu = interpret mode
    priority=100,
    runner=_run_pallas,
    needs_blocks=True,
    kv_layouts=_ALL_KV_LAYOUTS,
    fidelities=_EXACT,
))

register_backend(BackendSpec(
    name="xla",
    ops=frozenset({"ternary"}),
    domains=frozenset({"float", "int8"}),
    packings=frozenset({"base3", "trit2"}),
    platforms=frozenset({"cpu", "gpu", "tpu"}),
    priority=50,
    runner=_run_xla,
    kv_layouts=_ALL_KV_LAYOUTS,
    fidelities=_EXACT,
))

register_backend(BackendSpec(
    name="ref",
    ops=frozenset({"ternary"}),
    domains=frozenset({"float", "int8"}),
    packings=frozenset({"base3", "trit2"}),
    platforms=frozenset({"cpu", "gpu", "tpu"}),
    priority=10,
    runner=_run_ref,
    kv_layouts=_ALL_KV_LAYOUTS,
    fidelities=_EXACT,
))

# ----------------------------------------------------- paged attention
# The layout-specialized executors the comment above reserved: the fused
# paged-attention Pallas kernel consumes the page table in-kernel (no
# gathered dense copy), its gather-based XLA oracle materializes one.
# Both are float-KV only (int8 pools carry scale pages the fused read
# does not consume yet) and packing-agnostic — attention has no packed
# weight operand, so every packing mode a model runs under is admissible.
from . import paged_attention as _paged_attention  # noqa: E402

register_backend(BackendSpec(
    name="paged_attn",
    ops=frozenset({"attention"}),
    domains=frozenset({"float"}),
    packings=frozenset({"base3", "trit2"}),
    platforms=frozenset({"cpu", "tpu"}),     # cpu = interpret mode
    priority=100,
    runner=_paged_attention.run_pallas,
    kv_layouts=frozenset({"paged"}),
    fidelities=_EXACT,
))

register_backend(BackendSpec(
    name="paged_attn_ref",
    ops=frozenset({"attention"}),
    domains=frozenset({"float"}),
    packings=frozenset({"base3", "trit2"}),
    platforms=frozenset({"cpu", "gpu", "tpu"}),
    priority=10,
    runner=_paged_attention.run_gather,
    kv_layouts=frozenset({"paged"}),
    fidelities=_EXACT,
))

# The device-fidelity backend (fault-injected analog MAC: sampled
# conductances + ADC transfer over a seeded FaultModel) registers from
# repro.faults.backend — imported last so the built-in registrations
# above are already in place when it joins the registry.
from repro.faults import backend as _faults_backend  # noqa: E402,F401
