"""Capability-based execution planning for the kernel layer.

Every matmul in the framework resolves ONCE into a frozen, hashable
:class:`ExecutionPlan` — *what* to compute (op, domain, packing mode,
problem shape) plus *how* a backend realizes it (backend name, block
shapes, interpret flag) — and then runs through :func:`execute`.  The
old routing kwargs (``backend=``, ``domain=``, ``interpret=``,
``bm/bn/bk``) threaded through ``ops.ternary_matmul`` ->
``CIMConfig`` -> models -> serve survive only as deprecation shims.

Backends self-describe through :class:`BackendSpec`: the ops they
implement, the arithmetic domains, packing modes and platforms they
support, and a priority.  ``backend='auto'`` selects the
highest-priority capable backend for the current platform instead of
an if/elif chain; an explicit backend that lacks a capability fails
loudly with the list of what it *does* support.  The built-in
backends (pallas, xla, ref) register from ``kernels.backends``.

Resolution is cached per (shape, phase, request) via ``lru_cache``, so
plan construction inside a jit trace is a dict hit, and the per-call
platform probe of the old wrappers (``_default_interpret`` on every
invocation) is evaluated once per plan.

Contract: for any fixed plan, every backend capable of that plan's
(domain, packing) cell computes the same function — pallas == xla ==
ref bitwise in the int8 domain, and to f32 round-off in float (see
tests/test_kernels.py / tests/test_fastlane.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

OPS = ("ternary", "cim", "attention")
DOMAINS = ("float", "int8")
PACKINGS = ("base3", "trit2")
PHASES = ("auto", "decode", "prefill")
KV_LAYOUTS = ("dense", "paged")
FIDELITIES = ("exact", "device")

CIM_DEFAULT_BLOCKS = (128, 128, 128)    # kernels.cim_mac defaults

# Bounded plan-cache size: varied-shape traffic (paged serving widens the
# set of (M, K, N) keys a long-lived process resolves) must not grow the
# resolution cache without bound.  2^12 plans cover every (shape x request)
# cell a production sweep touches; eviction only ever costs a re-resolve.
PLAN_CACHE_SIZE = 4096


def check_choice(kind: str, value: Any, choices) -> None:
    """Uniform unknown-name error: every rejected backend/domain/mode
    string names the valid choices (ISSUE 4 satellite: some entrypoints
    used to raise bare ``ValueError(mode)``, others fell through)."""
    if value not in choices:
        raise ValueError(f"unknown {kind} {value!r}; expected one of "
                         f"{sorted(choices)}")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A fully resolved kernel execution: frozen and hashable, so it is
    a dict/jit-static key.  Produced by :func:`plan_matmul`; consumed by
    :func:`execute`.

    ``blocks`` is the (bm, bn, bk) tile choice for block-tiled backends
    (pallas) and None for backends that tile internally (xla, ref).
    ``interpret`` is resolved once at plan time (True off-TPU).
    ``phase`` is advisory metadata today (blocks are shape-resolved).
    ``kv_layout`` names the KV-cache layout the surrounding serving loop
    feeds this matmul from (``dense`` slot caches or the ``paged`` block
    pool): backends declare which layouts they can be planned under, so
    paged serving is a registered executor capability, not a kwarg
    threaded through ops/serve.  ``fidelity`` names the execution
    fidelity the plan was resolved for: ``exact`` (the bitwise kernel
    contract) or ``device`` (fault-injected analog path — sampled
    conductances + ADC transfer, ``repro.faults``).  The requested
    fidelity is routed through :func:`route_fidelity` first, so
    accuracy-critical phases (prefill) resolve to exact backends even
    under a ``device`` request.  ``adc_bits`` / ``num_trits`` are set
    for the macro-exact ``cim`` op and for device-fidelity plans.
    """
    op: str                                  # ternary | cim | attention
    backend: str                             # resolved name (never 'auto')
    domain: str                              # float | int8
    packing: str                             # base3 | trit2
    m: int
    k: int
    n: int
    phase: str = "auto"                      # auto | decode | prefill
    blocks: Optional[tuple] = None           # (bm, bn, bk) | None
    interpret: bool = False
    kv_layout: str = "dense"                 # dense | paged
    adc_bits: Optional[int] = None           # cim op / device fidelity
    num_trits: Optional[int] = None          # cim op / device fidelity
    fidelity: str = "exact"                  # exact | device (post-routing)
    block_source: str = "heuristic"          # heuristic | autotune | pinned

    @property
    def shape(self) -> tuple:
        return (self.m, self.k, self.n)

    def describe(self) -> dict:
        """JSON-friendly record of the resolved plan (bench artifacts)."""
        return {"backend": self.backend, "domain": self.domain,
                "packing": self.packing, "phase": self.phase,
                "blocks": list(self.blocks) if self.blocks else None,
                "interpret": self.interpret,
                "kv_layout": self.kv_layout,
                "fidelity": self.fidelity,
                "block_source": self.block_source}


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capability declaration + runner for one execution backend.

    ``runner(plan, x, w) -> y`` receives the resolved plan; selection
    never inspects the runner.  ``needs_blocks`` backends get (bm, bn,
    bk) resolved into the plan (shape-adaptive unless pinned).
    ``kv_layouts`` is the set of KV-cache layouts the backend can be
    planned under (``dense`` and/or ``paged``): a paged serving loop
    requests ``kv_layout='paged'`` and a dense-only backend is rejected
    at plan time instead of silently reading a layout it cannot.
    ``fidelities`` is the set of execution fidelities the backend
    implements: the built-ins are ``exact`` (bitwise kernel contract);
    the fault-injected analog path (``repro.faults``) registers a
    ``device``-only backend, so a fidelity request is a capability
    match, not a kwarg threaded through ops/serve.
    """
    name: str
    ops: frozenset
    domains: frozenset
    packings: frozenset
    platforms: frozenset
    priority: int
    runner: Callable
    needs_blocks: bool = False
    kv_layouts: frozenset = frozenset({"dense"})
    fidelities: frozenset = frozenset({"exact"})

    def supports(self, op: str, domain: str, packing: str,
                 platform: str, kv_layout: str = "dense",
                 fidelity: str = "exact") -> bool:
        return (op in self.ops and domain in self.domains
                and packing in self.packings and platform in self.platforms
                and kv_layout in self.kv_layouts
                and fidelity in self.fidelities)


_REGISTRY: dict[str, BackendSpec] = {}


def _ensure_builtin_backends() -> None:
    # populate lazily so `import repro.kernels.plan` alone works and the
    # registry survives partial package initialization
    if not _REGISTRY:
        from . import backends  # noqa: F401  (registers on import)


def register_backend(spec: BackendSpec, *, override: bool = False) -> None:
    """Register an execution backend.  Re-registering an existing name
    requires ``override=True`` (tests swap in capability-limited
    doubles)."""
    if spec.name in _REGISTRY and not override:
        raise ValueError(f"backend {spec.name!r} already registered; "
                         f"pass override=True to replace it")
    _REGISTRY[spec.name] = spec
    plan_cache_clear()        # capabilities changed: cached plans stale


def unregister_backend(name: str) -> None:
    """Remove a backend (test cleanup for registered doubles)."""
    _REGISTRY.pop(name, None)
    plan_cache_clear()


def backend_names() -> list:
    _ensure_builtin_backends()
    return sorted(_REGISTRY)


def get_backend(name: str) -> BackendSpec:
    _ensure_builtin_backends()
    if name not in _REGISTRY:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{backend_names()}")
    return _REGISTRY[name]


def route_fidelity(fidelity: str, phase: str) -> str:
    """Noise-aware routing policy: which fidelity a phase actually runs.

    ``exact`` requests always stay exact.  A ``device`` request runs the
    fault-injected path only for error-tolerant phases (``decode``
    sampling, ``auto``); the accuracy-critical ``prefill`` phase is
    routed back to an exact backend — prefill mistakes corrupt the
    whole KV prefix, while a decode-step upset perturbs one sampled
    token (the graceful-degradation contract of the serve engines)."""
    check_choice("fidelity", fidelity, FIDELITIES)
    check_choice("phase", phase, PHASES)
    if fidelity == "device" and phase == "prefill":
        return "exact"
    return fidelity


def resolve_backend(op: str = "ternary", backend: str = "auto",
                    domain: str = "float", packing: str = "base3",
                    platform: Optional[str] = None,
                    kv_layout: str = "dense",
                    fidelity: str = "exact") -> BackendSpec:
    """Capability match: 'auto' picks the highest-priority backend that
    supports (op, domain, packing, kv_layout, fidelity) on `platform`;
    an explicit name is validated against its declared capabilities and
    fails loudly."""
    _ensure_builtin_backends()
    if platform is None:
        platform = _platform()
    if backend in (None, "auto"):
        cands = [s for s in _REGISTRY.values()
                 if s.supports(op, domain, packing, platform, kv_layout,
                               fidelity)]
        if not cands:
            raise ValueError(
                f"no registered backend supports op={op!r} domain={domain!r} "
                f"packing={packing!r} kv_layout={kv_layout!r} "
                f"fidelity={fidelity!r} on platform "
                f"{platform!r}; registered: {backend_names()}")
        return max(cands, key=lambda s: s.priority)
    spec = get_backend(backend)
    for kind, value, have in (("op", op, spec.ops),
                              ("domain", domain, spec.domains),
                              ("packing mode", packing, spec.packings),
                              ("kv layout", kv_layout, spec.kv_layouts),
                              ("fidelity", fidelity, spec.fidelities),
                              ("platform", platform, spec.platforms)):
        if value not in have:
            raise ValueError(
                f"backend {backend!r} does not support {kind} {value!r} "
                f"(supports {sorted(have)}); registered backends: "
                f"{backend_names()}")
    return spec


def _platform() -> str:
    import jax
    return jax.default_backend()


def default_interpret(platform: Optional[str] = None) -> bool:
    """Pallas kernels run in interpret mode off-TPU.  Evaluated once per
    resolved plan (the old wrappers probed the backend on every call)."""
    return (platform or _platform()) != "tpu"


def shape_of(x, w) -> tuple:
    """(M, K, N) problem shape of ``x (..., K) @ w (..., K, N)``: M is
    the flattened leading extent (the kernels run on 2-D views)."""
    m = 1
    for d in x.shape[:-1]:
        m = m * int(d)
    return (m, int(x.shape[-1]), int(w.shape[-1]))


@functools.lru_cache(maxsize=PLAN_CACHE_SIZE)
def _resolve(op, m, k, n, phase, backend, domain, packing, interpret,
             bm, bn, bk, kv_layout, fidelity, adc_bits, num_trits,
             platform) -> ExecutionPlan:
    check_choice("op", op, OPS)
    check_choice("phase", phase, PHASES)
    check_choice("domain", domain, DOMAINS)
    check_choice("packing mode", packing, PACKINGS)
    check_choice("kv layout", kv_layout, KV_LAYOUTS)
    # noise-aware routing BEFORE capability match: a device request on
    # an accuracy-critical phase resolves against exact backends
    fidelity = route_fidelity(fidelity, phase)
    spec = resolve_backend(op, backend, domain, packing, platform,
                           kv_layout, fidelity)
    if interpret is None:
        interpret = default_interpret(platform)
    blocks = None
    block_source = "heuristic"
    if spec.needs_blocks:
        if op == "cim":
            dm, dn, dk = CIM_DEFAULT_BLOCKS
        else:
            from . import autotune
            tuned = autotune.lookup_blocks(m, k, n, phase, platform,
                                           packing, domain)
            if tuned is not None:
                dm, dn, dk = tuned
                block_source = "autotune"
            else:
                from .ternary_matmul import (TRIT2_PER_BYTE,
                                             select_block_shapes)
                # the kernel pads trit2 K to a byte multiple before
                # tiling; select against the extent it will actually see
                kdim = (k + (-k % TRIT2_PER_BYTE) if packing == "trit2"
                        else k)
                dm, dn, dk = select_block_shapes(m, kdim, n, packing,
                                                 domain=domain)
        if bm or bn or bk:
            block_source = "pinned"
        blocks = (bm or dm, bn or dn, bk or dk)
    return ExecutionPlan(op=op, backend=spec.name, domain=domain,
                         packing=packing, m=m, k=k, n=n, phase=phase,
                         blocks=blocks, interpret=bool(interpret),
                         kv_layout=kv_layout, adc_bits=adc_bits,
                         num_trits=num_trits, fidelity=fidelity,
                         block_source=block_source)


def plan_matmul(shape, phase: str = "auto", cfg: Any = None, *,
                op: str = "ternary", backend: Optional[str] = None,
                domain: Optional[str] = None, packing: Optional[str] = None,
                interpret: Optional[bool] = None, bm: Optional[int] = None,
                bn: Optional[int] = None, bk: Optional[int] = None,
                kv_layout: Optional[str] = None,
                fidelity: Optional[str] = None,
                adc_bits: Optional[int] = None,
                num_trits: Optional[int] = None) -> ExecutionPlan:
    """Resolve an :class:`ExecutionPlan` for a (M, K, N) matmul.

    ``cfg`` is any object carrying plan-request attributes (``backend``,
    ``domain``, ``packing``, ``interpret``, ``kv_layout``, ``fidelity``
    — e.g. a ``core.cim_linear.CIMConfig``); explicit keyword arguments
    override it.  Resolution is cached on the full request (bounded at
    ``PLAN_CACHE_SIZE`` entries — see ``plan_cache_info``), so calling
    this per layer inside a jit trace costs a dict lookup; pass
    ``bm/bn/bk`` to pin block shapes (tests, sweeps), otherwise
    block-tiled backends get the shape-adaptive choice.
    ``kv_layout='paged'`` requests a backend capable of running under
    the paged KV block pool.  ``fidelity='device'`` requests the
    fault-injected analog path (routed per phase — see
    :func:`route_fidelity`).  ``op='cim'`` plans the macro-exact CIM
    MAC (``adc_bits`` / ``num_trits`` default 5, as do device-fidelity
    ternary plans, whose ADC model needs them).
    """
    m, k, n = (int(s) for s in shape)
    if cfg is not None:
        # a config collapses to a plan request through plan_request()
        # (e.g. CIMConfig); bare attribute carriers work too
        req = (cfg.plan_request() if hasattr(cfg, "plan_request") else
               {f: getattr(cfg, f, None)
                for f in ("backend", "domain", "packing", "interpret",
                          "kv_layout", "fidelity")})
        backend = backend if backend is not None else req.get("backend")
        domain = domain if domain is not None else req.get("domain")
        packing = packing if packing is not None else req.get("packing")
        interpret = (interpret if interpret is not None
                     else req.get("interpret"))
        kv_layout = (kv_layout if kv_layout is not None
                     else req.get("kv_layout"))
        fidelity = (fidelity if fidelity is not None
                    else req.get("fidelity"))
    fidelity = "exact" if fidelity is None else fidelity
    if op == "cim" or fidelity == "device":
        adc_bits = 5 if adc_bits is None else adc_bits
        num_trits = 5 if num_trits is None else num_trits
    _ensure_builtin_backends()
    return _resolve(op, m, k, n, phase,
                    "auto" if backend is None else backend,
                    "float" if domain is None else domain,
                    "base3" if packing is None else packing,
                    interpret, bm, bn, bk,
                    "dense" if kv_layout is None else kv_layout,
                    fidelity, adc_bits, num_trits, _platform())


def plan_cache_info():
    """CacheInfo of the bounded plan-resolution cache (hits, misses,
    ``maxsize == PLAN_CACHE_SIZE``, currsize)."""
    return _resolve.cache_info()


def plan_cache_clear() -> None:
    _resolve.cache_clear()


def execute(plan: ExecutionPlan, x, w):
    """Run a resolved plan: ``x (..., K) @ w -> (..., N)``.

    ``w`` is an ``ops.PackedTernary`` for ternary plans, or a float
    (K, N) array / base3 PackedTernary for cim plans.  The plan's shape
    and packing are validated against the operands — a plan resolved for
    one shape must not silently run another (plans are per-shape)."""
    spec = get_backend(plan.backend)
    got = shape_of(x, w)
    if got != plan.shape:
        raise ValueError(f"operand shape {got} does not match plan "
                         f"{plan.shape} (plans are resolved per shape; "
                         f"call plan_matmul for this shape)")
    mode = getattr(w, "mode", None)
    if plan.op == "ternary" and mode is not None and mode != plan.packing:
        raise ValueError(f"weight packing {mode!r} does not match plan "
                         f"packing {plan.packing!r}")
    return spec.runner(plan, x, w)
