"""Compute kernels for the paper's hot spot (the ternary MAC).

Public API (see README.md in this directory):

  * ``plan_matmul``/``execute`` + ``ExecutionPlan`` — resolve-once
    capability-based kernel dispatch (kernels.plan).
  * ``register_backend``/``BackendSpec`` — the backend registry; the
    built-ins (pallas, xla, ref) register from kernels.backends.
  * ``PackedTernary``/``pack_weights``/``quantize_acts_int8`` — weight
    packing and activation quantization (kernels.ops).
  * ``ops.ternary_matmul``/``ops.ternary_matmul_int8``/``ops.cim_matmul``
    — deprecated kwarg-routed shims over plan/execute.
  * ``ref`` — pure-jnp oracles (the correctness contract).

The public surface of this package is pinned by
tests/test_api_surface.py against tests/api_manifest.json.
"""
from . import ops, ref                                    # noqa: F401
from . import backends as _backends                       # noqa: F401
from .ops import (PackedTernary, pack_weights,            # noqa: F401
                  quantize_acts_int8)
from .plan import (FIDELITIES, KV_LAYOUTS, BackendSpec,   # noqa: F401
                   ExecutionPlan, backend_names, check_choice,
                   default_interpret, execute, get_backend,
                   plan_cache_clear, plan_cache_info, plan_matmul,
                   register_backend, resolve_backend, route_fidelity,
                   shape_of, unregister_backend)

__all__ = [
    "BackendSpec", "ExecutionPlan", "FIDELITIES", "KV_LAYOUTS",
    "PackedTernary", "backend_names", "check_choice",
    "default_interpret", "execute", "get_backend", "ops",
    "pack_weights", "plan_cache_clear", "plan_cache_info",
    "plan_matmul", "quantize_acts_int8", "ref", "register_backend",
    "resolve_backend", "route_fidelity", "shape_of",
    "unregister_backend",
]
