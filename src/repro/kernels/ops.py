"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (the validation environment) and
False on TPU.  All wrappers accept/return standard JAX arrays and handle
quantization & packing, so model code can treat them as drop-in matmuls.

PackedTernary is a registered pytree (data/scale are children, the
packing mode is static aux), so packed weights flow through jit, scan
slicing (models scan over a leading layer axis) and the dry-run's
ShapeDtypeStruct lowering.

Two execution backends implement the same contract:
  pallas — kernels/ternary_matmul.py (VMEM dequant-on-load); the real
           TPU path, validated on CPU in interpret mode.
  xla    — fused jnp dequant + dot.  Used by the dry-run (Pallas TPU
           kernels cannot lower on the CPU host platform) so the packed
           uint8 weight reads show up faithfully in the memory-roofline
           term.  tests/test_kernels.py asserts pallas == xla == oracle.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.packing import pack_trit_planes_base3, pack_trits2
from repro.core.ternary import encode_inputs, ternarize, trit_range
from . import cim_mac as _cim_mac_kernel
from . import ternary_matmul as _tm_kernel

TRIT2_PER_BYTE = 4
BASE3_OFFSET = trit_range(5)        # 121


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.tree_util.register_pytree_node_class
class PackedTernary:
    """A weight matrix packed for the ternary_matmul kernel.

    data : uint8 (..., K, N) [base3] or (..., K/4, N) [trit2]
    scale: f32  (..., N) — per-output-column
    mode : 'base3' | 'trit2' (static)
    """

    def __init__(self, data, scale, mode: str = "base3"):
        self.data = data
        self.scale = scale
        self.mode = mode

    @property
    def kdim(self) -> int:
        k = self.data.shape[-2]
        return k * TRIT2_PER_BYTE if self.mode == "trit2" else k

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def shape(self) -> tuple:
        return self.data.shape[:-2] + (self.kdim, self.data.shape[-1])

    def tree_flatten(self):
        return (self.data, self.scale), (self.mode,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    def __repr__(self):
        return (f"PackedTernary(mode={self.mode!r}, "
                f"data={getattr(self.data, 'shape', None)}, "
                f"scale={getattr(self.scale, 'shape', None)})")


def pack_weights(w: jax.Array, mode: str = "base3",
                 num_trits: int = 5) -> PackedTernary:
    """Quantize a float (..., K, N) weight with the paper's truncating flow
    and pack for HBM-dense storage (per-output-column scales).  A leading
    stack axis (scan-over-layers weights) is supported."""
    if mode == "base3":
        tt = ternarize(w, num_trits, axis=-2, method="truncate")
        data = pack_trit_planes_base3(tt.trits)          # (..., K, N) uint8
        scale = jnp.squeeze(tt.scale, axis=-2)           # (..., N)
    elif mode == "trit2":
        # single-trit weights: w ~ scale * t, t in {-1,0,1}; threshold at
        # 0.75 * mean|w| per column (standard TWN choice).
        absw = jnp.abs(w)
        thr = 0.75 * jnp.mean(absw, axis=-2, keepdims=True)
        t = jnp.sign(w) * (absw > thr)
        nonzero = jnp.maximum(jnp.sum(jnp.abs(t), axis=-2), 1.0)
        scale = jnp.sum(absw * jnp.abs(t), axis=-2) / nonzero   # (..., N)
        k = w.shape[-2]
        kpad = -k % TRIT2_PER_BYTE
        if kpad:
            pad = [(0, 0)] * w.ndim
            pad[-2] = (0, kpad)
            t = jnp.pad(t, pad)
        tk = jnp.moveaxis(t.astype(jnp.int8), -2, 0)     # (K, ..., N)
        data = jnp.moveaxis(pack_trits2(tk), 0, -2)      # (..., K/4, N)
    else:
        raise ValueError(mode)
    return PackedTernary(data, scale.astype(jnp.float32), mode)


# ------------------------------------------------------------------ xla path

def _unpack_trit2_xla(p: jax.Array, dtype) -> jax.Array:
    """uint8 (..., K/4, N) -> (..., K, N) trit values in `dtype`."""
    fields = [(p >> (2 * i)) & 0x3 for i in range(TRIT2_PER_BYTE)]
    codes = jnp.stack(fields, axis=-2)                   # (..., K/4, 4, N)
    dec = (codes == 1).astype(dtype) - (codes == 2).astype(dtype)
    return dec.reshape(p.shape[:-2] +
                       (p.shape[-2] * TRIT2_PER_BYTE, p.shape[-1]))


def _dequant_xla(w: PackedTernary, dtype=jnp.float32) -> jax.Array:
    """Fused-by-XLA dequantization of a packed weight (any leading dims)."""
    if w.mode == "base3":
        dec = w.data.astype(jnp.float32) - float(BASE3_OFFSET)
    else:
        dec = _unpack_trit2_xla(w.data, jnp.float32)
    return (dec * w.scale.astype(jnp.float32)[..., None, :]).astype(dtype)


def ternary_matmul_xla(x: jax.Array, w: PackedTernary) -> jax.Array:
    """x (..., K) @ packed w -> (..., N) f32 via fused jnp dequant."""
    # trit2 packing pads K to a byte multiple; drop the padded rows on the
    # CONTRACTION axis (the K-penultimate one — leading-axis slicing would
    # truncate the layer stack of 3-D scan-over-layers weights).
    wd = _dequant_xla(w)[..., : x.shape[-1], :]
    return jnp.matmul(x.astype(jnp.float32), wd,
                      preferred_element_type=jnp.float32)


# ----------------------------------------------------------- int8 domain

def quantize_acts_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization of activations (..., K).

    Returns (x_int8, x_scale) with x ~ x_int8 * x_scale[..., None].  The
    shared entry point for every int-domain backend, so pallas/xla/oracle
    all consume bit-identical integers.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    x_scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    xi = jnp.clip(jnp.round(x.astype(jnp.float32) / x_scale[..., None]),
                  -127, 127).astype(jnp.int8)
    return xi, x_scale


def _dequant_xla_int8(w: PackedTernary) -> jax.Array:
    """Packed weight -> int8 trit/value matrix (no float scale applied)."""
    if w.mode == "base3":
        return (w.data.astype(jnp.int32) - BASE3_OFFSET).astype(jnp.int8)
    return _unpack_trit2_xla(w.data, jnp.int8)


def ternary_matmul_int8_xla(x_int: jax.Array, x_scale: jax.Array,
                            w: PackedTernary) -> jax.Array:
    """Int-domain xla backend: int8 x int8 -> int32 dot, float epilogue.

    Mirrors the kernel's epilogue order (acc * x_scale * w_scale) so the
    two backends stay bitwise identical.
    """
    wd = _dequant_xla_int8(w)[..., : x_int.shape[-1], :]
    acc = jnp.matmul(x_int, wd, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32)
            * x_scale.astype(jnp.float32)[..., None]
            * w.scale.astype(jnp.float32)[..., None, :])


def ternary_matmul_int8(x: jax.Array, w: PackedTernary, *, interpret=None,
                        backend: str = "auto", **block_kw) -> jax.Array:
    """Decode fast lane: quantize x per-row to int8 once, then run the
    whole matmul in the integer domain (MXU int8 dot, int32 accumulate)
    with every float scale deferred to the epilogue."""
    xi, x_scale = quantize_acts_int8(x)
    if backend == "xla":
        return ternary_matmul_int8_xla(xi, x_scale, w)
    if interpret is None:
        interpret = _default_interpret()
    lead = x.shape[:-1]
    xi2 = xi.reshape(-1, xi.shape[-1])
    xs2 = x_scale.reshape(-1)
    if w.mode == "trit2" and x.shape[-1] % TRIT2_PER_BYTE:
        xi2 = jnp.pad(xi2, ((0, 0), (0, -x.shape[-1] % TRIT2_PER_BYTE)))
    y = _tm_kernel.ternary_matmul_int8(xi2, xs2, w.data, w.scale,
                                       mode=w.mode, interpret=interpret,
                                       **block_kw)
    return y.reshape(*lead, w.data.shape[-1])


# ---------------------------------------------------------------- dispatch

def ternary_matmul(x: jax.Array, w: PackedTernary, *, interpret=None,
                   backend: str = "auto", domain: str = "float",
                   **block_kw) -> jax.Array:
    """x (..., K) @ packed w (K, N) -> (..., N) fp32.

    Block shapes are shape-adaptive by default (see
    kernels.ternary_matmul.select_block_shapes); pass bm/bn/bk to pin.
    domain='int8' routes to the int-domain fast lane
    (:func:`ternary_matmul_int8`).
    """
    if domain == "int8":
        return ternary_matmul_int8(x, w, interpret=interpret,
                                   backend=backend, **block_kw)
    if domain != "float":
        raise ValueError(f"unknown domain {domain!r} (float | int8)")
    if backend == "xla":
        return ternary_matmul_xla(x, w)
    if interpret is None:
        interpret = _default_interpret()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if w.mode == "trit2" and x.shape[-1] % TRIT2_PER_BYTE:
        x2 = jnp.pad(x2, ((0, 0), (0, -x.shape[-1] % TRIT2_PER_BYTE)))
    y = _tm_kernel.ternary_matmul(x2, w.data, w.scale, mode=w.mode,
                                  interpret=interpret, **block_kw)
    return y.reshape(*lead, w.data.shape[-1])


def cim_matmul(x: jax.Array, w: "PackedTernary | jax.Array", *,
               adc_bits: int = 5, num_trits: int = 5, interpret=None,
               **block_kw) -> jax.Array:
    """Macro-exact CIM matmul: float x (..., K) x weight (K, N) -> (..., N).

    Accepts a float weight (ternarized on the fly) or a base3 PackedTernary.
    """
    if interpret is None:
        interpret = _default_interpret()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xt = encode_inputs(x2, num_trits)
    if isinstance(w, PackedTernary):
        if w.mode != "base3":
            raise ValueError("cim_matmul needs base3 (multi-trit) weights")
        from repro.core.packing import unpack_base3_to_planes
        w_trits = unpack_base3_to_planes(w.data, num_trits)
        w_scale = w.scale
    else:
        # per-tensor scale: exactly mirrors core.cim.cim_matmul
        tt = ternarize(w, num_trits)
        w_trits, w_scale = tt.trits, tt.scale
    y_int = _cim_mac_kernel.cim_mac(xt.trits, w_trits, adc_bits=adc_bits,
                                    interpret=interpret, **block_kw)
    y = y_int.astype(jnp.float32) * xt.scale * w_scale
    return y.reshape(*lead, w_trits.shape[-1])
