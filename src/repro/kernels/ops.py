"""Packed-weight containers, quantizers, and the legacy jit'd wrappers.

The kernel layer's public API is the plan/registry pair in
``kernels.plan`` (see src/repro/kernels/README.md):

    plan = plan_matmul(shape_of(x, pw), cfg=cim_cfg)   # resolve once
    y = execute(plan, x, pw)                           # run anywhere

``ternary_matmul`` / ``ternary_matmul_int8`` / ``cim_matmul`` below are
thin deprecation shims over that API: the old routing kwargs
(``backend=``, ``domain=``, ``interpret=``, ``bm/bn/bk``) still work
but emit a ``DeprecationWarning`` — backend selection now lives in the
capability registry, not in per-call if/elif chains, and the platform
probe for ``interpret`` is evaluated once per resolved plan instead of
on every wrapper invocation.

PackedTernary is a registered pytree (data/scale are children, the
packing mode is static aux), so packed weights flow through jit, scan
slicing (models scan over a leading layer axis) and the dry-run's
ShapeDtypeStruct lowering.

The xla implementation functions (``ternary_matmul_xla``,
``ternary_matmul_int8_xla``) remain importable: they are the 'xla'
backend's runners and the dry-run's lowering path (Pallas TPU kernels
cannot lower on the CPU host platform, and the packed uint8 weight
reads must show up faithfully in the memory-roofline term).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.packing import pack_trit_planes_base3, pack_trits2
from repro.core.ternary import ternarize, trit_range
from .plan import (PACKINGS, check_choice, execute, plan_matmul,
                   shape_of)

TRIT2_PER_BYTE = 4
BASE3_OFFSET = trit_range(5)        # 121


@jax.tree_util.register_pytree_node_class
class PackedTernary:
    """A weight matrix packed for the ternary_matmul kernel.

    data : uint8 (..., K, N) [base3] or (..., K/4, N) [trit2]
    scale: f32  (..., N) — per-output-column
    mode : 'base3' | 'trit2' (static)
    """

    def __init__(self, data, scale, mode: str = "base3"):
        self.data = data
        self.scale = scale
        self.mode = mode

    @property
    def kdim(self) -> int:
        k = self.data.shape[-2]
        return k * TRIT2_PER_BYTE if self.mode == "trit2" else k

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def shape(self) -> tuple:
        return self.data.shape[:-2] + (self.kdim, self.data.shape[-1])

    def tree_flatten(self):
        return (self.data, self.scale), (self.mode,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    def __repr__(self):
        return (f"PackedTernary(mode={self.mode!r}, "
                f"data={getattr(self.data, 'shape', None)}, "
                f"scale={getattr(self.scale, 'shape', None)})")


def pack_weights(w: jax.Array, mode: str = "base3",
                 num_trits: int = 5) -> PackedTernary:
    """Quantize a float (..., K, N) weight with the paper's truncating flow
    and pack for HBM-dense storage (per-output-column scales).  A leading
    stack axis (scan-over-layers weights) is supported."""
    check_choice("packing mode", mode, PACKINGS)
    if mode == "base3":
        tt = ternarize(w, num_trits, axis=-2, method="truncate")
        data = pack_trit_planes_base3(tt.trits)          # (..., K, N) uint8
        scale = jnp.squeeze(tt.scale, axis=-2)           # (..., N)
    else:
        # single-trit weights: w ~ scale * t, t in {-1,0,1}; threshold at
        # 0.75 * mean|w| per column (standard TWN choice).
        absw = jnp.abs(w)
        thr = 0.75 * jnp.mean(absw, axis=-2, keepdims=True)
        t = jnp.sign(w) * (absw > thr)
        nonzero = jnp.maximum(jnp.sum(jnp.abs(t), axis=-2), 1.0)
        scale = jnp.sum(absw * jnp.abs(t), axis=-2) / nonzero   # (..., N)
        k = w.shape[-2]
        kpad = -k % TRIT2_PER_BYTE
        if kpad:
            pad = [(0, 0)] * w.ndim
            pad[-2] = (0, kpad)
            t = jnp.pad(t, pad)
        tk = jnp.moveaxis(t.astype(jnp.int8), -2, 0)     # (K, ..., N)
        data = jnp.moveaxis(pack_trits2(tk), 0, -2)      # (..., K/4, N)
    return PackedTernary(data, scale.astype(jnp.float32), mode)


# ------------------------------------------------------------------ xla path

def _unpack_trit2_xla(p: jax.Array, dtype) -> jax.Array:
    """uint8 (..., K/4, N) -> (..., K, N) trit values in `dtype`."""
    fields = [(p >> (2 * i)) & 0x3 for i in range(TRIT2_PER_BYTE)]
    codes = jnp.stack(fields, axis=-2)                   # (..., K/4, 4, N)
    dec = (codes == 1).astype(dtype) - (codes == 2).astype(dtype)
    return dec.reshape(p.shape[:-2] +
                       (p.shape[-2] * TRIT2_PER_BYTE, p.shape[-1]))


def _dequant_xla(w: PackedTernary, dtype=jnp.float32) -> jax.Array:
    """Fused-by-XLA dequantization of a packed weight (any leading dims)."""
    if w.mode == "base3":
        dec = w.data.astype(jnp.float32) - float(BASE3_OFFSET)
    else:
        dec = _unpack_trit2_xla(w.data, jnp.float32)
    return (dec * w.scale.astype(jnp.float32)[..., None, :]).astype(dtype)


def ternary_matmul_xla(x: jax.Array, w: PackedTernary) -> jax.Array:
    """x (..., K) @ packed w -> (..., N) f32 via fused jnp dequant."""
    # trit2 packing pads K to a byte multiple; drop the padded rows on the
    # CONTRACTION axis (the K-penultimate one — leading-axis slicing would
    # truncate the layer stack of 3-D scan-over-layers weights).
    wd = _dequant_xla(w)[..., : x.shape[-1], :]
    return jnp.matmul(x.astype(jnp.float32), wd,
                      preferred_element_type=jnp.float32)


# ----------------------------------------------------------- int8 domain

def quantize_acts_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization of activations (..., K).

    Returns (x_int8, x_scale) with x ~ x_int8 * x_scale[..., None].  The
    shared entry point for every int-domain backend, so pallas/xla/oracle
    all consume bit-identical integers.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    x_scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    xi = jnp.clip(jnp.round(x.astype(jnp.float32) / x_scale[..., None]),
                  -127, 127).astype(jnp.int8)
    return xi, x_scale


def _dequant_xla_int8(w: PackedTernary) -> jax.Array:
    """Packed weight -> int8 trit/value matrix (no float scale applied)."""
    if w.mode == "base3":
        return (w.data.astype(jnp.int32) - BASE3_OFFSET).astype(jnp.int8)
    return _unpack_trit2_xla(w.data, jnp.int8)


def ternary_matmul_int8_xla(x_int: jax.Array, x_scale: jax.Array,
                            w: PackedTernary) -> jax.Array:
    """Int-domain xla backend: int8 x int8 -> int32 dot, float epilogue.

    Mirrors the kernel's epilogue order (acc * x_scale * w_scale) so the
    two backends stay bitwise identical.
    """
    wd = _dequant_xla_int8(w)[..., : x_int.shape[-1], :]
    acc = jnp.matmul(x_int, wd, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32)
            * x_scale.astype(jnp.float32)[..., None]
            * w.scale.astype(jnp.float32)[..., None, :])


# ------------------------------------------------------ deprecation shims

def _warn_legacy(fn: str, used: dict, stacklevel: int = 1) -> None:
    """Emit the routing-kwarg DeprecationWarning at the SHIM CALLER's
    frame.  ``stacklevel`` counts frames between the shim and the user
    (1 = the shim was called directly); each shim passes its depth
    explicitly so a future shim sitting one level deeper cannot
    silently misattribute the warning.  The reported filename must be
    the user's call site — pinned by
    tests/test_kernels.py::test_shim_warning_points_at_caller."""
    used = {k: v for k, v in used.items() if v is not None}
    if used:
        warnings.warn(
            f"ops.{fn}({', '.join(sorted(used))}=...) routing kwargs are "
            f"deprecated: resolve an ExecutionPlan once with "
            f"repro.kernels.plan_matmul and run repro.kernels.execute "
            f"(src/repro/kernels/README.md has the migration table)",
            DeprecationWarning, stacklevel=2 + stacklevel)


def ternary_matmul(x: jax.Array, w: PackedTernary, *, interpret=None,
                   backend: str = "auto", domain: str = "float",
                   bm: int | None = None, bn: int | None = None,
                   bk: int | None = None) -> jax.Array:
    """x (..., K) @ packed w (K, N) -> (..., N) fp32.

    Deprecation shim: equivalent to ``execute(plan_matmul(...), x, w)``;
    the routing kwargs survive behind a DeprecationWarning.
    """
    _warn_legacy("ternary_matmul", {
        "interpret": interpret, "bm": bm, "bn": bn, "bk": bk,
        "backend": None if backend == "auto" else backend,
        "domain": None if domain == "float" else domain}, stacklevel=1)
    plan = plan_matmul(shape_of(x, w), backend=backend, domain=domain,
                       packing=w.mode, interpret=interpret,
                       bm=bm, bn=bn, bk=bk)
    return execute(plan, x, w)


def ternary_matmul_int8(x: jax.Array, w: PackedTernary, *, interpret=None,
                        backend: str = "auto", bm: int | None = None,
                        bn: int | None = None,
                        bk: int | None = None) -> jax.Array:
    """Decode fast lane: quantize x per-row to int8 once, then run the
    whole matmul in the integer domain (MXU int8 dot, int32 accumulate)
    with every float scale deferred to the epilogue.

    Deprecation shim for an int8-domain plan (see ``ternary_matmul``).
    """
    _warn_legacy("ternary_matmul_int8", {
        "interpret": interpret, "bm": bm, "bn": bn, "bk": bk,
        "backend": None if backend == "auto" else backend}, stacklevel=1)
    plan = plan_matmul(shape_of(x, w), backend=backend, domain="int8",
                       packing=w.mode, interpret=interpret,
                       bm=bm, bn=bn, bk=bk)
    return execute(plan, x, w)


def cim_matmul(x: jax.Array, w: "PackedTernary | jax.Array", *,
               adc_bits: int = 5, num_trits: int = 5, interpret=None,
               bm: int | None = None, bn: int | None = None,
               bk: int | None = None) -> jax.Array:
    """Macro-exact CIM matmul: float x (..., K) x weight (K, N) -> (..., N).

    Accepts a float weight (ternarized on the fly) or a base3 PackedTernary.
    Deprecation shim for an ``op='cim'`` plan.
    """
    _warn_legacy("cim_matmul", {"interpret": interpret, "bm": bm,
                                "bn": bn, "bk": bk}, stacklevel=1)
    plan = plan_matmul(shape_of(x, w), op="cim", interpret=interpret,
                       bm=bm, bn=bn, bk=bk, adc_bits=adc_bits,
                       num_trits=num_trits)
    return execute(plan, x, w)
