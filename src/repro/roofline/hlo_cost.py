"""Loop-aware cost roll-up over post-optimization HLO text.

Why this exists: XLA's HloCostAnalysis (``compiled.cost_analysis()``)
visits every ``while`` body exactly ONCE, so any model lowered with
jax.lax.scan (all of ours: scan-over-layers, flash-attention chunks,
SSD/sLSTM time scans, microbatch accumulation) under-reports flops /
bytes / collective traffic by the trip count.  This module re-derives
the three roofline inputs from the HLO text itself:

  * builds a per-computation symbol table (op name -> shape/dtype),
  * computes flops per op (dot = 2*prod(result)*K from the parsed
    contracting dims; elementwise/reduce = prod(shape); data movement
    ops = 0),
  * computes bytes per op (operands + result), skipping inside fused
    computations (a fusion's internal traffic stays on-chip) and
    counting the fusion op itself instead,
  * converts collectives to per-device wire bytes with ring formulas,
  * multiplies ``while`` bodies by trip counts parsed from the loop
    condition (scan lowers to `i < N` with a literal N), recursively.

Validated in tests/test_roofline.py against cost_analysis() on loop-free
graphs (where both must agree) and against trip-count ground truth on
scanned graphs.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_RE = re.compile(
    r"compare\([^)]*\)\s*,\s*direction=LT", re.I)

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "remainder",
    "atan2", "logistic", "cosine", "sine", "expm1", "log1p", "cbrt",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "exponential-minus-one",
}
ZERO_FLOPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "transpose", "broadcast", "copy", "copy-start", "copy-done",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "gather", "scatter", "pad", "reverse", "iota", "convert", "rng",
    "rng-bit-generator", "after-all", "partition-id", "replica-id",
    "optimization-barrier", "bitcast-convert", "get-dimension-size",
    "custom-call", "infeed", "outfeed", "domain", "send", "recv",
    "send-done", "recv-done",
}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start"}
# ops whose bytes we count at top level (data movement included)
BYTE_OPS_EXTRA = {"copy", "slice", "dynamic-slice", "dynamic-update-slice",
                  "concatenate", "gather", "scatter", "pad", "reverse",
                  "convert", "broadcast", "transpose", "reshape",
                  "bitcast-convert"}


def _shape_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) across a (possibly tuple) type."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    operands: list
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict           # op name -> type string


def parse_module(text: str) -> dict:
    """name -> Computation for every computation in the module."""
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            m = _COMP_HDR_RE.match(line[:-1].strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                # header also declares parameters? (types live on param ops)
                continue
        if line.startswith("}"):
            # keep cur until a new header (nested braces don't occur)
            cur = None
            continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # result type: balanced parens for tuples (may contain /*index*/
        # comments), otherwise a single whitespace-free token
        if rest.startswith("("):
            depth = 0
            ti = len(rest) - 1
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        ti = i
                        break
            type_str = rest[:ti + 1]
            rest = rest[ti + 1:]
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            type_str = rest[:sp]
            rest = rest[sp:]
        mo = re.match(r"\s*([\w\-]+)\(", rest)
        if not mo:
            continue
        opcode = mo.group(1).lower()
        # operands: first balanced paren group after the opcode
        start = rest.find("(", mo.start(1))
        depth = 0
        end = start
        for i in range(start, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[start + 1:end]
        attrs = rest[end + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        op = Op(name, opcode, type_str, operands, attrs, line)
        cur.ops.append(op)
        cur.shapes[name] = type_str
    return comps


def _called(op: Op, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", op.attrs)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    """Parse `i < N` from a scan's condition computation."""
    consts = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.attrs.replace(
                " ", ""):
            for o in op.operands:
                if o in consts:
                    return max(consts[o], 1)
        if op.opcode == "compare":
            m = re.search(r"direction=(GT|GE|LE)", op.attrs)
            if m:
                for o in op.operands:
                    if o in consts and consts[o] > 0:
                        return max(consts[o], 1)
    return 1


def _group_size(attrs: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return total_devices


def _dot_flops(op: Op, shapes: dict) -> float:
    _, rbytes = _shape_info(op.type_str)
    relems, _ = _shape_info(op.type_str)
    k = 1.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if m and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * relems * k


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    unknown_trips: int = 0

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_bytes + o.coll_bytes, kinds,
                    self.unknown_trips + o.unknown_trips)

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t, self.coll_bytes * t,
                    {k: v * t for k, v in self.coll_by_kind.items()},
                    self.unknown_trips)


def _op_bytes(op: Op, shapes: dict) -> float:
    # slice-type ops touch only the sliced region, not the whole operand
    if op.opcode in ("dynamic-slice", "gather", "slice"):
        return 2.0 * _shape_info(op.type_str)[1]
    if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
        upd = shapes.get(op.operands[1])
        if upd:
            return 2.0 * _shape_info(upd)[1]
    total = 0.0
    for o in op.operands:
        t = shapes.get(o)
        if t:
            total += _shape_info(t)[1]
    total += _shape_info(op.type_str)[1]
    return total


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """HBM traffic of a fusion op: parameters consumed only through
    dynamic-slice/gather count at slice size (scan bodies constantly
    slice one layer out of a stacked (L, ...) buffer — charging the whole
    buffer per iteration inflates bytes by O(L)); a root
    dynamic-update-slice writes only the update region."""
    callee = _called(op, "calls")
    fc = comps.get(callee) if callee else None
    if fc is None:
        return _op_bytes(op, comp.shapes)
    param_names = [fop.name for fop in fc.ops if fop.opcode == "parameter"]
    uses: dict[str, list] = {}
    root = fc.ops[-1] if fc.ops else None
    for fop in fc.ops:
        for o in fop.operands:
            if o in fc.shapes:
                uses.setdefault(o, []).append(fop)
    total = 0.0
    for pname in param_names:
        psize = _shape_info(fc.shapes.get(pname, ""))[1]
        u = uses.get(pname, [])
        if u and all(x.opcode in ("dynamic-slice", "gather") for x in u):
            total += min(sum(2.0 * _shape_info(x.type_str)[1] for x in u),
                         psize)
        elif u and all(x.opcode == "dynamic-update-slice" for x in u):
            for x in u:
                upd = fc.shapes.get(x.operands[1]) if len(x.operands) > 1 \
                    else None
                total += _shape_info(upd)[1] if upd else psize
        else:
            total += psize
    rbytes = _shape_info(op.type_str)[1]
    if root is not None and root.opcode == "dynamic-update-slice" and \
            len(root.operands) > 1:
        upd = fc.shapes.get(root.operands[1])
        if upd:
            rbytes = _shape_info(upd)[1]
    return total + rbytes


def analyze_text(text: str, total_devices: int) -> Cost:
    comps = parse_module(text)
    # computations reached through fusion `calls=` are on-chip
    fused: set[str] = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                callee = _called(op, "calls")
                if callee:
                    fused.add(callee)

    memo: dict[str, Cost] = {}

    def comp_cost(name: str, in_fusion: bool) -> Cost:
        key = name + ("|f" if in_fusion else "")
        if key in memo:
            return memo[key]
        memo[key] = Cost()             # break cycles defensively
        c = comps.get(name)
        if c is None:
            return Cost()
        total = Cost()
        for op in c.ops:
            total = total + op_cost(op, c, in_fusion)
        memo[key] = total
        return total

    def op_cost(op: Op, comp: Computation, in_fusion: bool) -> Cost:
        oc = op.opcode
        if oc == "while":
            body = _called(op, "body")
            cond = _called(op, "condition")
            # XLA records the statically-known trip count on the op
            m = re.search(r"known_trip_count[^0-9]*(\d+)", op.line)
            if m:
                trip = max(int(m.group(1)), 1)
                known = True
            else:
                trip = _trip_count(comps[cond]) if cond in comps else 1
                known = trip > 1
            inner = comp_cost(body, in_fusion) if body else Cost()
            cost = inner.scaled(trip)
            if not known:
                cost.unknown_trips += 1
            return cost
        if oc == "fusion":
            callee = _called(op, "calls")
            inner = comp_cost(callee, True) if callee else Cost()
            b = 0.0 if in_fusion else _fusion_bytes(op, comp, comps)
            return Cost(inner.flops, b + inner.bytes, inner.coll_bytes,
                        inner.coll_by_kind, inner.unknown_trips)
        if oc == "conditional":
            branches = re.findall(r"%([\w.\-]+)", op.attrs)
            costs = [comp_cost(b, in_fusion) for b in branches
                     if b in comps]
            if not costs:
                return Cost()
            best = max(costs, key=lambda x: x.flops + x.bytes)
            return best
        if oc == "call":
            callee = _called(op, "to_apply")
            return comp_cost(callee, in_fusion) if callee else Cost()
        if oc in COLLECTIVES:
            kind = oc.replace("-start", "")
            _, size = _shape_info(op.type_str)
            g = _group_size(op.attrs, total_devices)
            if g <= 1:
                wire = 0.0
            elif kind == "all-reduce":
                wire = 2.0 * size * (g - 1) / g
            elif kind == "all-gather":
                wire = size * (g - 1) / g
            elif kind == "reduce-scatter":
                wire = size * (g - 1)
            elif kind == "all-to-all":
                wire = size * (g - 1) / g
            else:
                wire = float(size)
            b = 0.0 if in_fusion else _op_bytes(op, comp.shapes)
            return Cost(0.0, b, wire, {kind: wire})
        # plain ops
        flops = 0.0
        elems, _ = _shape_info(op.type_str)
        if oc == "dot":
            flops = _dot_flops(op, comp.shapes)
        elif oc == "convolution":
            flops = 2.0 * elems  # no convs in this framework (stub fronts)
        elif oc in ("reduce", "reduce-window"):
            ielems = 0
            for o in op.operands:
                t = comp.shapes.get(o)
                if t:
                    ielems += _shape_info(t)[0]
            flops = float(ielems)
        elif oc in ELEMENTWISE:
            flops = float(elems)
        elif oc in ZERO_FLOPS:
            flops = 0.0
        else:
            flops = float(elems)
        if in_fusion:
            return Cost(flops, 0.0, 0.0)
        if oc in ZERO_FLOPS and oc not in BYTE_OPS_EXTRA:
            return Cost(flops, 0.0, 0.0)
        return Cost(flops, _op_bytes(op, comp.shapes), 0.0)

    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda k: len(comps[k].ops))
    # computations reachable only as while-bodies etc. are rolled up from
    # the entry; fused computations are not double counted because we only
    # start from entry.
    return comp_cost(entry, False)
