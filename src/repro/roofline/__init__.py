from .analysis import (HW, V5E, RooflineReport, analyze_compiled,
                       collective_bytes, model_flops)  # noqa: F401
