"""Three-term roofline from a compiled (dry-run) executable.

    compute    = HLO_FLOPs_per_device   / peak_FLOP/s
    memory     = HLO_bytes_per_device   / HBM_bw
    collective = wire_bytes_per_device  / ICI_link_bw

``compiled.cost_analysis()`` on a partitioned module reports PER-DEVICE
flops / bytes (verified: an 8-way-sharded matmul reports 1/8 of the math)
so no further division by chip count is applied.

collective_bytes parses the post-optimization HLO: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute result
shape is converted to wire bytes with the standard ring formulas:

    all-reduce       2·S·(g-1)/g        (reduce-scatter + all-gather)
    all-gather       S·(g-1)/g          (S = full gathered size)
    reduce-scatter   S_out·(g-1)        (S_out = per-shard output)
    all-to-all       S·(g-1)/g
    collective-permute  S

where g is the replica-group size parsed from the op's replica_groups.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass(frozen=True)
class HW:
    """Per-chip hardware constants."""
    name: str
    peak_flops: float          # FLOP/s (bf16)
    hbm_bw: float              # B/s
    ici_bw: float              # B/s per link


V5E = HW("tpu-v5e", 197e12, 819e9, 50e9)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        num_groups, group_size = int(m.group(1)), int(m.group(2))
        return max(group_size, 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return total_devices


def collective_bytes(hlo_text: str, total_devices: int) -> dict:
    """Per-device wire bytes by collective kind, from optimized HLO."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op").lower()
        size = _shape_bytes(m.group("type"))
        g = _group_size(line, total_devices)
        if g <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            wire = size * (g - 1) / g
        elif op == "reduce-scatter":
            wire = size * (g - 1)
        elif op == "all-to-all":
            wire = size * (g - 1) / g
        else:                                     # collective-permute
            wire = float(size)
        out[op] += wire
        counts[op] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def model_flops(cfg, tokens: int, kind: str) -> float:
    """6·N_active·D for train, 2·N_active·D per generated/processed token
    for serving — the 'useful work' denominator."""
    n = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_ratio: float        # MODEL_FLOPS / (HLO_FLOPs x chips)
    peak_fraction: float       # t_compute / max(all terms) = roofline frac
    collectives: dict
    memory_stats: Optional[dict] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} | "
                f"{self.t_collective*1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_ratio:.2f} | {self.peak_fraction:.2f} |")


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, cfg=None, tokens: int = 0,
                     kind: str = "train", hw: HW = V5E) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    # XLA's cost analysis visits while (scan) bodies ONCE — useless for
    # scanned models.  The loop-aware HLO roll-up is the real source;
    # XLA's numbers are kept for reference/validation on loop-free cells.
    from .hlo_cost import analyze_text
    text = compiled.as_text()
    cost = analyze_text(text, chips)
    flops = cost.flops
    bytes_acc = cost.bytes
    coll = {"total": cost.coll_bytes, **cost.coll_by_kind,
            "unknown_trip_loops": cost.unknown_trips,
            "xla_flops_once": float(ca.get("flops", 0.0)),
            "xla_bytes_once": float(ca.get("bytes accessed", 0.0))}

    t_comp = flops / hw.peak_flops
    t_mem = bytes_acc / hw.hbm_bw
    t_coll = coll["total"] / hw.ici_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, tokens, kind) if cfg is not None and tokens else 0.0
    useful = mf / (flops * chips) if flops else 0.0
    peak_frac = t_comp / max(max(terms.values()), 1e-30)

    # memory_analysis is optional in the compiled-executable protocol
    # (some backends return None or raise Unimplemented); record WHY it
    # is missing instead of silently dropping the section.  jax is
    # imported here, not module-level: this module is otherwise static
    # math, and `compiled` existing means jax is already loaded.
    import jax
    try:
        m = compiled.memory_analysis()
        ms = {"argument_bytes": m.argument_size_in_bytes,
              "output_bytes": m.output_size_in_bytes,
              "temp_bytes": m.temp_size_in_bytes,
              "alias_bytes": m.alias_size_in_bytes} if m is not None \
            else {"unavailable": "memory_analysis() returned None"}
    except (NotImplementedError, AttributeError,
            jax.errors.JaxRuntimeError) as e:
        ms = {"unavailable": repr(e)}

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        wire_bytes_per_device=coll["total"],
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, model_flops_total=mf, useful_ratio=useful,
        peak_fraction=peak_frac, collectives=coll, memory_stats=ms)
