"""Restore-scrub and drift channels over packed parameter trees.

The serve engines use three operations, all deterministic per campaign
key and all device-resident (no host transfer):

  * :func:`disturb_packed_params` — the accumulated-error channel: each
    trit of every packed weight is replaced by a uniform random trit
    with probability ``rate``.  Applied once per decode chunk with a
    chunk-indexed key, so error COMPOUNDS monotonically while serving.
  * :func:`scrub_packed_params` — the paper's DC-power-free restore as
    an online repair: re-restore every weight tile from its pristine
    TL-ReRAM contents (store -> restore through the measured-yield
    confusion channel).  Accumulated drift is discarded; the residual
    error is bounded by ``1 - yield`` per state, independent of how
    long the engine ran since the last scrub.
  * :func:`packed_trit_error_rate` — fraction of trits differing
    between two packed trees (the repair metric the scrub gate pins).

``adc_probe`` is the per-chunk health counter: the worst-case all-ones
input drive over the served weights, counting row-group CBL counts that
would saturate the ADC code space.  It returns device scalars sized to
ride the engines' single per-chunk transfer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cim import MacroConfig
from repro.core.error_injection import inject_trit_errors
from repro.core.packing import (pack_trit_planes_base3, pack_trits2,
                                unpack_base3_to_planes, unpack_trits2)
from repro.kernels.ops import PackedTernary

ROWS_ACTIVE = MacroConfig().rows_active


def packed_to_trits(leaf: PackedTernary, num_trits: int = 5) -> jax.Array:
    """PackedTernary -> (q, ..., K, N) trit planes."""
    if leaf.mode == "base3":
        return unpack_base3_to_planes(leaf.data, num_trits)
    t = unpack_trits2(jnp.moveaxis(leaf.data, -2, 0), leaf.kdim)
    return jnp.moveaxis(t, 0, -2)[None]


def trits_to_packed(trits: jax.Array, leaf: PackedTernary) -> PackedTernary:
    """Inverse of :func:`packed_to_trits` (scale/mode preserved)."""
    if leaf.mode == "base3":
        data = pack_trit_planes_base3(trits)
    else:
        data = jnp.moveaxis(pack_trits2(jnp.moveaxis(trits[0], -2, 0)),
                            0, -2)
    return PackedTernary(data, leaf.scale, leaf.mode)


def _is_packed(x) -> bool:
    return isinstance(x, PackedTernary)


def _map_packed(params, fn, num_trits: int):
    """Apply ``fn(trits, leaf_index) -> trits`` to every PackedTernary
    leaf (other leaves pass through untouched)."""
    counter = [0]

    def apply(leaf):
        if not _is_packed(leaf):
            return leaf
        i = counter[0]
        counter[0] += 1
        return trits_to_packed(fn(packed_to_trits(leaf, num_trits), i),
                               leaf)

    return jax.tree_util.tree_map(apply, params, is_leaf=_is_packed)


def disturb_packed_params(params, rate: float, key: jax.Array,
                          num_trits: int = 5):
    """One chunk's drift/read-disturb step: every trit independently
    replaced by a uniform random trit with probability ``rate``."""
    if rate <= 0.0:
        return params

    def disturb(trits, i):
        km, kv = jax.random.split(jax.random.fold_in(key, i))
        flip = jax.random.bernoulli(km, rate, trits.shape)
        rnd = jax.random.randint(kv, trits.shape, -1, 2,
                                 dtype=jnp.int32).astype(jnp.int8)
        return jnp.where(flip, rnd, trits)

    return _map_packed(params, disturb, num_trits)


def scrub_packed_params(pristine, per_state_yield, key: jax.Array,
                        num_trits: int = 5):
    """Restore-scrub: rebuild the served weights from the PRISTINE tree
    through the store->restore confusion channel at ``per_state_yield``
    (None = ideal restore, returns the pristine tree).  This is the
    repair step — whatever the served tree drifted to is discarded."""
    if per_state_yield is None:
        return pristine
    y = jnp.asarray(per_state_yield, jnp.float32)

    def restore(trits, i):
        return inject_trit_errors(trits, y, jax.random.fold_in(key, i))

    return _map_packed(pristine, restore, num_trits)


def packed_trit_error_rate(params_a, params_b, num_trits: int = 5) -> float:
    """Fraction of trits that differ between two packed trees (same
    structure).  Host-side diagnostic — the scrub-repair metric."""
    leaves_a = [x for x in jax.tree_util.tree_leaves(
        params_a, is_leaf=_is_packed) if _is_packed(x)]
    leaves_b = [x for x in jax.tree_util.tree_leaves(
        params_b, is_leaf=_is_packed) if _is_packed(x)]
    if len(leaves_a) != len(leaves_b):
        raise ValueError(
            f"packed trees differ in structure: {len(leaves_a)} vs "
            f"{len(leaves_b)} packed leaves")
    diff = total = 0
    for a, b in zip(leaves_a, leaves_b):
        ta = packed_to_trits(a, num_trits)
        tb = packed_to_trits(b, num_trits)
        diff += int(jnp.sum(ta != tb))
        total += ta.size
    return diff / total if total else 0.0


def adc_probe(params, adc_bits: int = 5, num_trits: int = 5):
    """Worst-case saturation probe over the FIRST packed leaf: drive
    every row with input trit +1 and count row-group CBL counts outside
    the ADC code space [0, 2^bits - 1].  Returns (clip_lo, clip_hi)
    device int32 scalars (zero-zero when no packed leaf exists)."""
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=_is_packed):
        if _is_packed(leaf):
            trits = packed_to_trits(leaf, num_trits)
            if trits.ndim != 3:
                trits = trits.reshape(trits.shape[0], -1,
                                      trits.shape[-1])
            q, k, n = trits.shape
            ra = ROWS_ACTIVE
            g = -(-k // ra)
            pad = g * ra - k
            if pad:
                trits = jnp.pad(trits, ((0, 0), (0, pad), (0, 0)))
            wg = trits.reshape(q, g, ra, n).astype(jnp.int32)
            rows_real = jnp.minimum(
                ra, jnp.maximum(0, k - jnp.arange(g) * ra))
            # all-ones drive: count = rows_real - sum_r w
            count = rows_real[None, :, None] - wg.sum(axis=2)
            clip_lo = jnp.sum(count < 0).astype(jnp.int32)
            clip_hi = jnp.sum(count > 2**adc_bits - 1).astype(jnp.int32)
            return clip_lo, clip_hi
    zero = jnp.zeros((), jnp.int32)
    return zero, zero
