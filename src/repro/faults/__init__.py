"""Device-fault modeling: seeded fault campaigns, the ``device``
fidelity backend, and the restore-scrub repair channel.

Public API (see README.md in this directory):

  * ``FaultModel`` / ``measured_fault_model`` — composable, seeded
    fault channels (restore confusion at measured TL yield, stuck-at,
    conductance variation, drift) — ``faults.model``.
  * ``register_device_backend`` / ``set_fault_model`` /
    ``get_fault_model`` — the ``fidelity='device'`` execution backend
    (analog MAC through sampled conductances + ``adc_transfer``) —
    ``faults.backend``.
  * ``scrub_packed_params`` / ``disturb_packed_params`` /
    ``packed_trit_error_rate`` / ``adc_probe`` — the serve engines'
    per-chunk drift + periodic restore-scrub repair — ``faults.scrub``.
"""
from .model import FaultModel, measured_fault_model          # noqa: F401
from .backend import (DEVICE_BACKEND, device_ternary_mac,    # noqa: F401
                      get_fault_model, register_device_backend,
                      set_fault_model, weight_trit_planes)
from .scrub import (adc_probe, disturb_packed_params,        # noqa: F401
                    packed_to_trits, packed_trit_error_rate,
                    scrub_packed_params, trits_to_packed)

__all__ = [
    "DEVICE_BACKEND", "FaultModel", "adc_probe", "device_ternary_mac",
    "disturb_packed_params", "get_fault_model", "measured_fault_model",
    "packed_to_trits", "packed_trit_error_rate",
    "register_device_backend", "scrub_packed_params", "set_fault_model",
    "trits_to_packed", "weight_trit_planes",
]
