"""The ``device``-fidelity execution backend: ternary MACs through the
analog signal chain.

Where the exact backends compute ``x @ dequant(w)`` bitwise, this
backend executes the plan the way the TL-nvSRAM-CIM macro physically
would — per 16-row group, each cell contributes ``1 - x*w`` discharge
paths to the shared CBL *weighted by its sampled conductance*
(lognormal resistance variation + CMOS mismatch from the active
:class:`~repro.faults.model.FaultModel`), and the group count is
digitized by ``core.cim.adc_transfer`` (round + clip to the 5-bit code
space) before the digital shift-&-add reconstructs the MAC assuming
*nominal* rows — exactly where conductance error and ADC saturation
become output error.  Weight trits additionally pass the model's
restore-confusion and stuck-at channels.

Registered through the standard ``register_backend`` seam with
``fidelities={'device'}`` only: it can never shadow an exact request,
and an exact backend never silently serves a ``fidelity='device'``
plan.  The active fault model is module state (``set_fault_model``) —
swapping campaigns does not change plan resolution, so cached plans
stay valid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cim import MacroConfig, adc_transfer
from repro.core.packing import unpack_base3_to_planes, unpack_trits2
from repro.core.ternary import encode_inputs
from repro.kernels.ops import PackedTernary
from repro.kernels.plan import BackendSpec, register_backend

from .model import FaultModel

DEVICE_BACKEND = "device"
ROWS_ACTIVE = MacroConfig().rows_active      # 16 rows per CBL sense

_ACTIVE_MODEL = FaultModel()


def get_fault_model() -> FaultModel:
    """The fault campaign the device backend currently executes under."""
    return _ACTIVE_MODEL


def set_fault_model(model: FaultModel) -> FaultModel:
    """Swap the active campaign (returns the previous one).  Plans are
    unaffected — fidelity routing is capability-level; the campaign only
    parameterizes the runner."""
    global _ACTIVE_MODEL
    if not isinstance(model, FaultModel):
        raise TypeError(f"expected a FaultModel, got {type(model).__name__}")
    prev, _ACTIVE_MODEL = _ACTIVE_MODEL, model
    return prev


def weight_trit_planes(w: PackedTernary, num_trits: int = 5) -> jax.Array:
    """Packed weights -> (q, ..., K, N) int8 trit planes (q = 1 for
    trit2; ``num_trits`` for base3)."""
    if w.mode == "base3":
        return unpack_base3_to_planes(w.data, num_trits)
    t = unpack_trits2(jnp.moveaxis(w.data, -2, 0), w.kdim)
    return jnp.moveaxis(t, 0, -2)[None]


def device_ternary_mac(x: jax.Array, w_trits: jax.Array,
                       w_scale: jax.Array, model: FaultModel,
                       num_trits: int = 5, adc_bits: int = 5,
                       with_stats: bool = False):
    """Analog ternary MAC: faulted trits, conductance-weighted discharge
    counts, ADC quantization, nominal digital reconstruction.

    x: (..., K) float; w_trits: (q, K, N) int8; w_scale: (N,) float.
    Returns y (..., N) f32 — or (y, clip_lo, clip_hi) scalars counting
    pre-clip ADC codes outside [0, 2^bits - 1] when ``with_stats``
    (the saturation events the serve engines monitor per chunk).
    """
    if w_trits.ndim != 3:
        raise ValueError(
            f"device backend runs per-layer (q, K, N) weights; got trit "
            f"planes of shape {w_trits.shape} (stack axes unsupported)")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    xt = encode_inputs(x2, num_trits)                   # trits (qi, B, K)
    qw, k, n = w_trits.shape
    ft = model.fault_trits(w_trits, "w")                # (qw, K, N)
    gmul = model.conductance_multiplier(ft, "g")        # (qw, K, N) f32
    qi, b, _ = xt.trits.shape
    ra = ROWS_ACTIVE
    g = -(-k // ra)
    pad = g * ra - k
    xg = xt.trits
    if pad:
        xg = jnp.pad(xg, ((0, 0), (0, 0), (0, pad)))
        ft = jnp.pad(ft, ((0, 0), (0, pad), (0, 0)))
        gmul = jnp.pad(gmul, ((0, 0), (0, pad), (0, 0)))
    xg = xg.reshape(qi, b, g, ra).astype(jnp.float32)
    wg = (ft.astype(jnp.float32) * gmul).reshape(qw, g, ra, n)
    gg = gmul.reshape(qw, g, ra, n)
    # active rows: padded rows are deactivated (0 discharge paths)
    act = (jnp.arange(g * ra).reshape(g, ra) < k).astype(jnp.float32)
    # analog CBL count per group: sum_r act * gmul * (1 - x*w)
    base = jnp.einsum("gr,jgrn->jgn", act, gg)
    mac = jnp.einsum("ibgr,jgrn->ijbgn", xg, wg)
    count = base[None, :, None, :, :] - mac             # (qi,qw,B,G,N)
    noise = None
    if model.adc_noise_sigma > 0.0:
        noise = model.adc_noise_sigma * jax.random.normal(
            model.key_for("adc", b, k, n), count.shape)
    code = adc_transfer(count, adc_bits, noise)
    # digital reconstruction assumes the NOMINAL count offset
    rows_real = jnp.minimum(ra, jnp.maximum(0, k - jnp.arange(g) * ra))
    mac_q = rows_real[None, None, None, :, None] - code
    p3i = jnp.array([3**i for i in range(qi)], jnp.int32)
    p3j = jnp.array([3**j for j in range(qw)], jnp.int32)
    y_int = jnp.einsum("ij,ijbn->bn", p3i[:, None] * p3j[None, :],
                       mac_q.sum(axis=3))
    y = (y_int.astype(jnp.float32) * xt.scale
         * w_scale.astype(jnp.float32)).reshape(*lead, n)
    if not with_stats:
        return y
    pre = jnp.round(count if noise is None else count + noise)
    clip_lo = jnp.sum(pre < 0).astype(jnp.int32)
    clip_hi = jnp.sum(pre > 2**adc_bits - 1).astype(jnp.int32)
    return y, clip_lo, clip_hi


def _run_device(plan, x, w):
    if not isinstance(w, PackedTernary):
        raise ValueError("device backend needs PackedTernary weights; "
                         f"got {type(w).__name__}")
    num_trits = plan.num_trits or 5
    planes = weight_trit_planes(w, num_trits)
    return device_ternary_mac(x, planes, w.scale, get_fault_model(),
                              num_trits=num_trits,
                              adc_bits=plan.adc_bits or 5)


def register_device_backend(model: Optional[FaultModel] = None, *,
                            priority: int = 60,
                            override: bool = True) -> None:
    """Register (or re-register) the device-fidelity backend, optionally
    activating a new fault campaign.  One ``register_backend`` call —
    no edits to call sites, ``ops``, or ``CIMConfig`` (the standing
    extension seam)."""
    if model is not None:
        set_fault_model(model)
    register_backend(BackendSpec(
        name=DEVICE_BACKEND,
        ops=frozenset({"ternary"}),
        domains=frozenset({"float"}),
        packings=frozenset({"base3", "trit2"}),
        platforms=frozenset({"cpu", "gpu", "tpu"}),
        priority=priority,
        runner=_run_device,
        kv_layouts=frozenset({"dense", "paged"}),
        fidelities=frozenset({"device"}),
    ), override=override)


# registration happens on import (kernels.backends imports this module
# after the exact built-ins), exactly like the built-in backends
register_device_backend()
