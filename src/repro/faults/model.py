"""Composable, seeded fault models for device-fidelity execution.

A :class:`FaultModel` is a frozen, hashable description of one device
instance's non-idealities — the *campaign*: every channel draws from
keys derived with ``core.seeding.stable_seed`` over (campaign seed,
channel name, tensor shape), so the same model applied to the same
tensor produces bitwise-identical faults across calls, processes, and
chunk boundaries.  Channels compose (each is independently optional):

  restore_yield — per-state restore-error confusion (HRS/MRS/LRS) from
      the measured TL yield (``core.yield_model.tl_restore_yield``),
      sampled through ``core.error_injection.inject_trit_errors``;
  stuck_rate    — stuck-at cells: a per-cell mask frozen to a random
      trit, overriding every later restore (fabrication defects);
  variation     — lognormal resistance variation + CMOS mismatch via
      ``device_models.sample_resistance`` / ``discharge_conductance``,
      exposed as a multiplicative conductance error on each cell's
      discharge path (what the ADC actually integrates);
  drift_rate    — per-chunk read-disturb/drift: the serving engines
      apply this channel between decode chunks, so error ACCUMULATES
      until a restore-scrub repairs it (``faults.scrub``).

Compose variants with :func:`dataclasses.replace`; build a model at the
measured paper yield with :func:`measured_fault_model`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import device_models as dm
from repro.core.error_injection import inject_trit_errors
from repro.core.seeding import stable_seed


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One device instance's fault channels (frozen + hashable: usable
    as a jit-static argument and a campaign cache key)."""
    seed: int = 0
    restore_yield: Optional[tuple] = None    # (y_HRS, y_MRS, y_LRS)
    stuck_rate: float = 0.0
    variation: bool = True
    drift_rate: float = 0.0                  # per-chunk disturb channel
    adc_noise_sigma: float = 0.0             # ADC readout noise (LSB)
    device: dm.DeviceParams = dm.DeviceParams()

    def __post_init__(self):
        if self.restore_yield is not None:
            ry = tuple(float(y) for y in self.restore_yield)
            if len(ry) != 3:
                raise ValueError(
                    f"restore_yield must be 3 per-state yields "
                    f"[HRS, MRS, LRS]; got {self.restore_yield!r}")
            object.__setattr__(self, "restore_yield", ry)
        for name in ("stuck_rate", "drift_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1]; got {v}")

    # ------------------------------------------------------------ keys
    def key_for(self, *parts) -> jax.Array:
        """Deterministic campaign key for a named channel draw."""
        return jax.random.key(stable_seed("faults", self.seed, *parts))

    # -------------------------------------------------------- channels
    def fault_trits(self, trits: jax.Array, *parts) -> jax.Array:
        """Restore-confusion + stuck-at channels on a trit tensor
        ((q, ..., K, N) int8 in {-1, 0, 1}).  Deterministic per
        (campaign, parts, shape) — re-applying yields the same faults."""
        shape_tag = ("x".join(map(str, trits.shape)),) + parts
        out = trits
        if self.restore_yield is not None:
            out = inject_trit_errors(
                out, jnp.asarray(self.restore_yield, jnp.float32),
                self.key_for("restore", *shape_tag))
        if self.stuck_rate > 0.0:
            km, kv = jax.random.split(self.key_for("stuck", *shape_tag))
            mask = jax.random.bernoulli(km, self.stuck_rate, trits.shape)
            stuck = jax.random.randint(kv, trits.shape, -1, 2,
                                       dtype=jnp.int32).astype(jnp.int8)
            out = jnp.where(mask, stuck, out)
        return out

    def conductance_multiplier(self, trits: jax.Array,
                               *parts) -> jax.Array:
        """Per-cell multiplicative conductance error of the discharge
        path: sampled resistance at each trit's stored level (lognormal
        filament-gap variation) + CMOS mismatch, normalized by the
        level-nominal conductance.  Ones when ``variation`` is off."""
        if not self.variation:
            return jnp.ones(trits.shape, jnp.float32)
        shape_tag = ("x".join(map(str, trits.shape)),) + parts
        kr, kc = jax.random.split(self.key_for("gvar", *shape_tag))
        level = (trits.astype(jnp.int32) + 1)      # -1/0/+1 -> HRS/MRS/LRS
        r = dm.sample_resistance(level, kr, self.device, trits.shape)
        cmos = self.device.cmos_sigma_rel * jax.random.normal(
            kc, trits.shape)
        g = dm.discharge_conductance(r, self.device, cmos)
        g_nom = dm.discharge_conductance(
            dm.level_resistance(level, self.device), self.device)
        return (g / g_nom).astype(jnp.float32)

    # ------------------------------------------------------------ misc
    def describe(self) -> dict:
        """JSON-friendly campaign record (bench artifacts)."""
        return {"seed": self.seed,
                "restore_yield": (list(self.restore_yield)
                                  if self.restore_yield else None),
                "stuck_rate": self.stuck_rate,
                "variation": self.variation,
                "drift_rate": self.drift_rate,
                "adc_noise_sigma": self.adc_noise_sigma}


def measured_fault_model(n: int = 60, m: int = 4, num_mc: int = 4096,
                         seed: int = 0, variation: bool = False,
                         **channels) -> FaultModel:
    """FaultModel at the MEASURED TL restore yield: run the Monte-Carlo
    yield model (Fig. 6 methodology) for the paper's cluster
    configuration and pin its per-state yields as the restore-confusion
    channel.  Extra ``channels`` kwargs forward to :class:`FaultModel`
    (e.g. ``drift_rate=``, ``stuck_rate=``).

    ``variation`` defaults to OFF here (unlike the raw
    :class:`FaultModel`): in the TL-nvSRAM architecture the ternary MAC
    discharges through the SRAM array — ReRAM resistance variation acts
    on the *restore* path, and the Monte-Carlo yield model has already
    sampled those same lognormal resistances into the per-state yields
    this campaign pins.  Applying the lognormal channel to the MAC
    conductances on top of that double-counts the variation (and models
    a ReRAM-CIM macro, not this paper's).  Pass ``variation=True`` for
    a ReRAM-in-the-MAC what-if campaign."""
    from repro.core.yield_model import tl_restore_yield
    key = jax.random.key(stable_seed("measured_fault_model", seed, n, m,
                                     num_mc))
    y = tl_restore_yield(key, n, m=m, num_mc=num_mc)
    per_state = tuple(float(v) for v in y["per_state"])
    return FaultModel(seed=seed, restore_yield=per_state,
                      variation=variation, **channels)
