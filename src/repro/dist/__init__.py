"""Distributed execution: mesh specs + logical-axis sharding rules."""
from . import mesh, sharding, variants                        # noqa: F401
from .mesh import (MULTI_POD, SINGLE_POD, MeshSpec, make_mesh,  # noqa: F401
                   spec_for)
from .sharding import (Rules, UnknownLogicalAxisError,        # noqa: F401
                       constrain, constrain_act, logical_to_spec,
                       named_sharding, rules_for, serve_rules,
                       set_activation_context, spec_tree, train_rules)
from .variants import (MESHES, OVERRIDES, REPLICATING_VARIANTS,  # noqa: F401
                       VariantCell, apply_override, enumerate_variants)
