"""Distributed execution: mesh specs + logical-axis sharding rules."""
from . import mesh, sharding                                  # noqa: F401
from .mesh import (MULTI_POD, SINGLE_POD, MeshSpec, make_mesh,  # noqa: F401
                   spec_for)
from .sharding import (Rules, UnknownLogicalAxisError,        # noqa: F401
                       constrain, constrain_act, logical_to_spec,
                       named_sharding, rules_for, serve_rules,
                       set_activation_context, spec_tree, train_rules)
