"""The enumerable registry of sharding-rule variants.

Every production placement the repo can lower is some base
``rules_for(cfg, mode, fsdp)`` plus at most one of the named overrides
below — the same overrides ``repro.launch.dryrun`` applies for its
``--ep data`` / ``--pure-dp`` / ``--sp`` cells.  Keeping the override
dicts HERE (and making dryrun consume them) is what lets the
``shard`` analysis pass prove contracts over the live lattice instead
of a hand-copied snapshot: a new variant added for a launch experiment
is automatically walked by the prover on the next `make analyze`.

``enumerate_variants(cfg)`` yields every (mode x fsdp x variant) cell
for one model config; crossing that with ``MESHES`` gives the full
placement lattice for the config.  All of it is abstract — ``Rules``
and ``MeshSpec`` carry no devices.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

from .mesh import MULTI_POD, SINGLE_POD, MeshSpec
from .sharding import Rules, rules_for

# name -> (Rules.with_overrides kwargs, one-line rationale).  The
# rationale strings double as documentation in `dist/README.md` and in
# shard-pass findings.
OVERRIDES: dict[str, tuple[dict, str]] = {
    "ep-data": (
        dict(expert="data"),
        "true EP: experts sharded over the DP axis — tokens move to "
        "the expert owners via all-to-all instead of XLA re-gathering "
        "the expert weights over 'data' on every use",
    ),
    "pure-dp": (
        dict(batch=("pod", "data", "model"), heads=None, kv=None,
             mlp=None, inner=None, vocab=None, expert=None,
             embed_rp=None, head_count=None, cache_seq=None),
        "small models on big meshes: fold the model axis into data "
        "parallelism (1 sequence per chip) and keep weights "
        "replicated over it",
    ),
    "sp": (
        dict(seq="model"),
        "sequence parallelism over 'model' (Megatron-SP): everything "
        "between the TP matmuls stops being replicated 16x",
    ),
}

# Variants whose POINT is weight replication: the shard pass skips its
# replication-floor rule (SD003) for these, because flagging them
# would flag the design.
REPLICATING_VARIANTS = frozenset({"pure-dp"})

# The production meshes the prover crosses the variants with.  Both
# are abstract MeshSpecs; MULTI_POD is the 512-chip 2x16x16 pod pair.
MESHES: tuple[MeshSpec, ...] = (SINGLE_POD, MULTI_POD)


def apply_override(rules: Rules, name: str) -> Rules:
    """Apply one named override variant to a base ``Rules``."""
    try:
        overrides, _ = OVERRIDES[name]
    except KeyError:
        raise KeyError(
            f"unknown rules variant {name!r}; known: "
            f"{sorted(OVERRIDES)}") from None
    return rules.with_overrides(**overrides)


class VariantCell(NamedTuple):
    """One resolved cell of the rules lattice for a model config."""
    mode: str          # "train" | "serve"
    fsdp: bool
    variant: str       # "base" or an OVERRIDES key
    rules: Rules

    @property
    def tag(self) -> str:
        fs = "fsdp" if self.fsdp else "nofsdp"
        return f"{self.mode}/{fs}/{self.variant}"


def enumerate_variants(cfg) -> Iterator[VariantCell]:
    """Yield every (mode x fsdp x variant) rules cell for one config."""
    for mode in ("train", "serve"):
        for fsdp in (True, False):
            base = rules_for(cfg, mode, fsdp=fsdp)
            yield VariantCell(mode, fsdp, "base", base)
            for name in OVERRIDES:
                yield VariantCell(mode, fsdp, name,
                                  apply_override(base, name))
