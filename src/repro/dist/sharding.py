"""Logical-axis sharding rules: names -> PartitionSpecs.

Every parameter and activation in the codebase carries *logical* axis
names (ParamDef.axes, the constrain_act call sites).  This module owns
the single mapping from those names to physical mesh axes, so a whole
parallelism strategy is one Rules object — FSDP off, expert parallelism
over 'data', sequence parallelism, pure-DP small models are all
``with_overrides`` one-liners (see launch/dryrun.py).

Resolution invariants (enforced by logical_to_spec, tested in
tests/test_sharding.py):

* **divisibility** — a dim only shards if its size divides evenly over
  the target mesh axes; otherwise it silently replicates (recorded
  honestly by the roofline, never padded).
* **no axis reuse** — one physical axis shards at most one dim of a
  given array (left-to-right, first dim wins).
* **quantum units** — dims made of indivisible units (attention heads:
  quantum = head_dim) shard by the *unit count*, so a 16-way TP axis
  never splits mid-head (40-head qwen3 replicates instead).
* **batch folding** — 'batch' maps to the tuple of data-parallel axes
  present in the mesh (('pod', 'data') on the multi-pod mesh); trailing
  axes are dropped until the batch divides, so a batch of 1 replicates.
* **zero-size dims** never shard (elastic edge case: empty buffers).

``set_activation_context`` installs the (rules, mesh) pair that
``constrain_act`` — called from dense()/attention on every activation —
resolves against; with no context it is a no-op, which is what keeps
the single-device smoke tests sharding-free.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import mesh as mesh_lib


class UnknownLogicalAxisError(KeyError):
    """A ParamDef / constraint names a logical axis no rule covers."""


# ---------------------------------------------------------------------
# rule sets
# ---------------------------------------------------------------------

# Default logical->physical mapping for training.  Values are a physical
# axis name, a tuple of names (folded jointly, trailing ones dropped on
# divisibility failure), or None (replicate).
_TRAIN_AXES = {
    # activations
    "batch": mesh_lib.DP_AXES,
    # continuous-batching slot pool: the pool's leading slot axis IS the
    # serving batch axis, so it folds over the same DP axes (the per-slot
    # inner batch of 1 then replicates by divisibility)
    "slot": mesh_lib.DP_AXES,
    # paged-KV block pool: pages distribute over the same DP axes the
    # slots fold over (each data shard owns a stripe of the page pool;
    # per-slot gathers cross shards only for pages another shard wrote —
    # the prefix-shared ones).  Same folding/divisibility policy.
    "page": mesh_lib.DP_AXES,
    "seq": None,
    "kv_seq": None,
    "head_count": "model",
    "act_embed": None,
    # parameters
    "layers": None,
    "embed": "data",            # FSDP: weights reduce-scattered over DP
    "embed_rp": "model",        # row-parallel contraction (kv projections)
    "vocab": "model",           # unembed: column-parallel TP
    "vocab_in": None,           # lookup table: vocab dim never sharded
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "inner": "model",           # SSM expanded dim
    "expert": "model",
    "cache_seq": None,
    "none": None,
}

# Serving additionally shards the KV-cache sequence dim over the TP axis
# (decode is cache-bandwidth bound; each chip reads cap/16 positions) and
# anchors cache reads ('kv_seq') to match.
_SERVE_AXES = {**_TRAIN_AXES, "cache_seq": "model", "kv_seq": "model"}


@dataclasses.dataclass(frozen=True)
class Rules:
    """Immutable logical->physical axis mapping + sharding quanta."""
    axis_map: Any                      # dict[str, str | tuple | None]
    quantum: Any = None                # dict[str, int] — unit sizes

    def physical(self, name: str):
        try:
            return self.axis_map[name]
        except KeyError:
            raise UnknownLogicalAxisError(
                f"no sharding rule for logical axis {name!r}; known axes: "
                f"{sorted(self.axis_map)}") from None

    def with_overrides(self, **overrides) -> "Rules":
        """New Rules with some logical axes remapped (None = replicate)."""
        return Rules({**self.axis_map, **overrides}, dict(self.quantum or {}))

    def with_quantum(self, **units) -> "Rules":
        return Rules(dict(self.axis_map), {**(self.quantum or {}), **units})


def train_rules(fsdp: bool = True, quantum: Optional[dict] = None) -> Rules:
    axes = dict(_TRAIN_AXES)
    if not fsdp:
        axes["embed"] = None
    return Rules(axes, dict(quantum or {}))


def serve_rules(fsdp: bool = True, quantum: Optional[dict] = None) -> Rules:
    axes = dict(_SERVE_AXES)
    if not fsdp:
        axes["embed"] = None
    return Rules(axes, dict(quantum or {}))


def rules_for(cfg, mode: str, fsdp: bool = True) -> Rules:
    """Rules for a ModelConfig: head-bearing dims get quantum = head_dim
    so TP never splits inside a head (GQA kv groups included)."""
    quantum = {"heads": cfg.hd, "kv": cfg.hd}
    if mode == "train":
        return train_rules(fsdp=fsdp, quantum=quantum)
    if mode == "serve":
        return serve_rules(fsdp=fsdp, quantum=quantum)
    raise ValueError(f"mode must be 'train' or 'serve', got {mode!r}")


# ---------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------

def _resolve_dim(candidate, dim: int, name: str, sizes: dict, used: set,
                 quantum: dict):
    """One dim -> PartitionSpec entry (axis name, tuple, or None)."""
    if candidate is None or dim == 0:
        return None
    axes = (candidate,) if isinstance(candidate, str) else tuple(candidate)
    axes = tuple(a for a in axes if a in sizes and a not in used)
    if not axes:
        return None
    q = (quantum or {}).get(name, 1)
    if q > 1 and dim % q:
        return None                      # partial unit: cannot shard at all
    units = dim // q
    # drop trailing axes until the unit count divides the fold product
    while axes:
        prod = math.prod(sizes[a] for a in axes)
        if units % prod == 0:
            break
        axes = axes[:-1]
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def logical_to_spec(axes: tuple, shape: tuple, rules: Rules,
                    mesh) -> P:
    """Resolve logical axes against a mesh into a PartitionSpec.

    `mesh` may be a real Mesh, a MeshSpec, or anything with .axis_names
    + .devices.  Trailing replicated dims are trimmed (P('data') rather
    than P('data', None)) so specs compare naturally in tests and stay
    rank-compatible with scalar/low-rank leaves.
    """
    if len(axes) != len(shape):
        raise ValueError(
            f"axes {axes} and shape {shape} disagree on rank")
    sizes = mesh_lib.axis_sizes(mesh)
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        entry = _resolve_dim(rules.physical(name), dim, name, sizes, used,
                             rules.quantum)
        if entry is not None:
            used.update((entry,) if isinstance(entry, str) else entry)
        entries.append(entry)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def page_spmd_axes(rules: Rules, mesh, pages: int):
    """Physical mesh axes the paged-KV pool's leading ``page`` axis
    folds over — the page-pool mirror of :func:`slot_spmd_axes`, with
    the same folding/divisibility policy (an indivisible pool
    replicates; returns None when 'page' resolves to replicated)."""
    entry = _resolve_dim(rules.physical("page"), pages, "page",
                         mesh_lib.axis_sizes(mesh), set(), rules.quantum)
    if entry is None:
        return None
    return entry if isinstance(entry, str) else tuple(entry)


def slot_spmd_axes(rules: Rules, mesh, slots: int):
    """Physical mesh axes the slot-pool axis folds over, in the form
    ``jax.vmap(spmd_axis_name=...)`` takes — how the chunked decode loop
    (serve.make_chunked_decode_loop) threads the 'slot' rule into every
    activation constraint under its per-slot vmap.

    Applies the same folding/divisibility policy as logical_to_spec
    (trailing DP axes dropped until `slots` divides), so an indivisible
    pool replicates instead of failing inside vmap.  Returns None when
    the slot axis resolves to replicated (e.g. off-mesh engines).
    """
    entry = _resolve_dim(rules.physical("slot"), slots, "slot",
                         mesh_lib.axis_sizes(mesh), set(), rules.quantum)
    if entry is None:
        return None
    return entry if isinstance(entry, str) else tuple(entry)


def spec_tree(defs: Any, rules: Rules, mesh) -> Any:
    """ParamDef tree -> PartitionSpec tree (same structure)."""
    from repro.models.config import is_def
    return jax.tree.map(
        lambda d: logical_to_spec(d.axes, d.shape, rules, mesh), defs,
        is_leaf=is_def)


def named_sharding(axes: tuple, shape: tuple, rules: Rules,
                   mesh) -> NamedSharding:
    """NamedSharding for one array (requires a real Mesh)."""
    return NamedSharding(mesh, logical_to_spec(axes, shape, rules, mesh))


# ---------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------

def constrain(x, axes: tuple, rules: Optional[Rules], mesh):
    """with_sharding_constraint through the rule engine (no-op off-mesh)."""
    if rules is None or mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(axes, x.shape, rules, mesh))


# Module-level activation context: model code (dense(), attention) calls
# constrain_act without threading rules/mesh through every signature;
# make_train_step / the dry-run install the context before tracing.
_ACT_CTX = threading.local()


def set_activation_context(rules: Optional[Rules], mesh) -> None:
    """Install (rules, mesh) for constrain_act; either None clears it."""
    if rules is None or mesh is None:
        _ACT_CTX.value = None
    else:
        _ACT_CTX.value = (rules, mesh)


def get_activation_context():
    return getattr(_ACT_CTX, "value", None)


def constrain_act(x, axes: Optional[tuple] = None):
    """Re-anchor an activation's sharding (no-op without a context).

    Default axes assume (batch, seq, *feature) layout with features
    replicated — the layout of every residual-stream activation.
    """
    ctx = get_activation_context()
    if ctx is None or x.ndim < 2:
        return x
    rules, mesh = ctx
    if axes is None:
        axes = ("batch", "seq") + ("none",) * (x.ndim - 2)
    return constrain(x, axes, rules, mesh)
