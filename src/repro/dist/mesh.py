"""Device-mesh specifications and construction.

A MeshSpec is a *declarative* mesh description (shape + axis names) that
can be reasoned about without touching jax device state — the dry-run
and the sharding tests resolve rules against specs (or duck-typed fake
meshes) long before any devices exist.  ``make_mesh`` turns a spec into
a real ``jax.sharding.Mesh`` over whatever devices the process has
(production chips, or fake CPU devices forced via
``--xla_force_host_platform_device_count``).

Axis conventions (shared with dist.sharding):

  pod    — outermost data-parallel axis (inter-pod DCN-class links)
  data   — intra-pod data-parallel / FSDP axis
  model  — tensor-parallel axis (heads / mlp / vocab / experts)
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax

# Axes over which the global batch is folded (outermost first).
DP_AXES = ("pod", "data")


class MeshSpec(NamedTuple):
    """Shape + axis names; construction-free mesh description."""
    shape: tuple
    axes: tuple

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def dp_axes(self) -> tuple:
        """The data-parallel axes this mesh actually has."""
        return tuple(a for a in self.axes if a in DP_AXES)

    @property
    def axis_sizes(self) -> dict:
        return dict(zip(self.axes, self.shape))


SINGLE_POD = MeshSpec((16, 16), ("data", "model"))
MULTI_POD = MeshSpec((2, 16, 16), ("pod", "data", "model"))


def _pow2_factor(n: int, cap: int) -> int:
    """Largest power-of-two divisor of n, capped at `cap`."""
    f = 1
    while n % (f * 2) == 0 and f * 2 <= cap:
        f *= 2
    return f


def spec_for(n: int, *, multi_pod: bool = False) -> MeshSpec:
    """A MeshSpec for exactly `n` devices.

    The model (TP) axis takes the largest power-of-two factor of n (up to
    16, the production TP width); the data axis absorbs the rest, so
    non-power-of-two device counts still produce a valid mesh (the odd
    factor lands on 'data' where divisibility only gates batch folding).
    `multi_pod` peels a pod axis of 2 off first when n is even.
    """
    if n <= 0:
        raise ValueError(f"device count must be positive, got {n}")
    if multi_pod:
        pod = 2 if n % 2 == 0 else 1
        rest = n // pod
        model = _pow2_factor(rest, 16)
        return MeshSpec((pod, rest // model, model),
                        ("pod", "data", "model"))
    model = _pow2_factor(n, 16)
    return MeshSpec((n // model, model), ("data", "model"))


def make_mesh(spec: MeshSpec, devices=None) -> jax.sharding.Mesh:
    """Materialize a spec over real devices (default: all local devices).

    Requires ``spec.num_devices`` devices; the multi-device tests run in
    a subprocess with ``--xla_force_host_platform_device_count`` set
    before jax initializes.
    """
    if devices is None:
        return jax.make_mesh(spec.shape, spec.axes)
    import numpy as np
    arr = np.asarray(devices).reshape(spec.shape)
    return jax.sharding.Mesh(arr, spec.axes)


def axis_sizes(mesh) -> dict:
    """{axis name: size} for a real Mesh, a MeshSpec, or any duck-typed
    object with .axis_names + .devices (the tests' FakeMesh)."""
    if isinstance(mesh, MeshSpec):
        return mesh.axis_sizes
    return dict(zip(mesh.axis_names, mesh.devices.shape))
