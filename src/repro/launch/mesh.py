"""Production mesh builders (launch-side; dist/mesh.py holds the generic
machinery).  FUNCTIONS, not module-level constants — importing this module
must never touch jax device state, because the dry-run sets XLA_FLAGS
before the first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
