import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
against the production meshes, and extract the roofline terms.

The two lines above MUST stay the first statements in this file — jax
locks the host platform device count on first initialization, and the
dry-run needs 512 placeholder devices for the 2x16x16 multi-pod mesh.
(Do NOT import this module from tests; run it as a subprocess.)

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape decode_32k \
      --multi-pod --packed base3
  python -m repro.launch.dryrun --all            # subprocess per cell, resumable
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

DEFAULT_OUT = "experiments/dryrun"


def _mesh_tag(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def cell_filename(arch: str, shape: str, multi_pod: bool,
                  packed: str | None) -> str:
    tag = _mesh_tag(multi_pod)
    suffix = f"__{packed}" if packed else ""
    return f"{arch}__{shape}__{tag}{suffix}.json"


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             packed: str | None = None, microbatches: int = 0,
             fsdp: bool = True, remat: str = "full",
             opt_name: str = "auto", ep: str = "model", sp: bool = False,
             pure_dp: bool = False, kv_cache: str = "",
             decode_loop: int = 0, continuous: int = 0,
             kv_layout: str = "dense", page_size: int = 16,
             fidelity: str = "exact",
             extra_tags: dict | None = None) -> dict:
    from repro import configs
    from repro.configs.shapes import SHAPES, runnable
    from repro.dist import sharding as shd
    from repro.dist import variants
    from repro.launch.input_specs import (abstract_cache,
                                          abstract_model_params,
                                          decode_loop_specs,
                                          decode_token_spec,
                                          paged_pool_specs,
                                          prefill_batch_specs,
                                          slot_pool_specs,
                                          train_batch_specs)
    from repro.launch.mesh import make_production_mesh
    from repro.models import registry
    from repro.roofline import analyze_compiled
    from repro.core.cim_linear import CIMConfig

    if fidelity == "device" and not packed:
        raise ValueError("fidelity 'device' requires packed ternary "
                         "weights (--packed); the device model faults "
                         "packed trits")
    cfg = configs.get(arch)
    cell = SHAPES[shape]
    meta = {"arch": arch, "shape": shape, "mesh": _mesh_tag(multi_pod),
            "packed": packed, "fsdp": fsdp, "remat": remat,
            "microbatches": microbatches, **(extra_tags or {})}
    ok, reason = runnable(cfg, cell)
    if not ok:
        return {**meta, "skipped": reason}
    if kv_cache == "int8":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        meta["kv_cache"] = "int8"

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mode = "train" if cell.kind == "train" else "serve"
    rules = shd.rules_for(cfg, mode, fsdp=fsdp)
    # the named overrides live in dist.variants (the registry the
    # `shard` analysis pass proves contracts over) — applying them
    # through apply_override keeps the dry-run and the prover on the
    # same lattice; see each OVERRIDES entry for the rationale
    if ep == "data":
        rules = variants.apply_override(rules, "ep-data")
        meta["ep"] = ep
    if pure_dp:
        rules = variants.apply_override(rules, "pure-dp")
        meta["pure_dp"] = True
    if sp:
        # activations shard (batch x data, seq x model): the TP matmuls
        # all-gather / reduce-scatter the seq axis around them (same
        # wire bytes as the TP all-reduces they replace) but norms,
        # residuals, rope, and crucially ATTENTION SCORES for archs
        # whose head count does not divide the 16-way model axis
        # (qwen3: 40H, whisper: 20H) stop being replicated 16x
        rules = variants.apply_override(rules, "sp")
        meta["sp"] = True
    shd.set_activation_context(rules, mesh)
    if cell.kind == "train" and remat != "config":
        cfg = dataclasses.replace(cfg, remat=remat)
    model = registry.build(cfg)
    # resolved once against the kernel registry: the dry-run pins the
    # xla backend (Pallas TPU kernels cannot lower on the CPU host
    # platform) and records the resolved routing in the cell metadata.
    # A 'device' fidelity request cannot pin xla (the fault-injected
    # backend is the only device-capable one): it resolves 'auto' under
    # the cell's phase, so decode cells lower the device path and
    # prefill cells route back to an exact backend (route_fidelity).
    cim = None
    if packed:
        if fidelity == "device":
            if cell.kind == "train":
                raise ValueError("--fidelity device is a serving "
                                 "fidelity; train cells have no device "
                                 "path")
            from repro import faults
            faults.set_fault_model(faults.measured_fault_model(
                num_mc=1024))
            phase = "decode" if cell.kind == "decode" else "prefill"
            cim = CIMConfig(mode="ternary", packing=packed,
                            backend="auto",
                            fidelity="device").resolve(phase=phase)
        else:
            cim = CIMConfig(mode="ternary", packing=packed,
                            backend="xla").resolve()
        meta["cim_backend"] = cim.backend
        meta["cim_fidelity"] = cim.fidelity

    t0 = time.monotonic()
    if cell.kind == "train":
        from repro.optim import adafactor, adamw, warmup_cosine
        from repro.train.step import make_abstract_state, make_train_step
        nparams = cfg.param_count()
        use_adafactor = (opt_name == "adafactor" or
                         (opt_name == "auto" and nparams > 3e9))
        lr = warmup_cosine(3e-4, 1000, 100_000)
        opt = adafactor(lr) if use_adafactor else adamw(lr)
        meta["optimizer"] = "adafactor" if use_adafactor else "adamw"
        mb = microbatches or (8 if cell.global_batch >= 64 else 1)
        meta["microbatches"] = mb
        state_abs, _specs = make_abstract_state(model, opt, rules, mesh)
        batch_abs = train_batch_specs(cfg, cell, rules, mesh)
        step_fn = make_train_step(model, opt, cim=cim, microbatches=mb,
                                  rules=rules, mesh=mesh)
        lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(
            state_abs, batch_abs)
        tokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        params_abs = abstract_model_params(model, rules, mesh, packed)
        batch_abs = prefill_batch_specs(cfg, cell, rules, mesh)

        def prefill_step(params, batch):
            logits, state = model.prefill(params, batch, cell.seq_len,
                                          cim=cim)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), state

        lowered = jax.jit(prefill_step).lower(params_abs, batch_abs)
        tokens = cell.global_batch * cell.seq_len
    else:                                   # decode
        params_abs = abstract_model_params(model, rules, mesh, packed)
        if kv_layout == "paged" and not continuous:
            raise ValueError("--kv paged requires --continuous SLOTS "
                             "(the paged pool is a continuous-batching "
                             "slot-pool layout)")
        if continuous and kv_layout == "paged":
            # paged-KV slot pool: lower one chunked round of the paged
            # scheduler loop (serve.make_paged_decode_loop) — the page
            # pool on a 'page' logical axis, per-slot page tables +
            # write positions, one host transfer per chunk.
            from repro.serve import make_paged_decode_loop
            chunk = decode_loop if decode_loop >= 1 else 8
            pages_per_slot = -(-cell.seq_len // page_size)
            num_pages = 1 + continuous * pages_per_slot
            (pool_abs, table_abs, pos_abs, tok_abs, live_abs, made_abs,
             fresh_abs, mn_abs, eos_abs) = paged_pool_specs(
                model, cell, rules, mesh, continuous, page_size,
                num_pages)
            loop_fn = make_paged_decode_loop(
                model, chunk, cim,
                spmd_axes=shd.slot_spmd_axes(rules, mesh, continuous))
            lowered = loop_fn.lower(params_abs, tok_abs, pool_abs,
                                    table_abs, pos_abs, live_abs,
                                    made_abs, fresh_abs, mn_abs, eos_abs)
            tokens = continuous * chunk
            meta["continuous_slots"] = continuous
            meta["chunk"] = chunk
            meta["kv_layout"] = "paged"
            meta["page_size"] = page_size
            meta["num_pages"] = num_pages
        elif continuous:
            # continuous-batching slot pool: lower one chunked decode
            # round (serve.make_chunked_decode_loop) — per-slot batch-1
            # states at independent positions, slot axis folded over DP,
            # one host transfer per chunk.  Chunk budget comes from
            # --decode-loop (default 8 steps).
            from repro.serve import make_chunked_decode_loop
            chunk = decode_loop if decode_loop >= 1 else 8
            specs = slot_pool_specs(model, cell, rules, mesh, continuous)
            pool_abs, tok_abs, live_abs, made_abs, fresh_abs, mn_abs, \
                eos_abs = specs
            loop_fn = make_chunked_decode_loop(
                model, chunk, cim,
                spmd_axes=shd.slot_spmd_axes(rules, mesh, continuous))
            lowered = loop_fn.lower(params_abs, tok_abs, pool_abs,
                                    live_abs, made_abs, fresh_abs,
                                    mn_abs, eos_abs)
            # at most `chunk` tokens per slot per scheduling round
            tokens = continuous * chunk
            meta["continuous_slots"] = continuous
            meta["chunk"] = chunk
        elif decode_loop:
            # the serving fast lane: lower the whole on-device
            # lax.while_loop decode body (one host transfer per bucket)
            # instead of a single step — proves the loop-carried cache +
            # live-mask graph compiles against the production mesh
            cache_abs = abstract_cache(model, cell, rules, mesh)
            if decode_loop < 2:
                raise ValueError("--decode-loop needs >= 2: slot 0 of the "
                                 "token buffer is the prefill token passed "
                                 "in, so a 1-token loop lowers a graph "
                                 "with zero decode steps")
            from repro.serve import make_decode_loop
            tok_abs, mn_abs, eos_abs = decode_loop_specs(cell, rules, mesh)
            loop_fn = make_decode_loop(model, decode_loop, cim)
            lowered = loop_fn.lower(params_abs, tok_abs, cache_abs,
                                    mn_abs, eos_abs)
            # the loop body runs at most max_new - 1 decode steps: slot 0
            # of the buffer is the prefill token passed IN, not generated
            # by this graph
            tokens = cell.global_batch * (decode_loop - 1)
            meta["decode_loop"] = decode_loop
        else:
            cache_abs = abstract_cache(model, cell, rules, mesh)
            token_abs = decode_token_spec(cell, rules, mesh)

            def serve_step(params, token, state):
                logits, st = model.decode(params, token, state, cim=cim)
                return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), st

            lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
                params_abs, token_abs, cache_abs)
            tokens = cell.global_batch
    t_lower = time.monotonic() - t0

    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    print(compiled.memory_analysis())       # proves it fits
    ca = compiled.cost_analysis()
    print({k: v for k, v in (ca[0] if isinstance(ca, list) else ca).items()
           if k in ("flops", "bytes accessed")})

    report = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=_mesh_tag(multi_pod),
        chips=chips, cfg=cfg, tokens=tokens,
        kind="train" if cell.kind == "train" else "serve")
    out = {**meta, "lower_s": round(t_lower, 2),
           "compile_s": round(t_compile, 2), **report.to_dict()}
    return out


def save_result(result: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    fname = cell_filename(result["arch"], result["shape"],
                          result["mesh"] == "2x16x16", result.get("packed"))
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1, default=str)
    if "skipped" in result:
        print(f"[skip] {fname}: {result['skipped']}")
    else:
        print(f"[ok]   {fname}: bottleneck={result['bottleneck']} "
              f"compute={result['t_compute']*1e3:.2f}ms "
              f"memory={result['t_memory']*1e3:.2f}ms "
              f"collective={result['t_collective']*1e3:.2f}ms "
              f"(compile {result['compile_s']}s)")


def sweep(out_dir: str, multi_pod_too: bool = True, resume: bool = True,
          packed: str | None = None, archs=None, timeout: int = 3600):
    """Subprocess-per-cell sweep (isolates XLA state; resumable)."""
    from repro import configs
    from repro.configs.shapes import SHAPES
    meshes = [False, True] if multi_pod_too else [False]
    failures = []
    for arch in (archs or configs.ARCHS):
        for shape in SHAPES:
            for mp in meshes:
                fname = cell_filename(arch, shape, mp, packed)
                path = os.path.join(out_dir, fname)
                if resume and os.path.exists(path):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--out-dir", out_dir]
                if mp:
                    cmd.append("--multi-pod")
                if packed:
                    cmd += ["--packed", packed]
                print(f"--- {fname}", flush=True)
                try:
                    r = subprocess.run(cmd, timeout=timeout,
                                       capture_output=True, text=True)
                    if r.returncode:
                        failures.append(fname)
                        print(r.stdout[-2000:])
                        print(r.stderr[-4000:])
                except subprocess.TimeoutExpired:
                    failures.append(fname + " (timeout)")
    print(f"sweep done; {len(failures)} failures: {failures}")
    return failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--single-pod-only", action="store_true")
    p.add_argument("--packed", choices=("base3", "trit2"))
    p.add_argument("--microbatches", type=int, default=0)
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--remat", default="full",
                   choices=("full", "dots", "none", "config"))
    p.add_argument("--opt", default="auto",
                   choices=("auto", "adamw", "adafactor"))
    p.add_argument("--ep", default="model", choices=("model", "data"))
    p.add_argument("--sp", action="store_true",
                   help="sequence parallelism over the model axis")
    p.add_argument("--pure-dp", action="store_true",
                   help="fold the model axis into data parallelism")
    p.add_argument("--kv-cache", default="", choices=("", "int8"),
                   help="KV cache storage dtype (int8 = scaled)")
    p.add_argument("--decode-loop", type=int, default=0,
                   help="decode cells: lower the on-device decode loop "
                        "with this max-new budget instead of one step")
    p.add_argument("--continuous", type=int, default=0, metavar="SLOTS",
                   help="decode cells: lower one chunked round of the "
                        "continuous-batching slot pool with this many "
                        "slots (chunk budget = --decode-loop, default 8)")
    p.add_argument("--kv", default="dense", choices=("dense", "paged"),
                   help="slot-pool KV layout for --continuous: dense "
                        "per-slot caches or the paged block pool "
                        "(serve.make_paged_decode_loop)")
    p.add_argument("--page-size", type=int, default=16,
                   help="positions per KV page for --kv paged")
    p.add_argument("--fidelity", default="exact",
                   choices=("exact", "device"),
                   help="execution fidelity for packed cells: 'device' "
                        "lowers decode through the fault-injected "
                        "analog backend (prefill cells route back to "
                        "exact — see repro.faults)")
    p.add_argument("--out-dir", default=DEFAULT_OUT)
    p.add_argument("--tag", default=None,
                   help="suffix for the output file (perf experiments)")
    args = p.parse_args(argv)
    if args.kv == "paged" and not args.continuous:
        p.error("--kv paged requires --continuous SLOTS (the paged "
                "pool is a continuous-batching slot-pool layout)")

    if args.all:
        fails = sweep(args.out_dir, multi_pod_too=not args.single_pod_only,
                      packed=args.packed)
        sys.exit(1 if fails else 0)

    if not args.arch or not args.shape:
        p.error("--arch and --shape required (or --all)")
    # no blanket except here: a failing cell should crash with its real
    # traceback and the interpreter's nonzero exit, not a laundered
    # sys.exit(1) that hides the exception type from callers
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   packed=args.packed, microbatches=args.microbatches,
                   fsdp=not args.no_fsdp, remat=args.remat,
                   opt_name=args.opt, ep=args.ep, sp=args.sp,
                   pure_dp=args.pure_dp, kv_cache=args.kv_cache,
                   decode_loop=args.decode_loop,
                   continuous=args.continuous, kv_layout=args.kv,
                   page_size=args.page_size, fidelity=args.fidelity)
    if args.tag:
        res["tag"] = args.tag
        os.makedirs(args.out_dir, exist_ok=True)
        fname = cell_filename(res["arch"], res["shape"],
                              res["mesh"] == "2x16x16", res.get("packed"))
        fname = fname.replace(".json", f"__{args.tag}.json")
        with open(os.path.join(args.out_dir, fname), "w") as f:
            json.dump(res, f, indent=1, default=str)
        print(f"[ok] {fname}")
    else:
        save_result(res, args.out_dir)


if __name__ == "__main__":
    main()
