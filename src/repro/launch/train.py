"""Training launcher.

On the CPU container this drives smoke-scale configs end-to-end (the
same code path the fault-tolerance tests use); on a real TPU slice the
same CLI runs the full assigned configs — the mesh, sharding rules, and
step function are identical, only the device count changes.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="internlm2-1.8b")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced same-family config (CPU)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    p.add_argument("--ckpt-interval", type=int, default=50)
    p.add_argument("--log-interval", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--optimizer", default="adamw",
                   choices=("adamw", "adafactor", "sgd"))
    p.add_argument("--cim", default="float",
                   choices=("float", "ternary", "exact"))
    args = p.parse_args(argv)

    from repro import configs, optim
    from repro.core.cim_linear import CIMConfig
    from repro.data import DataConfig, entropy_floor
    from repro.models import registry
    from repro.train import Trainer, TrainerConfig

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = registry.build(cfg)
    n = cfg.param_count()
    print(f"arch={cfg.name} family={cfg.family} params={n/1e6:.1f}M "
          f"devices={jax.device_count()}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    print(f"data entropy floor ~= {entropy_floor(data_cfg):.3f} nats/token")

    lr = optim.warmup_cosine(args.lr, max(args.steps // 20, 5), args.steps)
    opt = {"adamw": optim.adamw, "adafactor": optim.adafactor,
           "sgd": optim.sgd}[args.optimizer](lr)
    cim = None if args.cim == "float" else CIMConfig(mode=args.cim)

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_interval=args.ckpt_interval,
                         log_interval=args.log_interval,
                         microbatches=args.microbatches, seed=args.seed)
    trainer = Trainer(model, opt, data_cfg, tcfg, cim=cim)

    t0 = time.monotonic()
    state = trainer.run()
    dt = time.monotonic() - t0
    losses = [h["loss"] for h in trainer.history]
    tok_per_step = args.batch * args.seq
    print(json.dumps({
        "steps": int(state.step),
        "first_loss": round(losses[0], 4) if losses else None,
        "last_loss": round(sum(losses[-10:]) / max(len(losses[-10:]), 1), 4),
        "wall_s": round(dt, 1),
        "tokens_per_s": round(tok_per_step * len(losses) / max(dt, 1e-9)),
        "restarts": trainer.restarts,
    }))


if __name__ == "__main__":
    main()
