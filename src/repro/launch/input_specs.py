"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape).

Everything here is weak-type-correct, carries a NamedSharding resolved
through the logical-axis rules, and never allocates device memory — the
dry-run lowers and compiles against these.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeCell
from repro.dist import sharding as shd
from repro.models.config import ParamDef, abstract_params


def _sds(shape, dtype, axes, rules, mesh):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=shd.named_sharding(axes, shape, rules, mesh))


def train_batch_specs(cfg, cell: ShapeCell, rules, mesh) -> dict:
    b, s = cell.global_batch, cell.seq_len
    batch = {
        "tokens": _sds((b, s), jnp.int32, ("batch", "seq"), rules, mesh),
        "labels": _sds((b, s), jnp.int32, ("batch", "seq"), rules, mesh),
    }
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype,
                               ("batch", "seq", "act_embed"), rules, mesh)
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype,
                                ("batch", "seq", "act_embed"), rules, mesh)
    return batch


def prefill_batch_specs(cfg, cell: ShapeCell, rules, mesh) -> dict:
    b, s = cell.global_batch, cell.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32, ("batch", "seq"), rules, mesh)}
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype,
                               ("batch", "seq", "act_embed"), rules, mesh)
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype,
                                ("batch", "seq", "act_embed"), rules, mesh)
    return batch


def decode_token_spec(cell: ShapeCell, rules, mesh):
    return _sds((cell.global_batch, 1), jnp.int32, ("batch", "none"),
                rules, mesh)


def decode_loop_specs(cell: ShapeCell, rules, mesh):
    """Inputs of serve.make_decode_loop beyond params/cache: the
    prefill-sampled token and the per-row max-new/EOS vectors — all (B,)
    int32, batch-sharded like the decode token."""
    b = cell.global_batch
    mk = lambda: _sds((b,), jnp.int32, ("batch",), rules, mesh)
    return mk(), mk(), mk()


def abstract_model_params(model, rules, mesh, packed: str | None = None):
    """Params as ShapeDtypeStructs with shardings.

    packed='base3'|'trit2' replaces every eligible weight with an abstract
    PackedTernary (uint8 data + per-column scales) — the ternary-served
    dry-run (paper density mechanism in the memory-roofline term).
    """
    mk = lambda d: shd.named_sharding(d.axes, d.shape, rules, mesh)
    if packed is None:
        return abstract_params(model.param_defs, model.cfg.dtype, mk)

    from repro.kernels.ops import PackedTernary, TRIT2_PER_BYTE

    def convert(d: ParamDef):
        dt = d.dtype or model.cfg.dtype
        # routers stay float (routing-logit precision; f32 ParamDefs are
        # excluded because their dtype is pinned)
        eligible = (d.init == "normal" and len(d.shape) >= 2
                    and min(d.shape[-2:]) >= 256 and "vocab" != d.axes[0]
                    and d.dtype is None)
        if not eligible:
            sh = mk(d)
            return jax.ShapeDtypeStruct(d.shape, dt, sharding=sh)
        k, n = d.shape[-2], d.shape[-1]
        lead = d.shape[:-2]
        if packed == "trit2":
            data_shape = lead + (k // TRIT2_PER_BYTE, n)   # 4 trits / byte
        else:
            data_shape = lead + (k, n)                     # 1 byte / 5-trit
        data = jax.ShapeDtypeStruct(
            data_shape, jnp.uint8,
            sharding=shd.named_sharding(d.axes, data_shape, rules, mesh))
        scale_shape = lead + (n,)
        scale_axes = d.axes[:-2] + (d.axes[-1],)
        scale = jax.ShapeDtypeStruct(
            scale_shape, jnp.float32,
            sharding=shd.named_sharding(scale_axes, scale_shape, rules, mesh))
        return PackedTernary(data, scale, packed)

    return jax.tree.map(convert, model.param_defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_cache(model, cell: ShapeCell, rules, mesh):
    """Decode-state ShapeDtypeStructs (KV caches / SSM states / pos)."""
    defs = model.cache_defs(cell.global_batch, cell.seq_len)
    mk = lambda d: shd.named_sharding(d.axes, d.shape, rules, mesh)
    return abstract_params(defs, model.cfg.dtype, mk)


def paged_pool_specs(model, cell: ShapeCell, rules, mesh, slots: int,
                     page_size: int, num_pages: int):
    """Inputs of serve.make_paged_decode_loop beyond params: the paged
    KV block pool (pages on a leading 'page' logical axis, folded over
    the DP mesh axes — the page-pool mirror of the slot specs), the
    per-slot page tables / write positions, and the control lanes."""
    from repro.models.paged_kv import PagedKVCache

    cfg = model.cfg
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    int8 = cfg.kv_cache_dtype == "int8"
    kvdt = jnp.int8 if int8 else cfg.dtype
    pshape = (L, num_pages, page_size, kv, hd)
    paxes = ("layers", "page", "none", "kv", "none")
    pool = {"k_pages": _sds(pshape, kvdt, paxes, rules, mesh),
            "v_pages": _sds(pshape, kvdt, paxes, rules, mesh)}
    if int8:
        sshape, saxes = pshape[:-1], paxes[:-1]
        pool["k_scale_pages"] = _sds(sshape, jnp.float32, saxes, rules,
                                     mesh)
        pool["v_scale_pages"] = _sds(sshape, jnp.float32, saxes, rules,
                                     mesh)
    pool_abs = PagedKVCache(pool["k_pages"], pool["v_pages"],
                            pool.get("k_scale_pages"),
                            pool.get("v_scale_pages"))
    pages_per_slot = -(-cell.seq_len // page_size)
    table = _sds((slots, pages_per_slot), jnp.int32, ("slot", "none"),
                 rules, mesh)
    lane = lambda dt: _sds((slots,), dt, ("slot",), rules, mesh)
    return (pool_abs, table, lane(jnp.int32), lane(jnp.int32),
            lane(jnp.bool_), lane(jnp.int32), lane(jnp.bool_),
            lane(jnp.int32), lane(jnp.int32))


def slot_pool_specs(model, cell: ShapeCell, rules, mesh, slots: int):
    """Inputs of serve.make_chunked_decode_loop beyond params: the
    pooled decode state (per-slot batch-1 caches stacked on a leading
    'slot' axis, folded over the DP mesh axes) and the per-slot control
    lanes (tok, live, made, fresh, max_new, eos — all (slots,),
    slot-sharded like the pool)."""
    defs = model.cache_defs(1, cell.seq_len)
    pooled = jax.tree.map(
        lambda d: ParamDef((slots,) + d.shape, ("slot",) + d.axes,
                           d.init, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    mk = lambda d: shd.named_sharding(d.axes, d.shape, rules, mesh)
    pool_abs = abstract_params(pooled, model.cfg.dtype, mk)
    lane = lambda dt: _sds((slots,), dt, ("slot",), rules, mesh)
    return (pool_abs, lane(jnp.int32), lane(jnp.bool_), lane(jnp.int32),
            lane(jnp.bool_), lane(jnp.int32), lane(jnp.int32))
