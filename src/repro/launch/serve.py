"""Serving launcher — batched requests against a (optionally ternary-
packed) model.  The paper's end-to-end mode: weights stored at 1 byte /
5-trit weight (base3) or 2 bits/trit (trit2) and dequantized on-load.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --requests 16 --prompt-len 32 --max-new 16 --packed base3
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="internlm2-1.8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--capacity", type=int, default=256)
    p.add_argument("--packed", choices=("base3", "trit2"))
    p.add_argument("--domain", default="float", choices=("float", "int8"),
                   help="ternary-mode MXU domain (int8 = decode fast lane)")
    p.add_argument("--legacy-loop", action="store_true",
                   help="per-step decode driver (one host sync per token) "
                        "instead of the on-device lax.while_loop")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro import configs
    from repro.core.cim_linear import CIMConfig, hbm_bytes, ternarize_params
    from repro.models import registry
    from repro.serve import Request, ServeEngine

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    raw_bytes = hbm_bytes(params)

    cim = None
    if args.packed:
        cim = CIMConfig(mode="ternary", packing=args.packed,
                        domain=args.domain)
        params = ternarize_params(params, cim)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"weights {raw_bytes/1e6:.1f}MB -> {hbm_bytes(params)/1e6:.1f}MB "
          f"({args.packed or 'float'})")

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = lambda b: jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["patches"] = lambda b: jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    eng = ServeEngine(model, params, capacity=args.capacity,
                      max_batch=args.max_batch, cim=cim, extra_inputs=extra,
                      on_device_loop=not args.legacy_loop)
    key = jax.random.key(args.seed + 1)
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        prompt = jax.random.randint(k, (args.prompt_len,), 0,
                                    cfg.vocab_size)
        eng.submit(Request(uid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.monotonic()
    done = eng.run()
    dt = time.monotonic() - t0
    print(json.dumps({
        "requests": len(done),
        "generated_tokens": eng.generated_tokens,
        "steps": eng.steps_run,
        "host_transfers": eng.host_transfers,
        "decode_loop": "legacy" if args.legacy_loop else "device",
        "wall_s": round(dt, 2),
        "tok_per_s": round(eng.generated_tokens / max(dt, 1e-9), 1),
    }))


if __name__ == "__main__":
    main()
