"""Serving launcher — batched requests against a (optionally ternary-
packed) model.  The paper's end-to-end mode: weights stored at 1 byte /
5-trit weight (base3) or 2 bits/trit (trit2) and dequantized on-load.

Three drivers:
  * bucket (default) — ServeEngine pops one prompt-length bucket at a
    time (on-device decode loop per bucket);
  * ``--continuous`` — the continuous-batching Scheduler: a persistent
    pool of ``--slots`` decode slots, chunked on-device decode
    (``--chunk`` steps per host yield) with prefill-into-freed-slot
    admission;
  * ``--frontend`` — the SLO-aware serving front-end
    (``repro.frontend``): a model registry (``--frontend-models``, one
    scheduler pool per architecture) behind one bounded-queue submit
    path (``--queue-limit``), with FIFO or priority/deadline admission
    (``--admission slo``) and the open-loop trace replay as the
    request stream.

Request streams: all-at-once (default), a Poisson arrival stream
(``--arrival-rate`` requests/s), or a recorded JSON trace
(``--trace-file``: list of {arrival_s, prompt_len, max_new, eos_id}
plus optional {priority, deadline_s} SLO fields).  With an arrival
stream all drivers replay the same trace, so their latency percentiles
are comparable.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --requests 16 --prompt-len 32 --max-new 16 --packed base3 \
      --continuous --slots 8 --chunk 8 --arrival-rate 50

  PYTHONPATH=src python -m repro.launch.serve --frontend \
      --frontend-models internlm2-1.8b,qwen3-14b --smoke --requests 16 \
      --admission slo --deadline-s 0.5 --arrival-rate 50
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="internlm2-1.8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--capacity", type=int, default=256)
    p.add_argument("--packed", choices=("base3", "trit2"))
    p.add_argument("--domain", default="float", choices=("float", "int8"),
                   help="ternary-mode MXU domain (int8 = decode fast lane)")
    p.add_argument("--backend", default="auto",
                   help="kernel execution backend (any registered name; "
                        "'auto' = capability match, see "
                        "src/repro/kernels/README.md)")
    p.add_argument("--fidelity", default="exact",
                   choices=("exact", "device"),
                   help="execution fidelity: 'device' serves decode "
                        "through the fault-injected analog backend at "
                        "the measured TL restore yield (prefill stays "
                        "exact — see repro.faults); requires --packed")
    p.add_argument("--scrub-every", type=int, default=8,
                   help="decode chunks between restore-scrub repairs "
                        "under --fidelity device (0 disables scrubbing "
                        "— degradation accumulates)")
    p.add_argument("--legacy-loop", action="store_true",
                   help="per-step decode driver (one host sync per token) "
                        "instead of the on-device lax.while_loop")
    p.add_argument("--continuous", action="store_true",
                   help="continuous-batching Scheduler (slot pool + "
                        "chunked decode) instead of the bucket engine")
    p.add_argument("--slots", type=int, default=0,
                   help="decode slots for --continuous (default: "
                        "--max-batch)")
    p.add_argument("--chunk", type=int, default=8,
                   help="decode steps per scheduling round (host yield)")
    p.add_argument("--kv", default=None, choices=("dense", "paged"),
                   help="--continuous/--frontend KV layout: dense "
                        "per-slot caches or the paged, prefix-shared "
                        "block pool (default: dense for --continuous, "
                        "paged for --frontend pools)")
    p.add_argument("--page-size", type=int, default=16,
                   help="positions per KV page for --kv paged")
    p.add_argument("--num-pages", type=int, default=0,
                   help="page-pool size for --kv paged (0 = the "
                        "dense-pool equivalent: slots x capacity / "
                        "page size usable pages, + 1 for the reserved "
                        "null page)")
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="Poisson request arrivals per second (0 = all "
                        "requests available at t=0)")
    p.add_argument("--trace-file", default=None,
                   help="JSON arrival trace: list of {arrival_s, "
                        "prompt_len, max_new, eos_id} (overrides "
                        "--requests/--prompt-len/--max-new/--arrival-rate)")
    p.add_argument("--frontend", action="store_true",
                   help="serve through the SLO-aware front-end "
                        "(repro.frontend): model registry + bounded "
                        "queue + admission policy + open-loop replay")
    p.add_argument("--frontend-models", default=None, metavar="A,B",
                   help="comma-separated architecture names to "
                        "register as front-end pools (default: --arch)")
    p.add_argument("--queue-limit", type=int, default=64,
                   help="--frontend pending-queue bound; past it "
                        "submits are rejected with 'queue-full'")
    p.add_argument("--admission", default="fifo",
                   choices=("fifo", "slo"),
                   help="--frontend admission policy: fifo, or slo "
                        "(priority classes + earliest-deadline-first "
                        "+ shedding of unmeetable requests)")
    p.add_argument("--deadline-s", type=float, default=0.0,
                   help="--frontend relative completion budget applied "
                        "to every generated request (0 = no deadline; "
                        "a --trace-file's per-record deadline_s wins)")
    p.add_argument("--service-floor-s", type=float, default=0.0,
                   help="--admission slo minimum-service estimate: "
                        "pending requests whose deadline cannot be met "
                        "within it are shed")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    kv = args.kv or ("paged" if args.frontend else "dense")
    if kv == "paged" and not (args.continuous or args.frontend):
        p.error("--kv paged requires --continuous or --frontend (the "
                "paged pool is a slot-pool layout)")
    if args.frontend and args.continuous:
        p.error("--frontend drives its registry's scheduler pools "
                "itself; drop --continuous")
    if args.frontend and (args.packed or args.fidelity == "device"):
        p.error("--frontend pools serve float weights through the "
                "model registry; packed/device-fidelity serving is the "
                "bucket/--continuous path")
    if args.frontend and args.legacy_loop:
        p.error("--frontend has no legacy per-step loop; its pools are "
                "chunked schedulers")
    if args.fidelity == "device" and not args.packed:
        p.error("--fidelity device requires --packed (the device model "
                "faults packed ternary weights; float serving has no "
                "device path)")
    if args.fidelity == "device" and not args.continuous:
        p.error("--fidelity device requires --continuous (drift + "
                "restore-scrub are per-chunk hooks of the Scheduler)")

    if args.frontend:
        return _run_frontend(args, kv)

    from repro import configs
    from repro.core.cim_linear import CIMConfig, hbm_bytes, ternarize_params
    from repro.models import registry
    from repro.serve import (PagedScheduler, Request, Scheduler,
                             ServeEngine, latency_stats, load_trace,
                             make_trace, poisson_arrivals)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    raw_bytes = hbm_bytes(params)

    cim = cim_decode = None
    if args.packed:
        if args.fidelity == "device":
            # pin the measured-yield fault campaign BEFORE resolution so
            # the device backend serves the paper's TL restore yield
            from repro import faults
            faults.set_fault_model(faults.measured_fault_model(
                seed=args.seed, drift_rate=0.001))
        # fail fast for BOTH phases the engines will resolve (a device
        # request splits decode->device / prefill->exact; pinning the
        # decode resolution into the request would poison the prefill
        # one, so the engines get the unresolved request)
        cim = CIMConfig(mode="ternary", packing=args.packed,
                        domain=args.domain, backend=args.backend,
                        fidelity=args.fidelity)
        cim_decode = cim.resolve()
        cim.resolve(phase="prefill")
        params = ternarize_params(params, cim)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"weights {raw_bytes/1e6:.1f}MB -> {hbm_bytes(params)/1e6:.1f}MB "
          f"({args.packed or 'float'}"
          + (f", backend={cim_decode.backend}, domain={cim_decode.domain}, "
             f"fidelity={cim_decode.fidelity}" if cim else "") + ")")

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = lambda b: jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["patches"] = lambda b: jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    if args.trace_file:
        trace = load_trace(args.trace_file)
    else:
        arrivals = poisson_arrivals(args.requests, args.arrival_rate,
                                    seed=args.seed)
        trace = make_trace(arrivals, [args.prompt_len], [args.max_new])

    if args.continuous and kv == "paged":
        eng = PagedScheduler(model, params, capacity=args.capacity,
                             slots=args.slots or args.max_batch,
                             chunk=args.chunk, page_size=args.page_size,
                             num_pages=args.num_pages or None,
                             cim=cim, extra_inputs=extra,
                             scrub_every=args.scrub_every)
    elif args.continuous:
        eng = Scheduler(model, params, capacity=args.capacity,
                        slots=args.slots or args.max_batch,
                        chunk=args.chunk, cim=cim, extra_inputs=extra,
                        scrub_every=args.scrub_every)
    else:
        eng = ServeEngine(model, params, capacity=args.capacity,
                          max_batch=args.max_batch, cim=cim,
                          extra_inputs=extra,
                          on_device_loop=not args.legacy_loop)

    key = jax.random.key(args.seed + 1)
    for i, rec in enumerate(trace):
        k = jax.random.fold_in(key, i)
        prompt = jax.random.randint(k, (rec["prompt_len"],), 0,
                                    cfg.vocab_size)
        eng.submit(Request(uid=i, prompt=prompt, max_new=rec["max_new"],
                           eos_id=rec["eos_id"],
                           arrival_s=rec["arrival_s"]))

    t0 = time.monotonic()
    if args.continuous:
        done = eng.run()                      # natively arrival-aware
    else:
        # run_trace even when every arrival is 0.0 (no sleeps happen):
        # it stamps latency_s = completion - arrival, the same
        # definition the Scheduler uses, so the printed p50/p99 are
        # comparable across drivers
        done = eng.run_trace()
    dt = time.monotonic() - t0

    out = {
        "requests": len(done),
        "generated_tokens": eng.generated_tokens,
        "steps": eng.steps_run,
        "host_transfers": eng.host_transfers,
        "wall_s": round(dt, 2),
        "tok_per_s": round(eng.generated_tokens / max(dt, 1e-9), 1),
        **latency_stats(done),
    }
    if cim_decode is not None:
        out["fidelity"] = cim_decode.fidelity
    if args.continuous:
        out.update(decode_loop="continuous", slots=eng.slots,
                   chunk=eng.chunk, chunks=eng.chunks_run,
                   slot_occupancy=round(eng.slot_occupancy, 3))
        if cim_decode is not None and cim_decode.fidelity == "device":
            out.update(scrubs=eng.scrubs_run,
                       adc_clip_lo=eng.adc_clip_lo,
                       adc_clip_hi=eng.adc_clip_hi)
        if kv == "paged":
            out.update(kv="paged", page_size=eng.page_size,
                       num_pages=eng.num_pages,
                       pages_in_use_peak=eng.allocator.peak_in_use,
                       kv_bytes_pool=eng.kv_bytes(),
                       kv_bytes_resident_peak=eng.kv_bytes_resident_peak,
                       prefix_hit_rate=round(eng.prefix_hit_rate, 3))
    else:
        out["decode_loop"] = "legacy" if args.legacy_loop else "device"
    print(json.dumps(out))


def _run_frontend(args, kv: str) -> None:
    """The --frontend mode: registry + bounded-queue server + open-loop
    replay, reporting the load-harness stats (goodput, TTFT, latency
    split) plus the registry capacity report."""
    from repro.frontend import (FIFOAdmission, FrontendServer,
                                ModelRegistry, ModelSpec, SLOAdmission,
                                replay, trace_requests)
    from repro.serve import load_trace, make_trace, poisson_arrivals

    names = [m.strip()
             for m in (args.frontend_models or args.arch).split(",")
             if m.strip()]
    reg = ModelRegistry()
    for name in names:
        reg.register(ModelSpec(
            name=name, arch=name, smoke=args.smoke, kind=kv,
            capacity=args.capacity, slots=args.slots or args.max_batch,
            chunk=args.chunk, page_size=args.page_size,
            num_pages=args.num_pages or None, seed=args.seed))

    if args.trace_file:
        trace = load_trace(args.trace_file)
    else:
        arrivals = poisson_arrivals(args.requests, args.arrival_rate,
                                    seed=args.seed)
        trace = make_trace(arrivals, [args.prompt_len], [args.max_new],
                           deadlines=[args.deadline_s or None])
    records = trace_requests(trace, reg, names, seed=args.seed)

    policy = (SLOAdmission(service_floor_s=args.service_floor_s)
              if args.admission == "slo" else FIFOAdmission())
    server = FrontendServer(reg, policy, queue_limit=args.queue_limit)
    report = replay(server, records)
    out = {"decode_loop": "frontend", "models": names,
           "admission": policy.name, "queue_limit": args.queue_limit,
           "kv": kv, **report,
           "capacity_report": reg.capacity_report()}
    print(json.dumps(out))


if __name__ == "__main__":
    main()
