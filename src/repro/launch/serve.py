"""Serving launcher — batched requests against a (optionally ternary-
packed) model.  The paper's end-to-end mode: weights stored at 1 byte /
5-trit weight (base3) or 2 bits/trit (trit2) and dequantized on-load.

Two drivers:
  * bucket (default) — ServeEngine pops one prompt-length bucket at a
    time (on-device decode loop per bucket);
  * ``--continuous`` — the continuous-batching Scheduler: a persistent
    pool of ``--slots`` decode slots, chunked on-device decode
    (``--chunk`` steps per host yield) with prefill-into-freed-slot
    admission.

Request streams: all-at-once (default), a Poisson arrival stream
(``--arrival-rate`` requests/s), or a recorded JSON trace
(``--trace-file``: list of {arrival_s, prompt_len, max_new, eos_id}).
With an arrival stream both drivers replay the same trace, so their
latency percentiles are comparable.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --requests 16 --prompt-len 32 --max-new 16 --packed base3 \
      --continuous --slots 8 --chunk 8 --arrival-rate 50
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="internlm2-1.8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--capacity", type=int, default=256)
    p.add_argument("--packed", choices=("base3", "trit2"))
    p.add_argument("--domain", default="float", choices=("float", "int8"),
                   help="ternary-mode MXU domain (int8 = decode fast lane)")
    p.add_argument("--backend", default="auto",
                   help="kernel execution backend (any registered name; "
                        "'auto' = capability match, see "
                        "src/repro/kernels/README.md)")
    p.add_argument("--fidelity", default="exact",
                   choices=("exact", "device"),
                   help="execution fidelity: 'device' serves decode "
                        "through the fault-injected analog backend at "
                        "the measured TL restore yield (prefill stays "
                        "exact — see repro.faults); requires --packed")
    p.add_argument("--scrub-every", type=int, default=8,
                   help="decode chunks between restore-scrub repairs "
                        "under --fidelity device (0 disables scrubbing "
                        "— degradation accumulates)")
    p.add_argument("--legacy-loop", action="store_true",
                   help="per-step decode driver (one host sync per token) "
                        "instead of the on-device lax.while_loop")
    p.add_argument("--continuous", action="store_true",
                   help="continuous-batching Scheduler (slot pool + "
                        "chunked decode) instead of the bucket engine")
    p.add_argument("--slots", type=int, default=0,
                   help="decode slots for --continuous (default: "
                        "--max-batch)")
    p.add_argument("--chunk", type=int, default=8,
                   help="decode steps per scheduling round (host yield)")
    p.add_argument("--kv", default="dense", choices=("dense", "paged"),
                   help="--continuous KV layout: dense per-slot caches "
                        "or the paged, prefix-shared block pool")
    p.add_argument("--page-size", type=int, default=16,
                   help="positions per KV page for --kv paged")
    p.add_argument("--num-pages", type=int, default=0,
                   help="page-pool size for --kv paged (0 = the "
                        "dense-pool equivalent: slots x capacity / "
                        "page size usable pages, + 1 for the reserved "
                        "null page)")
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="Poisson request arrivals per second (0 = all "
                        "requests available at t=0)")
    p.add_argument("--trace-file", default=None,
                   help="JSON arrival trace: list of {arrival_s, "
                        "prompt_len, max_new, eos_id} (overrides "
                        "--requests/--prompt-len/--max-new/--arrival-rate)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.kv == "paged" and not args.continuous:
        p.error("--kv paged requires --continuous (the paged pool is a "
                "continuous-batching slot-pool layout)")
    if args.fidelity == "device" and not args.packed:
        p.error("--fidelity device requires --packed (the device model "
                "faults packed ternary weights; float serving has no "
                "device path)")
    if args.fidelity == "device" and not args.continuous:
        p.error("--fidelity device requires --continuous (drift + "
                "restore-scrub are per-chunk hooks of the Scheduler)")

    from repro import configs
    from repro.core.cim_linear import CIMConfig, hbm_bytes, ternarize_params
    from repro.models import registry
    from repro.serve import (PagedScheduler, Request, Scheduler,
                             ServeEngine, latency_stats, load_trace,
                             make_trace, poisson_arrivals)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(args.seed))
    raw_bytes = hbm_bytes(params)

    cim = cim_decode = None
    if args.packed:
        if args.fidelity == "device":
            # pin the measured-yield fault campaign BEFORE resolution so
            # the device backend serves the paper's TL restore yield
            from repro import faults
            faults.set_fault_model(faults.measured_fault_model(
                seed=args.seed, drift_rate=0.001))
        # fail fast for BOTH phases the engines will resolve (a device
        # request splits decode->device / prefill->exact; pinning the
        # decode resolution into the request would poison the prefill
        # one, so the engines get the unresolved request)
        cim = CIMConfig(mode="ternary", packing=args.packed,
                        domain=args.domain, backend=args.backend,
                        fidelity=args.fidelity)
        cim_decode = cim.resolve()
        cim.resolve(phase="prefill")
        params = ternarize_params(params, cim)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"weights {raw_bytes/1e6:.1f}MB -> {hbm_bytes(params)/1e6:.1f}MB "
          f"({args.packed or 'float'}"
          + (f", backend={cim_decode.backend}, domain={cim_decode.domain}, "
             f"fidelity={cim_decode.fidelity}" if cim else "") + ")")

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = lambda b: jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["patches"] = lambda b: jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    if args.trace_file:
        trace = load_trace(args.trace_file)
    else:
        arrivals = poisson_arrivals(args.requests, args.arrival_rate,
                                    seed=args.seed)
        trace = make_trace(arrivals, [args.prompt_len], [args.max_new])

    if args.continuous and args.kv == "paged":
        eng = PagedScheduler(model, params, capacity=args.capacity,
                             slots=args.slots or args.max_batch,
                             chunk=args.chunk, page_size=args.page_size,
                             num_pages=args.num_pages or None,
                             cim=cim, extra_inputs=extra,
                             scrub_every=args.scrub_every)
    elif args.continuous:
        eng = Scheduler(model, params, capacity=args.capacity,
                        slots=args.slots or args.max_batch,
                        chunk=args.chunk, cim=cim, extra_inputs=extra,
                        scrub_every=args.scrub_every)
    else:
        eng = ServeEngine(model, params, capacity=args.capacity,
                          max_batch=args.max_batch, cim=cim,
                          extra_inputs=extra,
                          on_device_loop=not args.legacy_loop)

    key = jax.random.key(args.seed + 1)
    for i, rec in enumerate(trace):
        k = jax.random.fold_in(key, i)
        prompt = jax.random.randint(k, (rec["prompt_len"],), 0,
                                    cfg.vocab_size)
        eng.submit(Request(uid=i, prompt=prompt, max_new=rec["max_new"],
                           eos_id=rec["eos_id"],
                           arrival_s=rec["arrival_s"]))

    t0 = time.monotonic()
    if args.continuous:
        done = eng.run()                      # natively arrival-aware
    else:
        # run_trace even when every arrival is 0.0 (no sleeps happen):
        # it stamps latency_s = completion - arrival, the same
        # definition the Scheduler uses, so the printed p50/p99 are
        # comparable across drivers
        done = eng.run_trace()
    dt = time.monotonic() - t0

    out = {
        "requests": len(done),
        "generated_tokens": eng.generated_tokens,
        "steps": eng.steps_run,
        "host_transfers": eng.host_transfers,
        "wall_s": round(dt, 2),
        "tok_per_s": round(eng.generated_tokens / max(dt, 1e-9), 1),
        **latency_stats(done),
    }
    if cim_decode is not None:
        out["fidelity"] = cim_decode.fidelity
    if args.continuous:
        out.update(decode_loop="continuous", slots=eng.slots,
                   chunk=eng.chunk, chunks=eng.chunks_run,
                   slot_occupancy=round(eng.slot_occupancy, 3))
        if cim_decode is not None and cim_decode.fidelity == "device":
            out.update(scrubs=eng.scrubs_run,
                       adc_clip_lo=eng.adc_clip_lo,
                       adc_clip_hi=eng.adc_clip_hi)
        if args.kv == "paged":
            out.update(kv="paged", page_size=eng.page_size,
                       num_pages=eng.num_pages,
                       pages_in_use_peak=eng.allocator.peak_in_use,
                       kv_bytes_pool=eng.kv_bytes(),
                       kv_bytes_resident_peak=eng.kv_bytes_resident_peak,
                       prefix_hit_rate=round(eng.prefix_hit_rate, 3))
    else:
        out["decode_loop"] = "legacy" if args.legacy_loop else "device"
    print(json.dumps(out))


if __name__ == "__main__":
    main()
