"""Monte-Carlo restore-yield model — reproduces Fig. 6 (and the SL contrast).

Yield := P(trit restored to the SRAM pair equals the trit stored in the
TL-ReRAM), under (i) lognormal ReRAM resistance variation (filament gap
3σ/μ = 10 %), (ii) reference-ladder variation, (iii) CMOS discharge-path
mismatch, (iv) comparator offset, and (v) leakage through the n-1
unselected insulating selectors (grows with cluster size n) plus m-1 off
clusters.  All draws are vectorized with jax.random — the "1000
Monte-Carlo SPICE runs" of §3.4 become a single vmapped batch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import device_models as dm
from .cim import HRS, LRS, MRS, restore_levels_to_trits, store_trits_to_levels
from .seeding import stable_seed

STATE_TRITS = jnp.array([-1, 0, 1], dtype=jnp.int8)          # HRS, MRS, LRS
# weights in NNs are sparse -> MRS-heavy prior (§3.4 "MRS tuned as preference")
SPARSE_PRIOR = jnp.array([0.25, 0.50, 0.25])


@partial(jax.jit, static_argnames=("n", "m", "num_mc", "d"))
def tl_restore_trials(key: jax.Array, n: int, m: int, num_mc: int,
                      d: dm.DeviceParams = dm.DeviceParams()) -> jax.Array:
    """(3, num_mc) bool — per-state restore success for TL-nvSRAM-CIM."""
    levels = store_trits_to_levels(STATE_TRITS)               # (3,)
    keys = jax.random.split(key, 5)
    r = dm.sample_resistance(levels[:, None], keys[0], d, (3, num_mc))
    cmos = d.cmos_sigma_rel * jax.random.normal(keys[1], (3, num_mc))
    g_cell = dm.discharge_conductance(r, d, cmos)
    # leakage: unselected selectors' insulating resistance also varies
    z = jax.random.normal(keys[2], (3, num_mc))
    g_leak = dm.leakage_conductance(n, m, d) * jnp.exp(0.1 * z)
    g_ref = dm.sample_reference_conductances(keys[3], d, (3, num_mc))
    cmp1 = d.comparator_sigma_siemens * jax.random.normal(keys[4], (3, num_mc))
    cmp2 = d.comparator_sigma_siemens * jax.random.normal(
        jax.random.fold_in(keys[4], 1), (3, num_mc))
    # restore_levels_to_trits recomputes the series conductance from
    # `resistances`; CMOS mismatch is folded in as an equivalent
    # conductance offset added to the leakage term.
    g_eff_offset = g_cell - dm.discharge_conductance(r, d)     # cmos part
    got = restore_levels_to_trits(levels[:, None], resistances=r,
                                  g_leak=g_leak + g_eff_offset,
                                  g_ref=g_ref, cmp_noise=(cmp1, cmp2), device=d)
    want = STATE_TRITS[:, None]
    return got == want


def tl_restore_yield(key: jax.Array, n: int, m: int = 4, num_mc: int = 4096,
                     d: dm.DeviceParams = dm.DeviceParams(),
                     prior: jax.Array = SPARSE_PRIOR) -> dict:
    ok = tl_restore_trials(key, n, m, num_mc, d)
    per_state = ok.mean(axis=1)
    return {
        "per_state": per_state,                  # [HRS(-1), MRS(0), LRS(+1)]
        "weighted": float(jnp.dot(prior, per_state)),
        "min_state": float(per_state.min()),
    }


@partial(jax.jit, static_argnames=("n", "num_mc", "d"))
def sl_restore_trials(key: jax.Array, n: int, num_mc: int,
                      d: dm.DeviceParams = dm.DeviceParams()) -> jax.Array:
    """(2, num_mc) bool — HRS/LRS restore success for the voltage-divider
    select scheme of SL-nvSRAM-CIM [12].  The unselected SL-ReRAMs hold
    random binary data; their combined parallel resistance moves the
    divider output, squeezing the margin as n grows."""
    keys = jax.random.split(key, 4)
    states = jnp.array([d.r_hrs, d.r_lrs])                     # selected
    r_sel = states[:, None] * jnp.exp(
        d.sigma_ln_r * jax.random.normal(keys[0], (2, num_mc)))
    bits = jax.random.bernoulli(keys[1], 0.5, (2, num_mc, max(n - 1, 1)))
    r_un_nom = jnp.where(bits, d.r_lrs, d.r_hrs)
    r_un = r_un_nom * jnp.exp(
        d.sigma_ln_r * jax.random.normal(keys[2], (2, num_mc, max(n - 1, 1))))
    vx = dm.sl_divider_voltage(r_sel, r_un, d.vdd)
    vth = dm.sl_nominal_threshold(n, d, d.vdd)      # trip fixed at n_design=6
    trip_noise = 0.025 * jax.random.normal(keys[3], (2, num_mc))  # 25 mV σ Vth
    vx = vx + trip_noise
    # HRS -> divider output HIGH (R_sel large -> small V across R_par?) --
    # V_X = V·R_par/(R_sel+R_par): HRS gives LOW V_X, LRS gives HIGH V_X.
    got_hrs_ok = vx[0] < vth
    got_lrs_ok = vx[1] > vth
    return jnp.stack([got_hrs_ok, got_lrs_ok])


def sl_restore_yield(key: jax.Array, n: int, num_mc: int = 4096,
                     d: dm.DeviceParams = dm.DeviceParams()) -> dict:
    ok = sl_restore_trials(key, n, num_mc, d)
    per_state = ok.mean(axis=1)
    return {"per_state": per_state, "weighted": float(per_state.mean()),
            "min_state": float(per_state.min())}


def yield_sweep(key: jax.Array, ns=(6, 12, 18, 30, 45, 60), m: int = 4,
                num_mc: int = 4096, scheme: str = "tl") -> dict:
    """Fig. 6(a): yield vs number of ReRAMs per cluster/group.

    Per-point keys are derived from the sweep *configuration*
    (``stable_seed``-folded), not the loop index, so the Monte-Carlo
    draw for a given (scheme, n, m, num_mc) point is identical no
    matter which other points the sweep includes."""
    out = {}
    for n in ns:
        k = jax.random.fold_in(
            key, stable_seed("yield_sweep", scheme, n, m, num_mc))
        out[n] = (tl_restore_yield(k, n, m, num_mc) if scheme == "tl"
                  else sl_restore_yield(k, n, num_mc))
    return out


def cluster_sweep(key: jax.Array, ms=(1, 2, 3, 4), n: int = 60,
                  num_mc: int = 4096) -> dict:
    """Fig. 6(b): yield vs number of clusters m (TL scheme).  Keys
    derive from the point configuration like :func:`yield_sweep`."""
    return {m: tl_restore_yield(
        jax.random.fold_in(
            key, stable_seed("cluster_sweep", "tl", n, m, num_mc)),
        n, m, num_mc) for m in ms}
