"""CIMLinear — the paper's technique as a drop-in linear layer.

Execution modes (config: ``cim.mode``):
  'float'    — plain bf16/f32 matmul (reference / training).
  'ternary'  — packed-ternary fast path via kernels.ternary_matmul:
               base3 (paper's 5-trit, 2x denser) or trit2 (1-trit, 8x).
               This is the production serving path.
  'exact'    — macro-exact simulation via kernels.cim_mac (row groups +
               5-bit ADC); for accuracy studies, incl. restore-error
               injection at a given yield.

The weights of any architecture in repro.models can be converted with
:func:`ternarize_params` — the technique is weight-storage-level and
applies to every matmul in the framework (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


MODES = ("float", "ternary", "exact")


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """Execution-mode config: a plan *request* for the kernel layer.

    The routing fields (backend/domain/packing/interpret) are exactly
    the request half of a ``kernels.ExecutionPlan`` — ``linear`` feeds
    them to ``kernels.plan_matmul`` per shape.  ``resolve()`` pins the
    'auto' fields once against the backend registry; long-lived drivers
    (serve engines, train steps, launchers) resolve at construction so
    a bad request fails there, not mid-decode.
    """
    mode: str = "float"            # float | ternary | exact
    packing: str = "base3"         # base3 | trit2 (ternary mode)
    num_trits: int = 5
    adc_bits: int = 5              # exact mode
    restore_yield: Optional[tuple] = None   # per-state yields -> error inject
    interpret: Optional[bool] = None
    backend: str = "auto"          # any registered kernel backend
    domain: str = "float"          # float | int8 — ternary-mode MXU domain
    kv_layout: str = "dense"       # dense | paged — serving KV layout
    fidelity: str = "exact"        # exact | device — execution fidelity

    def plan_request(self) -> dict:
        """The fields this config contributes to plan resolution."""
        return {"backend": self.backend, "domain": self.domain,
                "packing": self.packing, "interpret": self.interpret,
                "kv_layout": self.kv_layout, "fidelity": self.fidelity}

    def resolve(self, phase: str = "auto") -> "CIMConfig":
        """Pin 'auto' routing fields against the kernel backend registry
        (capability-checked, fails loudly on an incapable backend).

        ``phase`` routes the requested fidelity first
        (``kernels.route_fidelity``): resolving a ``device`` request for
        the accuracy-critical ``prefill`` phase pins an EXACT backend —
        the serve engines resolve one config per phase, so prefill and
        decode each fail loudly at construction if no backend covers
        their routed fidelity."""
        from repro.kernels import (default_interpret, resolve_backend,
                                   route_fidelity)
        if self.mode not in MODES:
            raise ValueError(f"unknown cim mode {self.mode!r}; expected "
                             f"one of {sorted(MODES)}")
        fidelity = route_fidelity(self.fidelity, phase)
        backend = self.backend
        if self.mode == "ternary":
            backend = resolve_backend("ternary", self.backend, self.domain,
                                      self.packing,
                                      kv_layout=self.kv_layout,
                                      fidelity=fidelity).name
        elif self.mode == "exact":
            backend = resolve_backend("cim", self.backend,
                                      kv_layout=self.kv_layout,
                                      fidelity=fidelity).name
        else:
            from repro.kernels import check_choice
            from repro.kernels.plan import KV_LAYOUTS
            check_choice("kv layout", self.kv_layout, KV_LAYOUTS)
            if fidelity != "exact":
                raise ValueError(
                    "fidelity 'device' needs the ternary (packed-weight) "
                    "serving path; float mode has no device model")
        interpret = (default_interpret() if self.interpret is None
                     else self.interpret)
        return dataclasses.replace(self, backend=backend,
                                   interpret=interpret, fidelity=fidelity)


def linear(x: jax.Array, w: Any, cfg: CIMConfig = CIMConfig(),
           phase: str = "auto") -> jax.Array:
    """Apply a linear layer under the configured CIM mode.

    `w` is a float (K, N) array in float/exact modes, or a
    kernels.ops.PackedTernary in ternary mode.  Ternary/exact modes
    resolve a (cached) ExecutionPlan per shape and run
    ``kernels.execute`` — backend selection is a capability match in
    the kernel registry, not an if/elif chain here."""
    from repro.kernels import execute, ops, plan_matmul, shape_of
    if cfg.mode == "ternary" or isinstance(w, ops.PackedTernary):
        pw = w if isinstance(w, ops.PackedTernary) else ops.pack_weights(
            w, cfg.packing, cfg.num_trits)
        plan = plan_matmul(shape_of(x, pw), phase, cfg, packing=pw.mode)
        return execute(plan, x, pw)
    if cfg.mode == "float":
        return x @ w
    if cfg.mode == "exact":
        plan = plan_matmul(shape_of(x, w), phase, cfg, op="cim",
                           packing="base3", domain="float",
                           adc_bits=cfg.adc_bits, num_trits=cfg.num_trits)
        return execute(plan, x, w)
    raise ValueError(f"unknown cim mode {cfg.mode!r}; expected one of "
                     f"{sorted(MODES)}")


def ternarize_params(params: Any, cfg: CIMConfig,
                     predicate=None) -> Any:
    """Convert every matmul weight in a pytree to PackedTernary.

    predicate(path, leaf) -> bool selects which weights convert (default:
    2-D or layer-stacked 3-D float arrays with trailing dims >= 64;
    embedding tables and norms stay float, like the paper keeps
    peripheral logic digital)."""
    from repro.kernels import ops

    def default_pred(path, x):
        name = str(getattr(path[-1], "key", path[-1])) if path else ""
        return (isinstance(x, jax.Array) and x.ndim in (2, 3, 4)
                and x.dtype in (jnp.float32, jnp.bfloat16)
                and min(x.shape[-2:]) >= 64
                and name not in ("embed", "router"))

    pred = predicate or default_pred

    def convert(path, x):
        if pred(path, x):
            return ops.pack_weights(x, cfg.packing, cfg.num_trits)
        return x

    return jax.tree_util.tree_map_with_path(convert, params)


def hbm_bytes(params: Any) -> int:
    """Total HBM bytes of a (possibly packed) parameter tree — the
    density metric of Table 4 at model level."""
    from repro.kernels import ops
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, ops.PackedTernary)):
        if isinstance(leaf, ops.PackedTernary):
            total += leaf.data.size + leaf.scale.size * 4
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total
