"""CIMLinear — the paper's technique as a drop-in linear layer.

Execution modes (config: ``cim.mode``):
  'float'    — plain bf16/f32 matmul (reference / training).
  'ternary'  — packed-ternary fast path via kernels.ternary_matmul:
               base3 (paper's 5-trit, 2x denser) or trit2 (1-trit, 8x).
               This is the production serving path.
  'exact'    — macro-exact simulation via kernels.cim_mac (row groups +
               5-bit ADC); for accuracy studies, incl. restore-error
               injection at a given yield.

The weights of any architecture in repro.models can be converted with
:func:`ternarize_params` — the technique is weight-storage-level and
applies to every matmul in the framework (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    mode: str = "float"            # float | ternary | exact
    packing: str = "base3"         # base3 | trit2 (ternary mode)
    num_trits: int = 5
    adc_bits: int = 5              # exact mode
    restore_yield: Optional[tuple] = None   # per-state yields -> error inject
    interpret: Optional[bool] = None
    backend: str = "auto"          # auto (pallas) | xla — ternary mode
    domain: str = "float"          # float | int8 — ternary-mode MXU domain


def linear(x: jax.Array, w: Any, cfg: CIMConfig = CIMConfig()) -> jax.Array:
    """Apply a linear layer under the configured CIM mode.

    `w` is a float (K, N) array in float/exact modes, or a
    kernels.ops.PackedTernary in ternary mode."""
    from repro.kernels import ops
    if cfg.mode == "ternary" or isinstance(w, ops.PackedTernary):
        pw = w if isinstance(w, ops.PackedTernary) else ops.pack_weights(
            w, cfg.packing, cfg.num_trits)
        return ops.ternary_matmul(x, pw, interpret=cfg.interpret,
                                  backend=cfg.backend, domain=cfg.domain)
    if cfg.mode == "float":
        return x @ w
    if cfg.mode == "exact":
        return ops.cim_matmul(x, w, adc_bits=cfg.adc_bits,
                              num_trits=cfg.num_trits, interpret=cfg.interpret)
    return x @ w


def ternarize_params(params: Any, cfg: CIMConfig,
                     predicate=None) -> Any:
    """Convert every matmul weight in a pytree to PackedTernary.

    predicate(path, leaf) -> bool selects which weights convert (default:
    2-D or layer-stacked 3-D float arrays with trailing dims >= 64;
    embedding tables and norms stay float, like the paper keeps
    peripheral logic digital)."""
    from repro.kernels import ops

    def default_pred(path, x):
        name = str(getattr(path[-1], "key", path[-1])) if path else ""
        return (isinstance(x, jax.Array) and x.ndim in (2, 3, 4)
                and x.dtype in (jnp.float32, jnp.bfloat16)
                and min(x.shape[-2:]) >= 64
                and name not in ("embed", "router"))

    pred = predicate or default_pred

    def convert(path, x):
        if pred(path, x):
            return ops.pack_weights(x, cfg.packing, cfg.num_trits)
        return x

    return jax.tree_util.tree_map_with_path(convert, params)


def hbm_bytes(params: Any) -> int:
    """Total HBM bytes of a (possibly packed) parameter tree — the
    density metric of Table 4 at model level."""
    from repro.kernels import ops
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, ops.PackedTernary)):
        if isinstance(leaf, ops.PackedTernary):
            total += leaf.data.size + leaf.scale.size * 4
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total
