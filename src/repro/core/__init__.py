"""Core: the paper's contribution (TL-nvSRAM-CIM) as composable JAX modules."""
from . import (cim, device_models, energy, error_injection, mapping, packing,
               ternary, yield_model)
from .cim import MacroConfig, cim_matmul, cim_matmul_int
from .ternary import TernaryTensor, encode_inputs, ternarize

__all__ = [
    "cim", "device_models", "energy", "error_injection", "mapping",
    "packing", "ternary", "yield_model", "MacroConfig", "cim_matmul",
    "cim_matmul_int", "TernaryTensor", "encode_inputs", "ternarize",
]
