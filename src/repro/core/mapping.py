"""Compact weight mapping (§3.6, Fig. 8) — pure-Python planner.

Three steps, exactly as the paper describes:
  1. each layer's weights -> an (R_L x C_L) trit matrix
     (conv C,M,k,q -> (C·k·k) x (M·q·2) SRAM columns), split into
     R x C blocks with R = rows activated per CIM cycle and C = subarray
     columns;
  2. blocks are distributed over subarrays evenly (round-robin by block
     count), optionally DUPLICATING blocks onto idle subarrays to raise
     inference parallelism;
  3. within a subarray, blocks first-fit into the column space left by
     earlier blocks at ReRAM depth slot (cluster i, SL j); when slot
     R_{i,j} fills, mapping moves to R_{i,(j+1)}.

The plan feeds the energy model (restore cycles, subarray count) and
CIMLinear (virtual macro placement).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .cim import MacroConfig


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One weight tensor; conv: (cin, k, k, cout); fc: k=1.
    `spatial` = output feature-map positions (weight reuse per inference)."""
    name: str
    cin: int
    cout: int
    kernel: int = 1
    spatial: int = 1

    @property
    def rows(self) -> int:          # R_L
        return self.cin * self.kernel * self.kernel

    def cols(self, num_trits: int) -> int:   # C_L in SRAM columns
        return self.cout * num_trits * 2

    def params(self) -> int:
        return self.rows * self.cout

    def macs(self) -> int:
        """MACs for one inference."""
        return self.rows * self.cout * self.spatial


@dataclasses.dataclass
class Placement:
    layer: str
    block_row: int          # which R-row band of the layer matrix
    block_col: int          # which C-column band
    subarray: int
    cluster: int            # i
    depth: int              # j  (SL index within cluster)
    col_offset: int         # starting SRAM column inside the subarray slot
    width: int              # SRAM columns occupied


@dataclasses.dataclass
class MappingPlan:
    placements: list
    num_subarrays: int
    depth_slots_used: int           # max (cluster, depth) index used + 1
    restore_cycles: int             # one per occupied depth slot
    total_block_rows: int
    duplication: int
    overflow_trits: int             # trits that did NOT fit on-chip
    utilization: float              # occupied SRAM-col-slots / capacity

    @property
    def fits(self) -> bool:
        return self.overflow_trits == 0


def _blocks(layers: Sequence[LayerSpec], cfg: MacroConfig):
    """Step 1: split every layer matrix into (R x C) blocks; yields
    (layer, brow, bcol, width_cols) sorted large-to-small per the paper's
    'smaller blocks fill the columns left by the former block' rule."""
    out = []
    for sp in layers:
        n_r = math.ceil(sp.rows / cfg.rows_active)
        c_l = sp.cols(cfg.num_trits)
        n_c = math.ceil(c_l / cfg.sram_cols)
        for br in range(n_r):
            for bc in range(n_c):
                width = min(cfg.sram_cols, c_l - bc * cfg.sram_cols)
                out.append((sp.name, br, bc, width))
    return out


def compact_map(layers: Sequence[LayerSpec], cfg: MacroConfig = MacroConfig(),
                num_subarrays: int | None = None, duplicate: bool = False) -> MappingPlan:
    if num_subarrays is None:
        num_subarrays = cfg.num_subarrays
    blocks = _blocks(layers, cfg)
    # step 2: even distribution (round-robin)
    per_sub = [[] for _ in range(num_subarrays)]
    for idx, b in enumerate(blocks):
        per_sub[idx % num_subarrays].append(b)

    # each subarray: rows/rows_active row-bands x sram_cols columns per
    # depth slot; depth slots = clusters_per_cell * rerams_per_cluster
    bands = cfg.rows // cfg.rows_active
    max_depth = cfg.clusters_per_cell * cfg.rerams_per_cluster
    placements: list[Placement] = []
    overflow = 0
    max_slot = 0
    occupied_cols = 0
    for s, blist in enumerate(per_sub):
        # first-fit within (depth, band): cursor per depth slot
        # free space tracked as (depth, band) -> next free column
        cursors: dict[tuple[int, int], int] = {}
        # sort smaller blocks later so they backfill leftover columns
        blist = sorted(blist, key=lambda b: -b[3])
        for (name, br, bc, width) in blist:
            placed = False
            slot = 0
            while slot < max_depth * bands:
                depth, band = divmod(slot, bands)
                free = cursors.get((depth, band), 0)
                if cfg.sram_cols - free >= width:
                    cursors[(depth, band)] = free + width
                    cluster, d_in = divmod(depth, cfg.rerams_per_cluster)
                    placements.append(Placement(name, br, bc, s, cluster,
                                                d_in, free, width))
                    occupied_cols += width
                    max_slot = max(max_slot, depth + 1)
                    placed = True
                    break
                slot += 1
            if not placed:
                overflow += width * cfg.rows_active // 2  # trits that spill
    dup = 1
    if duplicate and overflow == 0:
        # duplicate the whole plan onto idle depth slots for parallelism
        capacity_slots = max_depth
        dup = max(1, capacity_slots // max(1, max_slot))
    capacity = num_subarrays * bands * max_depth * cfg.sram_cols
    return MappingPlan(
        placements=placements,
        num_subarrays=num_subarrays,
        depth_slots_used=max_slot,
        restore_cycles=max_slot,
        total_block_rows=len(blocks),
        duplication=dup,
        overflow_trits=overflow,
        utilization=occupied_cols / capacity,
    )


def subarrays_needed(layers: Sequence[LayerSpec], cfg: MacroConfig = MacroConfig()) -> int:
    """Minimum subarrays so that every trit fits (capacity argument of
    Fig. 11(b): ResNet-18 needs 6 TL subarrays vs 76 SL subarrays)."""
    total_trits = sum(sp.params() for sp in layers) * cfg.num_trits
    cap = cfg.rows * cfg.trit_cols * cfg.trits_per_cell
    return math.ceil(total_trits / cap)


# ---- reference models of the paper's evaluation (§4.1) ------------------

def resnet18_cifar() -> list[LayerSpec]:
    """ResNet-18 (CIFAR-10 variant, ~11.2M params ~ 11 MB @ 8b)."""
    ls = [LayerSpec("conv1", 3, 64, 3, 32 * 32)]
    cfgs = [(64, 64, 2, 32), (64, 128, 2, 16), (128, 256, 2, 8), (256, 512, 2, 4)]
    for i, (cin, cout, blocks, hw) in enumerate(cfgs):
        for b in range(blocks):
            c0 = cin if b == 0 else cout
            ls.append(LayerSpec(f"s{i}b{b}c1", c0, cout, 3, hw * hw))
            ls.append(LayerSpec(f"s{i}b{b}c2", cout, cout, 3, hw * hw))
            if b == 0 and cin != cout:
                ls.append(LayerSpec(f"s{i}b{b}sc", cin, cout, 1, hw * hw))
    ls.append(LayerSpec("fc", 512, 10, 1, 1))
    return ls


def vgg9_cifar() -> list[LayerSpec]:
    """VGG-9 (~3M params ~ 3 MB @ 8b) as in [24]'s federated benchmark."""
    return [LayerSpec("conv1", 3, 32, 3, 32 * 32), LayerSpec("conv2", 32, 64, 3, 32 * 32),
            LayerSpec("conv3", 64, 128, 3, 16 * 16), LayerSpec("conv4", 128, 128, 3, 16 * 16),
            LayerSpec("conv5", 128, 256, 3, 8 * 8), LayerSpec("conv6", 256, 256, 3, 8 * 8),
            LayerSpec("fc1", 256 * 16, 512, 1, 1), LayerSpec("fc2", 512, 512, 1, 1),
            LayerSpec("fc3", 512, 10, 1, 1)]
