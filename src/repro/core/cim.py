"""Bit-exact functional model of the TL-nvSRAM-CIM macro (Figs. 3-5, §3).

The macro computes y = x @ w with ternary-coded operands:

* weights: q_w balanced trits, each trit restored into a PAIR of 6T SRAM
  cells (Q1Q2 per Table 1);
* inputs: q_i balanced trits driven serially (IN1/IN2 per Table 1), one
  trit per CIM cycle;
* 16 rows activated at a time; each row contributes 1 - x*w discharge
  paths to the shared CBL (differential scheme: 2 paths for product -1,
  1 for 0, 0 for +1), so the CBL *count* for a 16-row group lies in
  [0, 32] and is sensed by a 5-bit ADC (32 codes -> the single extreme
  count 32 saturates at 31; this is the macro's only intrinsic
  nonideality and is faithfully modeled);
* a shift-&-add combines trit positions with powers of 3 and row groups
  by plain summation.

With ``adc_bits`` large enough the model reduces EXACTLY to the integer
matmul of the quantized operands — a property tested in
tests/test_cim_macro.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .ternary import (TernaryTensor, encode_inputs, from_balanced_ternary,
                      signals_to_weight_trit, ternarize, weight_signals)


@dataclasses.dataclass(frozen=True)
class MacroConfig:
    """TL-nvSRAM-CIM macro parameters (defaults: the paper's 256x320 array)."""
    rows: int = 256                # SRAM rows per subarray
    sram_cols: int = 320           # SRAM columns (2 per trit column)
    rows_active: int = 16          # rows accumulated per CBL sense
    adc_bits: int = 5              # ADC resolution (counts domain)
    cbls_per_adc: int = 5          # ADC sharing (mux ratio)
    num_trits: int = 5             # trits per weight / input
    clusters_per_cell: int = 4     # m
    rerams_per_cluster: int = 60   # n
    num_subarrays: int = 6

    @property
    def trit_cols(self) -> int:           # weight-trit columns (= CBLs)
        return self.sram_cols // 2

    @property
    def weights_per_row(self) -> int:
        return self.trit_cols // self.num_trits

    @property
    def adcs(self) -> int:
        return self.trit_cols // self.cbls_per_adc

    @property
    def trits_per_cell(self) -> int:      # ReRAM capacity behind one trit position
        return self.clusters_per_cell * self.rerams_per_cluster

    @property
    def subarray_weight_capacity_trits(self) -> int:
        return self.rows * self.trit_cols * self.trits_per_cell

    def row_groups(self, k: int) -> int:
        return -(-k // self.rows_active)


def adc_transfer(count: jax.Array, adc_bits: int, noise: Optional[jax.Array] = None) -> jax.Array:
    """CBL count -> ADC code.  Counts live in [0, 2*rows_active]; a b-bit ADC
    has 2**b codes. Optional additive noise (in LSB) models readout noise."""
    x = count.astype(jnp.float32)
    if noise is not None:
        x = x + noise
    code = jnp.clip(jnp.round(x), 0, 2**adc_bits - 1)
    return code.astype(jnp.int32)


def cim_matmul_int(x_trits: jax.Array, w_trits: jax.Array, cfg: MacroConfig,
                   adc_noise_sigma: float = 0.0,
                   key: Optional[jax.Array] = None) -> jax.Array:
    """Integer CIM matmul over trit planes.

    x_trits: (q_i, B, K) int8; w_trits: (q_w, K, N) int8 -> (B, N) int32
    equal (up to ADC saturation/noise) to sum_ij 3^{i+j} (x_i @ w_j).
    """
    qi, b, k = x_trits.shape
    qw, k2, n = w_trits.shape
    assert k == k2, (k, k2)
    ra = cfg.rows_active
    g = cfg.row_groups(k)
    pad = g * ra - k
    if pad:
        x_trits = jnp.pad(x_trits, ((0, 0), (0, 0), (0, pad)))
        w_trits = jnp.pad(w_trits, ((0, 0), (0, pad), (0, 0)))
    xg = x_trits.reshape(qi, b, g, ra)
    wg = w_trits.reshape(qw, g, ra, n)
    # raw per-group MAC:  (qi, qw, B, G, N)
    mac = jnp.einsum("ibgr,jgrn->ijbgn", xg.astype(jnp.int32), wg.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    # number of active rows with a non-zero input trit in each group drives
    # the count offset: count = sum_r active_r * (1 - x_r w_r) over rows the
    # input driver actually pulls (x may be 0 -> still 1 path; inactive pad
    # rows contribute 0 paths). Padded rows are modeled as deactivated.
    rows_real = jnp.minimum(ra, jnp.maximum(0, k - jnp.arange(g) * ra))  # (G,)
    count = rows_real[None, None, None, :, None] - mac
    if adc_noise_sigma > 0.0:
        assert key is not None, "adc noise requires a PRNG key"
        noise = adc_noise_sigma * jax.random.normal(key, count.shape)
    else:
        noise = None
    code = adc_transfer(count, cfg.adc_bits, noise)
    mac_q = rows_real[None, None, None, :, None] - code
    # shift & add over trit positions (powers of 3) and sum over groups
    p3i = jnp.array([3**i for i in range(qi)], dtype=jnp.int32)
    p3j = jnp.array([3**j for j in range(qw)], dtype=jnp.int32)
    scale = p3i[:, None] * p3j[None, :]                       # (qi, qw)
    return jnp.einsum("ij,ijbn->bn", scale, mac_q.sum(axis=3))


def cim_matmul(x: jax.Array, w: jax.Array, cfg: MacroConfig = MacroConfig(),
               method: str = "truncate", adc_noise_sigma: float = 0.0,
               key: Optional[jax.Array] = None,
               w_ternary: Optional[TernaryTensor] = None) -> jax.Array:
    """Float-in/float-out CIM matmul: quantize -> trit MAC -> rescale.

    x: (B, K) float; w: (K, N) float (or pre-ternarized via w_ternary).
    """
    xt = encode_inputs(x, cfg.num_trits)
    wt = w_ternary if w_ternary is not None else ternarize(w, cfg.num_trits, method=method)
    y_int = cim_matmul_int(xt.trits, wt.trits, cfg, adc_noise_sigma, key)
    return y_int.astype(jnp.float32) * xt.scale * wt.scale


# ----------------------------------------------------------------------
# Store / restore state machine (Table 2, Figs. 4-5) — behavioural model.
# ----------------------------------------------------------------------

# Signal settings of Table 2, kept as data so tests can assert the modes.
VDD, VDDH, VDDL, VSTR = 0.9, 1.5, 0.6, 0.31
SIGNAL_TABLE = {
    ("store", 1):   dict(SEL_i=VDDH, SL_j=0.0, SL_x=VDDL, RSTR=0.0, STR1=0.0, STR2=0.0, CBL=VDDH),
    ("store", 2):   dict(SEL_i=VDDH, SL_j=VDDH, SL_x=VDDL, RSTR=0.0, STR1=VDD, STR2=VSTR, CBL=None),
    ("restore", 1): dict(SEL_i=0.0, SL_j=VDDL, SL_x=VDDL, RSTR=0.0, STR1=0.0, STR2=0.0, CBL=None),
    ("restore", 2): dict(SEL_i=VDD, SL_j=0.0, SL_x=VDDL, RSTR=VDD, STR1=0.0, STR2=0.0, CBL=None),
    ("cim", 0):     dict(SEL_i=0.0, SL_j=VDDL, SL_x=VDDL, RSTR=0.0, STR1="INB2", STR2="INB1", CBL="MAC"),
}

# ReRAM levels
HRS, MRS, LRS = 0, 1, 2
TRIT_TO_LEVEL = {-1: HRS, 0: MRS, 1: LRS}
LEVEL_TO_TRIT = {HRS: -1, MRS: 0, LRS: 1}


def store_trits_to_levels(trits: jax.Array) -> jax.Array:
    """Store mode: SRAM pair (Q1,Q2) -> conditional set current -> level.

    Phase 1 resets the selected ReRAM to HRS; phase 2 produces set current
    I00 (-> LRS) for Q1Q2=00, I10 (-> MRS) for 10, none (stay HRS) for 11.
    """
    q1, q2 = weight_signals(trits)
    level = jnp.where((q1 == 0) & (q2 == 0), LRS,
                      jnp.where((q1 == 1) & (q2 == 0), MRS, HRS))
    return level.astype(jnp.int8)


def restore_levels_to_trits(levels: jax.Array,
                            resistances: Optional[jax.Array] = None,
                            g_leak: float | jax.Array = 0.0,
                            g_ref: Optional[tuple] = None,
                            cmp_noise: Optional[tuple[jax.Array, jax.Array]] = None,
                            device=None) -> jax.Array:
    """Restore mode: ReRAM level (+ sampled resistance) -> (Q1, Q2) -> trit.

    With no variation arguments this is the ideal restore (exact inverse of
    store).  With `resistances` (ohms, same shape as levels) and leak /
    reference conductances it runs the differential-discharge comparison of
    §3.4 and may make errors — exactly what the yield model measures.
    """
    if resistances is None:
        q1 = (levels != LRS)
        q2 = (levels == HRS)
        return signals_to_weight_trit(q1.astype(jnp.int8), q2.astype(jnp.int8))
    from . import device_models as dm
    d = device or dm.DeviceParams()
    g_cell = dm.discharge_conductance(resistances, d) + g_leak
    if g_ref is None:
        g_ref = dm.reference_conductances(d)
    g_ref1, g_ref2, g_ref3 = g_ref
    n1 = n2 = 0.0
    if cmp_noise is not None:
        n1, n2 = cmp_noise
    q1 = (g_cell + n1 < g_ref1)                    # R above ref1 -> Q1=1
    q2_hi = (g_cell + n2 < g_ref2)                 # Q1=1 branch (VREF2)
    q2_lo = (g_cell + n2 < g_ref3)                 # Q1=0 branch (VREF3)
    q2 = jnp.where(q1, q2_hi, q2_lo)
    return signals_to_weight_trit(q1.astype(jnp.int8), q2.astype(jnp.int8))


def roundtrip_store_restore(trits: jax.Array, **restore_kw) -> jax.Array:
    """store -> (ideal or varied) restore; identity when ideal."""
    return restore_levels_to_trits(store_trits_to_levels(trits), **restore_kw)
