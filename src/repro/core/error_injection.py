"""Yield-driven trit-error injection (Fig. 10 methodology).

The paper evaluates NN accuracy by injecting bit errors "induced by
incorrect restore operations" into the weight matrix at the measured
restore-yield rate, then retraining.  Failures are *boundary* events:
a state is misread as the neighboring state whose decision margin was
violated (HRS<->MRS via V_REF2, MRS<->LRS via V_REF1); double-boundary
errors are second-order and ignored.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ternary import TernaryTensor


def confusion_from_yields(per_state: jax.Array) -> jax.Array:
    """(3,) per-state yields [HRS(-1), MRS(0), LRS(+1)] -> (3,3) confusion
    matrix rows=true (index = trit+1), cols=read.

    Yields are validated: the input must be shape (3,), concrete values
    must be finite (a NaN yield silently poisons every sampled trit
    downstream), and each yield is clamped into [0, 1] — Monte-Carlo
    yield estimates at small sample counts can come out at 1 + eps and
    would otherwise produce negative error probabilities.  Every row of
    the result sums to 1 by construction (asserted on concrete inputs).
    """
    per_state = jnp.asarray(per_state, jnp.float32)
    if per_state.shape != (3,):
        raise ValueError(f"per-state yields must have shape (3,) "
                         f"[HRS, MRS, LRS]; got {per_state.shape}")
    if not isinstance(per_state, jax.core.Tracer):
        if not bool(jnp.all(jnp.isfinite(per_state))):
            raise ValueError(f"per-state yields must be finite; got "
                             f"{[float(v) for v in per_state]}")
    per_state = jnp.clip(per_state, 0.0, 1.0)
    y_h, y_m, y_l = per_state[0], per_state[1], per_state[2]
    # -1 fails -> read as 0; +1 fails -> read as 0; 0 splits to +/-1 evenly
    c = jnp.array([[0.0, 0.0, 0.0]] * 3)
    c = c.at[0].set(jnp.stack([y_h, 1 - y_h, jnp.zeros(())]))
    c = c.at[1].set(jnp.stack([(1 - y_m) / 2, y_m, (1 - y_m) / 2]))
    c = c.at[2].set(jnp.stack([jnp.zeros(()), 1 - y_l, y_l]))
    if not isinstance(c, jax.core.Tracer):
        row_sums = jnp.sum(c, axis=-1)
        assert bool(jnp.all(jnp.abs(row_sums - 1.0) < 1e-6)), (
            f"confusion rows must sum to 1; got "
            f"{[float(v) for v in row_sums]}")
    return c


def inject_trit_errors(trits: jax.Array, per_state_yield: jax.Array,
                       key: jax.Array) -> jax.Array:
    """Sample restore errors on a trit-plane tensor ((q, ...) int8)."""
    conf = confusion_from_yields(jnp.asarray(per_state_yield, jnp.float32))
    u = jax.random.uniform(key, trits.shape)
    row = conf[(trits + 1).astype(jnp.int32)]          # (..., 3) probs
    cdf = jnp.cumsum(row, axis=-1)
    read_idx = jnp.sum(u[..., None] > cdf, axis=-1)    # 0..2
    return (read_idx - 1).astype(jnp.int8)


def inject_restore_errors(t: TernaryTensor, per_state_yield, key) -> TernaryTensor:
    return TernaryTensor(inject_trit_errors(t.trits, per_state_yield, key), t.scale)


def expected_trit_error_rate(per_state_yield, prior=(0.25, 0.5, 0.25)) -> float:
    p = jnp.asarray(prior)
    y = jnp.asarray(per_state_yield)
    return float(jnp.dot(p, 1.0 - y))
