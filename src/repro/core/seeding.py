"""Deterministic, PYTHONHASHSEED-independent seed derivation.

Every Monte-Carlo consumer in the repo (yield sweeps, fault campaigns,
benchmark cells) derives its PRNG keys from :func:`stable_seed` over
*named* parts instead of ad-hoc integer offsets (``fold_in(key, 999+n)``),
so (i) adding a cell never silently re-seeds its neighbors, and (ii) the
same cell reproduces bitwise across processes and Python versions
(``hash()`` is salted per process; ``zlib.crc32`` is not).

``benchmarks.common.stable_seed`` re-exports this function — lint rule
RA004 (repro.analysis) flags ``jax.random`` key construction in
``benchmarks/`` that bypasses it.
"""
from __future__ import annotations

import zlib


def stable_seed(*parts) -> int:
    """Deterministic 31-bit seed from string-able parts (crc32, not
    ``hash()`` — PYTHONHASHSEED-independent)."""
    return zlib.crc32("|".join(map(str, parts)).encode()) % (2**31)


def derive_key(*parts):
    """``jax.random.key`` seeded by ``stable_seed(*parts)`` (imported
    lazily so this module stays dependency-free for host-side use)."""
    import jax
    return jax.random.key(stable_seed(*parts))
