"""Dense trit packing — the TPU image of TL-ReRAM storage density.

Two packed formats:

* ``trit2``  — one trit per weight, 2-bit codes, 4 trits/byte.  This is the
  single-trit ("pure ternary") mode: 8x denser than bf16.  Code map:
  0 -> 0, 1 -> +1, 2 -> -1 (3 unused).
* ``base3``  — the paper's 5-trit weights.  3^5 = 243 <= 256, so a whole
  5-trit balanced number v in [-121,121] packs into ONE byte as v+121.
  This is exactly why the paper pairs 5-trit coding with 8-bit systems
  (Fig. 7b); decode is a single subtract.  2x denser than bf16 at ~8b
  precision.

Packing always runs along the FIRST axis of the trit/value array (the
contraction axis K of a (K, N) weight), so the matmul kernel can unpack
K-tiles straight in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ternary import from_balanced_ternary, to_balanced_ternary, trit_range

TRIT2_PER_BYTE = 4
_ENC = jnp.array([2, 0, 1], dtype=jnp.uint8)  # index by trit+1 -> code


def pack_trits2(trits: jax.Array) -> jax.Array:
    """(K, ...) int8 trits in {-1,0,1} -> (K//4, ...) uint8, little-endian
    2-bit fields. K must be a multiple of 4 (pad upstream)."""
    k = trits.shape[0]
    if k % TRIT2_PER_BYTE:
        raise ValueError(f"K={k} not a multiple of {TRIT2_PER_BYTE}")
    codes = _ENC[(trits.astype(jnp.int32) + 1)]  # uint8 codes 0..2
    g = codes.reshape((k // TRIT2_PER_BYTE, TRIT2_PER_BYTE) + trits.shape[1:])
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8).reshape(
        (1, TRIT2_PER_BYTE) + (1,) * (trits.ndim - 1))
    return jnp.sum(
        (g.astype(jnp.uint8) << shifts).astype(jnp.uint8), axis=1, dtype=jnp.uint8
    )


def unpack_trits2(packed: jax.Array, k: int | None = None) -> jax.Array:
    """Inverse of pack_trits2 -> (K, ...) int8 in {-1,0,1}."""
    kp = packed.shape[0]
    fields = []
    for i in range(TRIT2_PER_BYTE):
        c = (packed >> (2 * i)) & 0x3
        fields.append(c)
    codes = jnp.stack(fields, axis=1).reshape((kp * TRIT2_PER_BYTE,) + packed.shape[1:])
    vals = (codes == 1).astype(jnp.int8) - (codes == 2).astype(jnp.int8)
    return vals[:k] if k is not None else vals


def pack_base3(values: jax.Array, num_trits: int = 5) -> jax.Array:
    """Integer values in [-trit_range, trit_range] -> uint8 (value+offset).

    Requires 3**num_trits <= 256 (num_trits <= 5)."""
    if 3**num_trits > 256:
        raise ValueError("base3 packing needs 3^q <= 256 (q <= 5)")
    lim = trit_range(num_trits)
    v = jnp.clip(values.astype(jnp.int32), -lim, lim)
    return (v + lim).astype(jnp.uint8)


def unpack_base3(packed: jax.Array, num_trits: int = 5) -> jax.Array:
    """uint8 -> int32 values in [-121, 121]; decode = subtract offset."""
    lim = trit_range(num_trits)
    return packed.astype(jnp.int32) - lim


def pack_trit_planes_base3(trits: jax.Array) -> jax.Array:
    """(q, K, ...) trit planes -> (K, ...) uint8 base3-packed values."""
    return pack_base3(from_balanced_ternary(trits), trits.shape[0])


def unpack_base3_to_planes(packed: jax.Array, num_trits: int = 5) -> jax.Array:
    """uint8 base3 -> (q, K, ...) trit planes (for the CIM-exact path)."""
    return to_balanced_ternary(unpack_base3(packed, num_trits), num_trits)


def packed_bytes(shape: tuple[int, ...], mode: str, num_trits: int = 5) -> int:
    """HBM bytes for a weight of `shape` in the given packed mode."""
    import math
    n = math.prod(shape)
    if mode == "trit2":
        return n * num_trits // TRIT2_PER_BYTE  # 2 bits per trit
    if mode == "base3":
        return n  # one byte per (<=5)-trit weight
    if mode == "bf16":
        return 2 * n
    raise ValueError(mode)
