"""Architecture-level energy / throughput / area model (Tables 4-5, Figs 9/11).

All per-op constants are the paper's Table 5; the few values the paper
does not publish are calibrated once and documented:

* ``E_COL_RERAM_CIM`` — ReRAM-CIM column-cycle energy.  The paper reports
  only the end ratio (TL = 2.0x baseline-3).  0.30 pJ/col-cycle (≈2.7x
  the SRAM column energy — consistent with the larger cell currents of
  current-domain ReRAM readout) reproduces that ratio.
* ``PERIPHERY_AREA_UM2`` — per-subarray periphery (ADCs, drivers, S&A).
  194,000 µm² simultaneously reproduces Fig. 11(a)'s 7.2x array-density
  gain and Fig. 11(b)'s 89.1% area saving.

Cycle/throughput model (validated against three separate paper claims):
a b-bit x b-bit MAC decomposes into b*b single-bit (or t*t single-trit)
partial products; each ADC sense accumulates `rows_active` partials for
one CBL; per cycle, #ADCs CBLs are sensed.  Peak MACs/cycle =
ADCs * rows_active / width^2  ->  BC: 32*32/64 = 16, TC: 32*16/25 = 20.48
(1.28x ~ the paper's 1.3x), and a 250-column TC array: 25*16/25 = 16
(parity with 21.9% fewer ADCs — §4.3)."""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .cim import MacroConfig
from .mapping import LayerSpec, MappingPlan, compact_map, subarrays_needed

PJ = 1e-12
FJ = 1e-15


@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    # Table 5
    e_col_sram_cim: float = 0.11 * PJ        # per column-cycle, 32 rows (BC)
    e_cbl_tl_cim: float = 0.096 * PJ         # per CBL-cycle, 16 rows (TC)
    e_restore_tl_array: float = 75.2 * PJ    # per array restore cycle
    e_ternary_encoder: float = 13.1 * FJ     # per 8b->5t conversion
    e_adc: float = 0.188 * PJ                # per 5-bit conversion
    e_shift_add: float = 0.336 * PJ / 5      # per CBL/col-cycle (0.336 pJ/5col)
    e_buffer_bit: float = 0.042 * PJ
    e_dram_bit: float = 4.2 * PJ
    e_reram_read_bit: float = 1.63 * PJ
    # Table 4 (cell level, layout-extracted)
    e_store_sl_cell: float = 360 * FJ
    e_store_tl_cell: float = 69.2 * FJ
    e_restore_sl_bit: float = 15.6 * FJ
    e_restore_tl_trit: float = 8.57 * FJ
    area_6t_um2: float = 0.75
    area_sl_cell_um2: float = 2.33
    area_tl_cell_um2: float = 6.35
    # calibrated (see module docstring)
    e_col_reram_cim: float = 0.30 * PJ
    periphery_area_um2: float = 194_000.0


C = EnergyConstants()

# ---------------------------------------------------------------- throughput

def macs_per_cycle(adcs: int, rows_active: int, width: int) -> float:
    """Peak full-precision MACs per cycle for a width-bit/trit coded array."""
    return adcs * rows_active / (width * width)


def peak_throughput_ratio(cfg: MacroConfig = MacroConfig()) -> float:
    """Fig. 9(a): TC(5t, 16 rows) vs BC(8b, 32 rows), 32 ADCs each."""
    tc = macs_per_cycle(cfg.adcs, cfg.rows_active, cfg.num_trits)
    bc = macs_per_cycle(32, 32, 8)
    return tc / bc


# ------------------------------------------------------------- cell metrics

def cell_metrics(cfg: MacroConfig = MacroConfig(), c: EnergyConstants = C) -> dict:
    """Reproduces Table 4 (density & CIM efficiency are derived, not copied)."""
    trits = cfg.trits_per_cell                       # 240
    bits_equiv = trits * 8 / 5                       # paper counts 384 "bits"
    sl_bits = 18
    tl = dict(
        data_per_cell_trits=trits,
        data_per_cell_bits=bits_equiv,
        store_energy=c.e_store_tl_cell,
        restore_energy=c.e_restore_tl_trit,
        # ops/fJ: 16 rows x 2 ops, x (64/25) effective-precision factor
        cim_efficiency_op_per_fj=(cfg.rows_active * 2 * (64 / 25))
        / (c.e_cbl_tl_cim / FJ),
        area_um2=c.area_tl_cell_um2,
        density_bits_um2=bits_equiv / c.area_tl_cell_um2,
    )
    sl = dict(
        data_per_cell_bits=sl_bits,
        store_energy=c.e_store_sl_cell,
        restore_energy=c.e_restore_sl_bit,
        cim_efficiency_op_per_fj=(32 * 2) / (c.e_col_sram_cim / FJ),
        area_um2=c.area_sl_cell_um2,
        density_bits_um2=sl_bits / c.area_sl_cell_um2,
    )
    return {"tl": tl, "sl": sl,
            "density_gain": tl["density_bits_um2"] / sl["density_bits_um2"]}


# ------------------------------------------------------ capacity & area

def array_capacity_bits(scheme: str, cfg: MacroConfig = MacroConfig()) -> float:
    """On-chip weight capacity of ONE subarray, in equivalent bits."""
    if scheme == "tl":
        trits = cfg.rows * cfg.trit_cols * cfg.trits_per_cell
        return trits * 8 / 5
    if scheme == "sl":            # [DAC'22]: 18 SL-ReRAMs per cell
        return 256 * 256 * 18
    if scheme == "sl_sel":        # SL + DC-free selectors: 3 groups x 18
        return 256 * 256 * 54
    if scheme in ("sram_dram", "sram_reram", "reram_cim"):
        return 256 * 256          # SRAM-resident bits only
    raise ValueError(scheme)


def array_area_um2(scheme: str, cfg: MacroConfig = MacroConfig(),
                   c: EnergyConstants = C) -> float:
    cell = {"tl": c.area_tl_cell_um2}.get(scheme, c.area_sl_cell_um2)
    cells = cfg.rows * cfg.trit_cols if scheme == "tl" else 256 * 256
    if scheme in ("sram_dram", "sram_reram", "reram_cim"):
        cell = c.area_6t_um2
    return cells * cell + c.periphery_area_um2


def arrays_to_fit(model_bytes: float, scheme: str, cfg: MacroConfig = MacroConfig()) -> int:
    return math.ceil(model_bytes * 8 / array_capacity_bits(scheme, cfg))


# ------------------------------------------------------- inference energy

@dataclasses.dataclass
class EnergyBreakdown:
    cim_array: float = 0.0
    adc: float = 0.0
    shift_add: float = 0.0
    encoder: float = 0.0
    buffer: float = 0.0
    weight_supply: float = 0.0   # DRAM / ReRAM-read / restore
    total: float = 0.0

    def finish(self):
        self.total = (self.cim_array + self.adc + self.shift_add +
                      self.encoder + self.buffer + self.weight_supply)
        return self


def inference_energy(layers: Sequence[LayerSpec], scheme: str,
                     cfg: MacroConfig = MacroConfig(), c: EnergyConstants = C,
                     num_arrays: int | None = None,
                     in_bits: int = 8, w_bits: int = 8) -> EnergyBreakdown:
    """Per-inference energy of the five evaluated schemes (§4.1).

    scheme: 'tl' | 'sl' (baseline-4) | 'sram_dram' (b1) | 'sram_reram' (b2)
            | 'reram_cim' (b3).
    `num_arrays` caps on-chip capacity (None = enough to fit: the paper's
    default for b2/b3/b4; b1's SRAM never fits a whole model)."""
    e = EnergyBreakdown()
    total_macs = sum(l.macs() for l in layers)
    model_bits = sum(l.params() for l in layers) * w_bits
    total_in_elems = sum(l.rows * l.spatial for l in layers)
    total_out_elems = sum(l.cout * l.spatial for l in layers)

    if scheme == "tl":
        q = cfg.num_trits
        partials = total_macs * q * q
        cbl_cycles = partials / cfg.rows_active
        e.cim_array = cbl_cycles * c.e_cbl_tl_cim
        e.adc = cbl_cycles * c.e_adc
        e.shift_add = cbl_cycles * c.e_shift_add
        e.encoder = total_in_elems * c.e_ternary_encoder
        e.buffer = (total_in_elems + total_out_elems) * 8 * c.e_buffer_bit
        n_arr = num_arrays or subarrays_needed(layers, cfg)
        fit_bits = n_arr * array_capacity_bits("tl", cfg)
        # the first-fit planner is exact for CNN-scale models; LLM-scale
        # models have millions of blocks, where the analytic depth count
        # (ceil(total trits / trits-per-depth-level)) is equivalent for
        # the energy term and O(1)
        n_blocks = sum(
            math.ceil(l.rows / cfg.rows_active)
            * math.ceil(l.cols(cfg.num_trits) / cfg.sram_cols)
            for l in layers)
        if n_blocks <= 50_000:
            restore_cycles = compact_map(layers, cfg, n_arr).restore_cycles
        else:
            total_trits = sum(l.params() for l in layers) * cfg.num_trits
            per_depth = n_arr * cfg.rows * cfg.trit_cols
            restore_cycles = math.ceil(total_trits / per_depth)
        e.weight_supply = n_arr * c.e_restore_tl_array * max(1, restore_cycles)
        overflow_bits = max(0.0, model_bits * 5 / 8 - fit_bits)  # trit bits
        e.weight_supply += overflow_bits * c.e_dram_bit
        return e.finish()

    # binary-coded schemes share the BC cycle structure
    partials = total_macs * in_bits * w_bits
    col_cycles = partials / 32
    e_col = c.e_col_reram_cim if scheme == "reram_cim" else c.e_col_sram_cim
    e.cim_array = col_cycles * e_col
    e.adc = col_cycles * c.e_adc
    e.shift_add = col_cycles * c.e_shift_add
    e.buffer = (total_in_elems + total_out_elems) * 8 * c.e_buffer_bit

    # weights a streaming baseline actually touches: spatial < 1 marks
    # conditionally-activated (MoE expert) layers — DRAM/ReRAM baselines
    # fetch only the routed fraction, CIM schemes store everything
    touched_bits = sum(l.params() * min(l.spatial, 1.0)
                       for l in layers) * w_bits
    if scheme == "sram_dram":        # baseline-1: stream weights from DRAM
        e.weight_supply = touched_bits * c.e_dram_bit
    elif scheme == "sram_reram":     # baseline-2: on-chip ReRAM -> SRAM each pass
        e.weight_supply = touched_bits * c.e_reram_read_bit
    elif scheme == "reram_cim":      # baseline-3: in-situ, no movement
        e.weight_supply = 0.0
    elif scheme == "sl":             # baseline-4: restore from SL-ReRAMs
        n_arr = num_arrays or arrays_to_fit(model_bits / 8, "sl", cfg)
        fit_bits = n_arr * array_capacity_bits("sl", cfg)
        restored = min(model_bits, fit_bits)
        e.weight_supply = restored * c.e_restore_sl_bit
        overflow = max(0.0, model_bits - fit_bits)
        e.weight_supply += overflow * c.e_dram_bit
    else:
        raise ValueError(scheme)
    return e.finish()


def efficiency_ratios(layers: Sequence[LayerSpec],
                      cfg: MacroConfig = MacroConfig(), c: EnergyConstants = C,
                      same_area_sl: bool = False) -> dict:
    """Fig. 9(b) / Fig. 11(b): TL energy-efficiency gains vs each baseline."""
    tl = inference_energy(layers, "tl", cfg, c).total
    out = {}
    for s in ("sram_dram", "sram_reram", "reram_cim", "sl"):
        kw = {}
        if s == "sl" and same_area_sl:
            # SL constrained to TL's area -> limited capacity -> DRAM refills
            tl_area = array_area_um2("tl", cfg, c) * subarrays_needed(layers, cfg)
            kw["num_arrays"] = max(1, int(tl_area // array_area_um2("sl", cfg, c)))
        out[s] = inference_energy(layers, s, cfg, c, **kw).total / tl
    return out


def area_and_ee_per_area(layers: Sequence[LayerSpec],
                         cfg: MacroConfig = MacroConfig(), c: EnergyConstants = C) -> dict:
    """Fig. 11(b): whole-model area and energy-efficiency-per-area."""
    model_bytes = sum(l.params() for l in layers)  # 8b weights
    n_tl = subarrays_needed(layers, cfg)
    n_sl = arrays_to_fit(model_bytes, "sl", cfg)
    a_tl = n_tl * array_area_um2("tl", cfg, c)
    a_sl = n_sl * array_area_um2("sl", cfg, c)
    e_tl = inference_energy(layers, "tl", cfg, c).total
    e_sl = inference_energy(layers, "sl", cfg, c, num_arrays=n_sl).total
    ee_per_area = (1 / e_tl / a_tl) / (1 / e_sl / a_sl)
    # same-area scenario: SL capped to TL's footprint
    n_sl_same = max(1, int(a_tl // array_area_um2("sl", cfg, c)))
    e_sl_same = inference_energy(layers, "sl", cfg, c, num_arrays=n_sl_same).total
    ee_per_area_same = (1 / e_tl / a_tl) / (1 / e_sl_same / (n_sl_same * array_area_um2("sl", cfg, c)))
    return {
        "tl_arrays": n_tl, "sl_arrays": n_sl,
        "tl_area_mm2": a_tl / 1e6, "sl_area_mm2": a_sl / 1e6,
        "area_saved": 1 - a_tl / a_sl,
        "ee_per_area_gain": ee_per_area,
        "ee_per_area_gain_same_area": ee_per_area_same,
    }
