"""Device models: TL-ReRAM, bidirectional selector, CMOS mismatch (§3.2/3.4).

Constants are the paper's (Table 2 footnote and §3.2):
  LRS 80 kΩ, HRS 1 MΩ, MRS = argmax min(MRS/LRS, HRS/MRS) ≈ 282 kΩ;
  selector V_IMT 0.45 V, V_MIT 25 mV, R_metallic 40 kΩ, R_insulating 0.12 GΩ;
  ReRAM variation: filament-gap 3σ/μ = 10 %;
  V_DD 0.9 V, V_DDH 1.5 V, V_DDL 0.6 V, V_STR 0.31 V.

Calibration note (DESIGN.md §2): the paper runs SPICE Monte-Carlo; we use
an analytic discharge-current model.  Gap variation maps to log-resistance
variation through the exponential gap→R law, so R is lognormal with
σ_lnR = (3σ/μ-gap / 3) · ln(HRS/LRS) · κ, κ = 1 (the gap modulates the
full tunneling-resistance dynamic range).  CMOS mismatch enters as a
Gaussian comparator/current offset.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeviceParams:
    r_lrs: float = 80e3
    r_hrs: float = 1e6
    r_mrs: float | None = None          # None -> derived optimal (≈282.8 kΩ)
    # selector (bidirectional, IMT/MIT)
    v_imt: float = 0.45
    v_mit: float = 0.025
    r_sel_metallic: float = 40e3
    r_sel_insulating: float = 0.12e9
    # access/discharge transistor on-resistance (28 nm core device)
    r_nmos: float = 10e3
    # variations
    gap_3sigma_over_mu: float = 0.10    # paper: 10 %
    cmos_sigma_rel: float = 0.03        # discharge-current mismatch (σ/I)
    comparator_sigma_siemens: float = 0.10e-6  # latch input-referred offset
    # supplies
    vdd: float = 0.9
    vddh: float = 1.5
    vddl: float = 0.6
    vstr: float = 0.31

    @property
    def mrs(self) -> float:
        if self.r_mrs is not None:
            return self.r_mrs
        return optimal_mrs(self.r_lrs, self.r_hrs)

    @property
    def sigma_ln_r(self) -> float:
        return (self.gap_3sigma_over_mu / 3.0) * math.log(self.r_hrs / self.r_lrs)


def optimal_mrs(r_lrs: float, r_hrs: float) -> float:
    """MRS maximizing min(MRS/LRS, HRS/MRS) -> geometric mean (§3.2: 282 kΩ)."""
    return math.sqrt(r_lrs * r_hrs)


def level_resistance(level: jax.Array, d: DeviceParams) -> jax.Array:
    """ReRAM level (0=HRS,1=MRS,2=LRS) -> nominal resistance."""
    table = jnp.array([d.r_hrs, d.mrs, d.r_lrs])
    return table[level]


def sample_resistance(level: jax.Array, key: jax.Array, d: DeviceParams,
                      shape=()) -> jax.Array:
    """Lognormal resistance sample around the level's nominal value."""
    nominal = level_resistance(level, d)
    z = jax.random.normal(key, shape if shape else jnp.shape(nominal))
    return nominal * jnp.exp(d.sigma_ln_r * z)


def discharge_conductance(r_reram, d: DeviceParams,
                          cmos_rel: jax.Array | float = 0.0) -> jax.Array:
    """Conductance of the Q-node discharge path: ReRAM in series with the
    metallic selector and the restore NMOS; CMOS mismatch scales current."""
    g = 1.0 / (r_reram + d.r_sel_metallic + d.r_nmos)
    return g * (1.0 + cmos_rel)


def leakage_conductance(n: int, m: int, d: DeviceParams,
                        sel_off_leak: float = 2e-9) -> float:
    """Parasitic discharge through the (n-1) unselected insulating selectors
    of the active cluster plus the (m-1) off clusters' SEL transistors.
    This is the term that grows with cluster size n and ultimately bounds
    restore yield (Fig. 6) — but only ~0.5 µS even at n = 60, versus the
    collapsing margins of the voltage-divider select scheme of [12]."""
    g_unsel = (n - 1) / d.r_sel_insulating
    g_off_clusters = (m - 1) * sel_off_leak
    return g_unsel + g_off_clusters


def reference_conductances(d: DeviceParams) -> tuple[float, float, float]:
    """V_REF1/2/3 ladders (serially connected ReRAMs, §3.2) as discharge
    conductances.  ref1 splits LRS|MRS, ref2 splits MRS|HRS, ref3 sits far
    above LRS so the Q1=0 branch always resolves Q2=0."""
    r1 = math.sqrt(d.r_lrs * d.mrs)
    r2 = math.sqrt(d.mrs * d.r_hrs)
    r3 = 8.0 * d.r_lrs
    def g(r):
        return 1.0 / (r + d.r_sel_metallic + d.r_nmos)
    return g(r1), g(r2), g(r3)


def sample_reference_conductances(key: jax.Array, d: DeviceParams, shape=()):
    """Reference ladders are built from ReRAMs too -> they vary.  Two series
    devices halve the variance of ln R (σ/√2)."""
    k1, k2, k3 = jax.random.split(key, 3)
    sig = d.sigma_ln_r / math.sqrt(2.0)
    r1 = math.sqrt(d.r_lrs * d.mrs) * jnp.exp(sig * jax.random.normal(k1, shape))
    r2 = math.sqrt(d.mrs * d.r_hrs) * jnp.exp(sig * jax.random.normal(k2, shape))
    r3 = 8.0 * d.r_lrs * jnp.exp(sig * jax.random.normal(k3, shape))
    def g(r):
        return 1.0 / (r + d.r_sel_metallic + d.r_nmos)
    return g(r1), g(r2), g(r3)


# ---------------- SL-nvSRAM-CIM voltage-divider select scheme [12] -------

def sl_divider_voltage(r_selected: jax.Array, r_unselected: jax.Array,
                       v: float = 0.9) -> jax.Array:
    """Voltage-divider readout of the previous SL-nvSRAM-CIM: the selected
    SL-ReRAM in series with the parallel combination of the (n-1)
    unselected ones.  V_X = V · R_par / (R_sel + R_par); r_unselected has
    shape (..., n-1)."""
    r_par = 1.0 / jnp.sum(1.0 / r_unselected, axis=-1)
    return v * r_par / (r_selected + r_par)


def sl_nominal_threshold(n: int, d: DeviceParams, v: float = 0.9,
                         n_design: int = 6) -> float:
    """Fixed SRAM trip voltage for the SL voltage-divider scheme [12].

    The divider output V_X drives the SRAM cell's restore node, whose trip
    point is FIXED by the CMOS design — [12] sized it for its silicon
    configuration of 6 SL-ReRAMs per group.  The returned value is the
    midpoint of the nominal HRS/LRS divider outputs at `n_design` with a
    balanced unselected population.  As the actual n grows past the design
    point, V_X(LRS) slides below this trip voltage and restore collapses —
    the scalability wall of §2.2.  (Pure Python: usable inside jitted
    callers with concrete n.)"""
    nd = n_design
    half = max(1, (nd - 1) // 2)
    g_par = half / d.r_lrs + max(0, nd - 1 - half) / d.r_hrs
    r_par = 1.0 / g_par
    v_h = v * r_par / (d.r_hrs + r_par)
    v_l = v * r_par / (d.r_lrs + r_par)
    return (v_h + v_l) / 2.0
