"""Balanced-ternary codec and the paper's truncating quantization (Table 1/3).

The paper stores each weight as ``q`` balanced-ternary trits (one trit per
TL-ReRAM; -1/0/+1 <-> HRS/MRS/LRS) and encodes 8-bit inputs as 5 trits via
the ternary input driver.  5 trits cover +/-(3^5-1)/2 = +/-121, slightly
less than int8's +/-127, hence the paper's "quantize to 8-bit, then
truncate to 5-trit" scheme (Table 3) which clips the rare |w|>121 values.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

TRITS_DEFAULT = 5


def trit_range(num_trits: int) -> int:
    """Max magnitude representable by `num_trits` balanced trits."""
    return (3**num_trits - 1) // 2


def to_balanced_ternary(x: jax.Array, num_trits: int = TRITS_DEFAULT) -> jax.Array:
    """Integer array -> balanced-ternary trit planes.

    Returns int8 array of shape (num_trits,) + x.shape with values in
    {-1, 0, +1}; plane ``i`` holds the coefficient of 3**i (LSB first).
    Values outside +/-trit_range are clipped first (the paper's truncation).
    """
    lim = trit_range(num_trits)
    v = jnp.clip(x.astype(jnp.int32), -lim, lim)

    def digit(v):
        # balanced digit in {-1,0,1}: ((v mod 3) + 1) mod 3 - 1
        d = jnp.mod(v, 3)  # jnp.mod is non-negative for positive divisor
        d = jnp.where(d == 2, -1, d)
        return d

    planes = []
    for _ in range(num_trits):
        d = digit(v)
        planes.append(d.astype(jnp.int8))
        v = (v - d) // 3
    return jnp.stack(planes, axis=0)


def from_balanced_ternary(trits: jax.Array) -> jax.Array:
    """Inverse of :func:`to_balanced_ternary`. trits: (num_trits, ...)."""
    num_trits = trits.shape[0]
    weights = jnp.array([3**i for i in range(num_trits)], dtype=jnp.int32)
    return jnp.tensordot(weights, trits.astype(jnp.int32), axes=([0], [0]))


class QuantResult(NamedTuple):
    values: jax.Array  # integer codes (int32)
    scale: jax.Array   # per-tensor or per-axis float scale s.t. x ~= values*scale


def quantize_symmetric(x: jax.Array, bound: int, axis=None) -> QuantResult:
    """Symmetric linear quantization of float x to integers in [-bound, bound]."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-12) / bound
    q = jnp.clip(jnp.round(x / scale), -bound, bound).astype(jnp.int32)
    return QuantResult(q, scale)


def quantize_8b(x: jax.Array, axis=None) -> QuantResult:
    """BC(8b): int8 symmetric quantization (paper's binary-coding baseline)."""
    return quantize_symmetric(x, 127, axis=axis)


def quantize_5t_direct(x: jax.Array, num_trits: int = TRITS_DEFAULT, axis=None) -> QuantResult:
    """TC(5t) direct: scale straight into the +/-121 trit range (Table 3 row 3)."""
    return quantize_symmetric(x, trit_range(num_trits), axis=axis)


def quantize_8b_truncate_5t(x: jax.Array, num_trits: int = TRITS_DEFAULT, axis=None) -> QuantResult:
    """The paper's method (Table 3 row 4): quantize to 8-bit, then truncate
    (clip) the int8 codes into the 5-trit range.  Because NN weights are
    sparse/small, clipping 122..127 -> 121 is nearly lossless."""
    q8 = quantize_8b(x, axis=axis)
    lim = trit_range(num_trits)
    return QuantResult(jnp.clip(q8.values, -lim, lim), q8.scale)


class TernaryTensor(NamedTuple):
    """A tensor quantized to balanced-ternary trit planes."""
    trits: jax.Array   # int8 (num_trits,) + shape, values in {-1,0,1}
    scale: jax.Array   # float scale

    @property
    def num_trits(self) -> int:
        return self.trits.shape[0]

    def dequantize(self) -> jax.Array:
        return from_balanced_ternary(self.trits).astype(jnp.float32) * self.scale


def ternarize(x: jax.Array, num_trits: int = TRITS_DEFAULT, axis=None,
              method: str = "truncate") -> TernaryTensor:
    """Float tensor -> TernaryTensor using the paper's flow.

    method: 'truncate' (8b then clip; the paper's choice) or 'direct'.
    """
    if method == "truncate":
        q = quantize_8b_truncate_5t(x, num_trits, axis=axis)
    elif method == "direct":
        q = quantize_5t_direct(x, num_trits, axis=axis)
    else:
        raise ValueError(f"unknown method {method!r}")
    return TernaryTensor(to_balanced_ternary(q.values, num_trits), q.scale)


def encode_inputs(x: jax.Array, num_trits: int = TRITS_DEFAULT, axis=None) -> TernaryTensor:
    """Ternary input driver: float activations -> 5-trit codes (shared by
    16 rows in the macro; here a pure function)."""
    q = quantize_8b_truncate_5t(x, num_trits, axis=axis)
    return TernaryTensor(to_balanced_ternary(q.values, num_trits), q.scale)


# --- Table 1 signal encodings (used by the macro model & its tests) -----

#   input trit  +1 -> IN1/IN2 = 1/1, 0 -> 1/0, -1 -> 0/0   (INB = complement)
#   weight trit +1 -> Q1Q2 = 00 (LRS), 0 -> 10 (MRS), -1 -> 11 (HRS)

def input_signals(trit: jax.Array) -> tuple[jax.Array, jax.Array]:
    """trit in {-1,0,1} -> (IN1, IN2) per Table 1."""
    in1 = (trit >= 0).astype(jnp.int8)
    in2 = (trit > 0).astype(jnp.int8)
    return in1, in2


def weight_signals(trit: jax.Array) -> tuple[jax.Array, jax.Array]:
    """trit in {-1,0,1} -> (Q1, Q2) per Table 1 (00=+1, 10=0, 11=-1)."""
    q1 = (trit <= 0).astype(jnp.int8)
    q2 = (trit < 0).astype(jnp.int8)
    return q1, q2


def signals_to_weight_trit(q1: jax.Array, q2: jax.Array) -> jax.Array:
    """(Q1,Q2) -> trit; inverse of weight_signals."""
    return (1 - q1.astype(jnp.int8) - q2.astype(jnp.int8)).astype(jnp.int8)
