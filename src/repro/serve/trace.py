"""Arrival traces for the serving engines.

A trace is a list of per-request dicts ``{"arrival_s", "prompt_len",
"max_new", "eos_id", "priority", "deadline_s"}`` — what the drivers
consume: the bucket engine via ``ServeEngine.run_trace``, the
continuous ``Scheduler`` natively, and the front-end load generator
(``repro.frontend.loadgen``) through its open-loop replay.  The last
two fields encode SLO classes for the front-end's admission policies
(``priority``: lower is more urgent, default 0; ``deadline_s``: a
RELATIVE completion budget from the request's arrival, or None for no
deadline) — the library schedulers carry them through untouched, so a
trace replays identically with or without a front-end.

Generators here are deterministic (``random.Random(seed)``) so the
bench and the CLI replay identical workloads across runs;
``load_trace`` reads the same shape from a JSON file for recorded
production streams and VALIDATES it (:class:`TraceError`, not a
KeyError deep inside a replay): records must be objects with the
required keys, arrivals must be non-negative and sorted, lengths and
budgets positive.
"""
from __future__ import annotations

import json
import random
from typing import Optional


class TraceError(ValueError):
    """A trace violated the record contract (malformed file, missing
    key, unsorted or negative arrivals)."""


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0
                     ) -> list[float]:
    """n arrival offsets with exponential inter-arrival gaps (a Poisson
    stream of `rate_per_s` requests/second)."""
    if rate_per_s <= 0:
        return [0.0] * n
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate_per_s)
        out.append(round(t, 6))
    return out


def bursty_arrivals(n: int, bursts: int = 2, gap_s: float = 0.25,
                    spread_s: float = 0.02, seed: int = 0) -> list[float]:
    """n arrivals in `bursts` tight clusters `gap_s` apart — the adverse
    pattern for bucket-at-a-time serving: a whole burst queues behind
    the bucket currently draining."""
    rng = random.Random(seed)
    out = []
    per = -(-n // bursts)
    for i in range(n):
        base = (i // per) * gap_s
        out.append(round(base + rng.uniform(0.0, spread_s), 6))
    return sorted(out)


def make_trace(arrivals: list[float], prompt_lens, max_news,
               eos_id: int = -1, priorities=None,
               deadlines=None) -> list[dict]:
    """Zip arrival offsets with cycled prompt-length / max-new menus
    into the canonical trace records.  ``priorities`` / ``deadlines``
    are optional cycled menus for the SLO fields (defaults: priority 0,
    no deadline); a ``deadlines`` entry of None means that class
    carries no deadline."""
    return [{"arrival_s": a,
             "prompt_len": prompt_lens[i % len(prompt_lens)],
             "max_new": max_news[i % len(max_news)],
             "eos_id": eos_id,
             "priority": (priorities[i % len(priorities)]
                          if priorities else 0),
             "deadline_s": (deadlines[i % len(deadlines)]
                            if deadlines else None)}
            for i, a in enumerate(arrivals)]


REQUIRED_KEYS = ("arrival_s", "prompt_len", "max_new")


def validate_trace(trace, where: str = "trace") -> list[dict]:
    """Check a list of records against the trace contract; returns the
    canonicalized records (defaults filled, numeric types coerced) or
    raises :class:`TraceError` naming the offending record.

    Contract: every record is an object carrying ``arrival_s`` (>= 0,
    non-decreasing across the trace), ``prompt_len`` (>= 1) and
    ``max_new`` (>= 1); ``eos_id`` defaults to -1 (never), ``priority``
    to 0, ``deadline_s`` to None (no deadline; else a positive relative
    budget)."""
    if not isinstance(trace, list):
        raise TraceError(f"{where}: expected a JSON list, got "
                         f"{type(trace).__name__}")
    out = []
    prev_arrival = 0.0
    for i, rec in enumerate(trace):
        at = f"{where}[{i}]"
        if not isinstance(rec, dict):
            raise TraceError(f"{at}: expected an object, got "
                             f"{type(rec).__name__}")
        missing = [k for k in REQUIRED_KEYS if k not in rec]
        if missing:
            raise TraceError(f"{at}: missing required keys {missing}")
        try:
            arrival = float(rec["arrival_s"])
            prompt_len = int(rec["prompt_len"])
            max_new = int(rec["max_new"])
            eos_id = int(rec.get("eos_id", -1))
            priority = int(rec.get("priority", 0))
            deadline: Optional[float] = (
                None if rec.get("deadline_s") is None
                else float(rec["deadline_s"]))
        except (TypeError, ValueError) as e:
            raise TraceError(f"{at}: non-numeric field ({e})") from e
        if arrival < 0:
            raise TraceError(f"{at}: negative arrival_s {arrival}")
        if arrival < prev_arrival:
            raise TraceError(f"{at}: arrival_s {arrival} is before the "
                             f"previous record's {prev_arrival} (traces "
                             f"must be sorted by arrival)")
        if prompt_len < 1:
            raise TraceError(f"{at}: prompt_len must be >= 1, got "
                             f"{prompt_len}")
        if max_new < 1:
            raise TraceError(f"{at}: max_new must be >= 1, got {max_new}")
        if deadline is not None and deadline <= 0:
            raise TraceError(f"{at}: deadline_s must be positive (a "
                             f"relative budget from arrival) or null, "
                             f"got {deadline}")
        prev_arrival = arrival
        out.append({"arrival_s": arrival, "prompt_len": prompt_len,
                    "max_new": max_new, "eos_id": eos_id,
                    "priority": priority, "deadline_s": deadline})
    return out


def load_trace(path: str) -> list[dict]:
    """JSON trace file: a validated list of request records
    (:func:`validate_trace`; optional fields get the generator
    defaults).  Raises :class:`TraceError` on a malformed file instead
    of KeyError-ing mid-replay."""
    with open(path) as f:
        try:
            raw = json.load(f)
        except ValueError as e:
            raise TraceError(f"trace file {path}: unparseable JSON "
                             f"({e})") from e
    return validate_trace(raw, where=f"trace file {path}")


def save_trace(path: str, trace: list[dict]) -> None:
    """Validate and write a trace (round-trips through
    :func:`load_trace`)."""
    canonical = validate_trace(trace)
    with open(path, "w") as f:
        json.dump(canonical, f, indent=1)
        f.write("\n")
