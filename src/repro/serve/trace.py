"""Arrival traces for the serving engines.

A trace is a list of per-request dicts ``{"arrival_s", "prompt_len",
"max_new", "eos_id"}`` — what both drivers consume: the bucket engine
via ``ServeEngine.run_trace`` and the continuous ``Scheduler`` natively.
Generators here are deterministic (``random.Random(seed)``) so the bench
and the CLI replay identical workloads across runs; ``load_trace`` reads
the same shape from a JSON file for recorded production streams.
"""
from __future__ import annotations

import json
import random


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0
                     ) -> list[float]:
    """n arrival offsets with exponential inter-arrival gaps (a Poisson
    stream of `rate_per_s` requests/second)."""
    if rate_per_s <= 0:
        return [0.0] * n
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate_per_s)
        out.append(round(t, 6))
    return out


def bursty_arrivals(n: int, bursts: int = 2, gap_s: float = 0.25,
                    spread_s: float = 0.02, seed: int = 0) -> list[float]:
    """n arrivals in `bursts` tight clusters `gap_s` apart — the adverse
    pattern for bucket-at-a-time serving: a whole burst queues behind
    the bucket currently draining."""
    rng = random.Random(seed)
    out = []
    per = -(-n // bursts)
    for i in range(n):
        base = (i // per) * gap_s
        out.append(round(base + rng.uniform(0.0, spread_s), 6))
    return sorted(out)


def make_trace(arrivals: list[float], prompt_lens, max_news,
               eos_id: int = -1) -> list[dict]:
    """Zip arrival offsets with cycled prompt-length / max-new menus
    into the canonical trace records."""
    return [{"arrival_s": a,
             "prompt_len": prompt_lens[i % len(prompt_lens)],
             "max_new": max_news[i % len(max_news)],
             "eos_id": eos_id}
            for i, a in enumerate(arrivals)]


def load_trace(path: str) -> list[dict]:
    """JSON trace file: a list of request records; missing fields get
    the generator defaults."""
    with open(path) as f:
        raw = json.load(f)
    if not isinstance(raw, list):
        raise ValueError(f"trace file {path}: expected a JSON list")
    out = []
    for i, rec in enumerate(raw):
        if not isinstance(rec, dict):
            raise ValueError(f"trace file {path}[{i}]: expected an object")
        out.append({"arrival_s": float(rec.get("arrival_s", 0.0)),
                    "prompt_len": int(rec.get("prompt_len", 32)),
                    "max_new": int(rec.get("max_new", 16)),
                    "eos_id": int(rec.get("eos_id", -1))})
    return out
