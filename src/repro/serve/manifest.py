"""Audited manifest of the serving engine's jitted entry points.

Every jitted function the serving path can dispatch is named here,
together with the donation and output-arity facts its factory
declares.  The ``jaxpr`` analysis pass (``repro.analysis``,
JX001–JX004) traces each entry against abstract inputs and proves the
declarations hold in the lowered artifact — a donated buffer that XLA
silently copies instead of aliasing (the 2x-KV-pool failure mode), a
widened dtype, or a callback smuggled into the hot path fails `make
analyze`, not a production serve.

An entry's ``build(model)`` returns ``(jitted_fn, args)`` where every
arg leaf is a ShapeDtypeStruct — nothing allocates.  The geometry
constants are deliberately tiny (the contracts are shape-independent);
``donated_argnums`` restates what the factory declares so drift
between this manifest and ``engine.py`` is itself caught (the trace
warns/loses aliasing when the real jit donates differently).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ParamDef, abstract_params, is_def

# tiny trace geometry: batch rows, KV capacity, pool slots, chunk
# steps, positions per KV page
B, CAP, SLOTS, CHUNK, PAGE = 2, 32, 4, 3, 8


class AuditedEntry(NamedTuple):
    """One jitted entry point under dataflow audit."""
    name: str
    build: Callable[[Any], tuple]     # model -> (jitted_fn, args)
    donated_argnums: tuple            # what the factory declares
    out_arity: int                    # declared output tuple length
    note: str = ""


def _params(model):
    return abstract_params(model.param_defs, model.cfg.dtype)


def _cache(model, b: int, cap: int):
    return abstract_params(model.cache_defs(b, cap), model.cfg.dtype)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _lane(dtype=jnp.int32):
    return _sds((SLOTS,), dtype)


def _slot_pool(model):
    """Per-slot batch-1 caches stacked on the leading slot axis — the
    abstract mirror of ``engine.init_slot_pool``."""
    pooled = jax.tree.map(
        lambda d: ParamDef((SLOTS,) + d.shape, ("slot",) + d.axes,
                           d.init, d.dtype),
        model.cache_defs(1, CAP), is_leaf=is_def)
    return abstract_params(pooled, model.cfg.dtype)


def _page_geometry():
    per_slot = -(-CAP // PAGE)
    return per_slot, 1 + SLOTS * per_slot


def _page_pool(model):
    from repro.models.paged_kv import PagedKVCache
    cfg = model.cfg
    _per_slot, num_pages = _page_geometry()
    pshape = (cfg.num_layers, num_pages, PAGE, cfg.num_kv_heads, cfg.hd)
    return PagedKVCache(_sds(pshape, cfg.dtype), _sds(pshape, cfg.dtype))


def _prefill(model):
    from .engine import make_prefill_step
    fn = make_prefill_step(model, CAP)
    return fn, (_params(model), {"tokens": _sds((B, CAP), jnp.int32)})


def _decode_step(model):
    from .engine import make_decode_step
    fn = make_decode_step(model)
    return fn, (_params(model), _sds((B,), jnp.int32),
                _cache(model, B, CAP))


def _decode_loop(model):
    from .engine import make_decode_loop
    fn = make_decode_loop(model, max_new=CHUNK + 1)
    row = _sds((B,), jnp.int32)
    return fn, (_params(model), row, _cache(model, B, CAP), row, row)


def _chunked_loop(model):
    from .engine import make_chunked_decode_loop
    fn = make_chunked_decode_loop(model, CHUNK)
    return fn, (_params(model), _lane(), _slot_pool(model),
                _lane(jnp.bool_), _lane(), _lane(jnp.bool_), _lane(),
                _lane())


def _admit(model):
    from .engine import make_admit_fn
    fn = make_admit_fn()
    scalar = _sds((), jnp.int32)
    return fn, (_slot_pool(model), _lane(), _lane(jnp.bool_), _lane(),
                _lane(jnp.bool_), _lane(), _lane(), scalar,
                _cache(model, 1, CAP), _sds((1,), jnp.int32), scalar,
                scalar)


def _paged_loop(model):
    from .engine import make_paged_decode_loop
    fn = make_paged_decode_loop(model, CHUNK)
    per_slot, _num_pages = _page_geometry()
    table = _sds((SLOTS, per_slot), jnp.int32)
    return fn, (_params(model), _lane(), _page_pool(model), table,
                _lane(), _lane(jnp.bool_), _lane(), _lane(jnp.bool_),
                _lane(), _lane())


def _paged_admit(model):
    from .engine import make_paged_admit_fn
    fn = make_paged_admit_fn()
    scalar = _sds((), jnp.int32)
    return fn, (_lane(), _lane(jnp.bool_), _lane(), _lane(jnp.bool_),
                _lane(), _lane(), _lane(), scalar,
                _sds((1,), jnp.int32), scalar, scalar, scalar)


def entries() -> tuple[AuditedEntry, ...]:
    """The serving engine's audited jitted surface."""
    return (
        AuditedEntry("serve.prefill_step", _prefill, (), 2,
                     "batched prefill; nothing donated (params are "
                     "reused across buckets)"),
        AuditedEntry("serve.decode_step", _decode_step, (2,), 2,
                     "legacy per-token step; the cache is donated and "
                     "must alias (no 2x cache memory)"),
        AuditedEntry("serve.decode_loop", _decode_loop, (), 3,
                     "on-device bucket loop; deliberately NO donation "
                     "— the while_loop carries the cache internally "
                     "and XLA cannot alias into loop state"),
        AuditedEntry("serve.chunked_decode_loop", _chunked_loop, (), 8,
                     "continuous-batching chunk; no donation (same "
                     "while_loop reason)"),
        AuditedEntry("serve.admit", _admit, (0, 1, 2, 3, 4, 5, 6), 7,
                     "admission scatter: pool + every control lane "
                     "donated and aliased in place"),
        AuditedEntry("serve.paged_decode_loop", _paged_loop, (), 9,
                     "paged-KV chunk; no donation (while_loop carries "
                     "the page pool)"),
        AuditedEntry("serve.paged_admit", _paged_admit,
                     (0, 1, 2, 3, 4, 5, 6), 7,
                     "lane-only admission scatter for the paged pool"),
    )
