"""Batched serving engine over the models' prefill/decode interface.

The paper is an inference-accelerator paper, so serving is the primary
end-to-end driver (examples/serve_cim.py): weights can be served from
packed-ternary HBM storage (the paper's density claim) by converting
params with core.cim_linear.ternarize_params — every dense() inside
prefill/decode then routes through the ternary_matmul kernel.

Engine model: requests are queued, bucketed by prompt length (identical
lengths batch exactly — no padding approximations in scoring), prefilled
as a batch, then decoded with per-row EOS/max-token termination.  The
decode batch keeps running while any row is live; finished rows keep
decoding into a scratch token that is discarded (standard fixed-batch
serving).

Two decode drivers:
  on-device (default) — ``make_decode_loop``: a single jitted
      ``lax.while_loop`` carries (token, cache, live-mask, token buffer)
      on device, checks EOS + per-row max-new in-graph, and transfers
      tokens to the host exactly ONCE per bucket.  The legacy driver
      blocked on a ``jax.device_get`` after every decode step,
      serializing host and device.
  legacy step loop (``on_device_loop=False``) — one jitted step per
      token with a host-side sync; kept for tests that pin per-step
      behavior and for debugging.

Both drivers produce identical greedy tokens; ``host_transfers`` counts
device->host syncs so the one-transfer-per-bucket contract is testable.

``make_decode_step`` is the jitted `serve_step` the multi-pod dry-run
lowers for the decode_32k / long_500k cells.

Continuous batching (``Scheduler``): the bucket engine drains one static
batch at a time, so decode slots sit empty while long requests finish
and new arrivals queue behind the whole bucket.  The Scheduler instead
keeps a persistent pool of ``slots`` decode lanes whose on-device state
(KV/carry, live-mask, per-slot max-new/EOS budgets) survives across
scheduling rounds:

  * each slot carries an independent batch-1 decode state stacked on a
    leading slot axis; ``make_chunked_decode_loop`` advances every slot
    with a vmapped single-row decode, so slots at DIFFERENT sequence
    positions coexist in one jitted ``lax.while_loop`` (the batched
    drivers share one scalar cache position and cannot do this);
  * the loop runs up to ``chunk`` decode steps, then yields to the host
    for admission with ONE device->host transfer (the PR 2 invariant,
    now per chunk instead of per bucket);
  * admission prefills newly arrived requests and scatters their state
    into freed slots in-graph (``make_admit_fn``) — compaction is the
    overwrite, no pool reshape, no extra transfer (the prefill token
    stays on device and is emitted by the next chunk's prologue);
  * finished rows are retired host-side from the per-chunk transfer and
    their slots returned to the free list.

Per-request tokens are bitwise identical to both PR 2 drivers (pinned in
tests/test_continuous.py): a slot's computation is exactly the batch-1
decode of that request, and greedy tokens are batch-shape independent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def make_prefill_step(model, capacity: int, cim=None) -> Callable:
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, capacity, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(prefill_step)


def make_decode_step(model, cim=None) -> Callable:
    def decode_step(params, token, state):
        logits, state = model.decode(params, token[:, None], state, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(decode_step, donate_argnums=(2,))


def make_decode_loop(model, max_new: int, cim=None) -> Callable:
    """Jitted whole-bucket decode: ``lax.while_loop`` over decode steps
    with the live-mask, per-row budgets and the token buffer all carried
    on device.

    fn(params, tok0, state, max_new_row, eos_row) ->
        (buf (B, max_new) int32, counts (B,) int32, steps () int32)

    tok0 is the prefill-sampled token (recorded at buf[:, 0], exactly
    like the legacy driver records it before its first decode step);
    counts[b] is how many of row b's buffer slots are real output
    (min(EOS position + 1, max_new_row[b])); steps is the number of
    decode steps executed (for steps_run accounting).  Rows append in
    lockstep while live, so a row's tokens always occupy buf[b, :counts].
    """
    def decode_loop(params, tok, state, max_new_row, eos_row):
        b = tok.shape[0]
        buf = jnp.zeros((b, max_new), jnp.int32).at[:, 0].set(tok)
        counts = jnp.ones((b,), jnp.int32)
        live = (counts < max_new_row) & (tok != eos_row)

        def cond(carry):
            step, tok, state, live, buf, counts = carry
            return jnp.any(live) & (step < max_new - 1)

        def body(carry):
            step, tok, state, live, buf, counts = carry
            logits, state = model.decode(params, tok[:, None], state,
                                         cim=cim)
            tok = greedy_sample(logits)
            buf = buf.at[:, step + 1].set(
                jnp.where(live, tok, buf[:, step + 1]))
            counts = counts + live.astype(jnp.int32)
            live = live & (counts < max_new_row) & (tok != eos_row)
            return step + 1, tok, state, live, buf, counts

        steps, _, _, _, buf, counts = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), tok, state, live, buf,
                         counts))
        return buf, counts, steps

    # no donate_argnums: the while_loop carries the cache internally and
    # XLA cannot alias the donated input into the loop state (it would
    # only warn on every bucket).
    return jax.jit(decode_loop)


# =====================================================================
# continuous batching: slot pool + chunked decode loop
# =====================================================================

def init_slot_pool(model, slots: int, capacity: int):
    """Pooled decode state: one batch-1 cache per slot, stacked on a new
    leading slot axis (logical axis 'slot' in repro.dist — folds over
    the data-parallel mesh axes like 'batch')."""
    one = model.init_cache(1, capacity)
    return jax.tree.map(lambda a: jnp.stack([a] * slots), one)


def make_chunked_decode_loop(model, chunk: int, cim=None, spmd_axes=None):
    """Chunked variant of ``make_decode_loop`` over a slot pool: run up
    to ``chunk`` decode steps in one jitted ``lax.while_loop``, then
    yield to the host for admission.

    fn(params, tok (P,), state_pool, live (P,), made (P,), fresh (P,),
       max_new_row (P,), eos_row (P,)) ->
        (tok, state_pool, live, made,
         buf (P, chunk+1) int32, cnt (P,) int32, steps (), occ ())

    Every slot advances with a vmapped batch-1 ``model.decode`` so slots
    at different positions coexist (each slot state carries its own
    scalar cache position).  `spmd_axes` threads the physical mesh axes
    of the slot dim into ``jax.vmap(spmd_axis_name=...)`` so activation
    constraints inside the model shard the pool over data parallelism
    (see dist.sharding.slot_spmd_axes).

    Semantics per slot are exactly ``make_decode_loop``'s per row:
    freshly admitted slots emit their prefill-sampled token at buf[:, 0]
    (already counted in ``made`` by the admit scatter), live rows append
    in per-row order at buf[row, cnt[row]], ``made`` tracks the per-slot
    budget and EOS flips ``live`` in-graph.  ``steps`` is the number of
    decode steps executed, ``occ`` the live-slot-steps (occupancy
    accounting); dead/empty slots keep decoding into scratch state, like
    finished rows in the fixed-batch drivers.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")

    def decode_one(params, tok, st):
        logits, st = model.decode(params, tok[None, None], st, cim=cim)
        return greedy_sample(logits)[0], st

    vdec = jax.vmap(decode_one, in_axes=(None, 0, 0),
                    spmd_axis_name=spmd_axes)

    def chunk_step(params, tok, state, live, made, fresh, max_new_row,
                   eos_row):
        p = tok.shape[0]
        rows = jnp.arange(p)
        # prologue: emit the admission tokens of freshly prefilled slots
        buf = jnp.zeros((p, chunk + 1), jnp.int32)
        buf = buf.at[:, 0].set(jnp.where(fresh, tok, 0))
        cnt = fresh.astype(jnp.int32)

        def cond(carry):
            step, live = carry[0], carry[3]
            return jnp.any(live) & (step < chunk)

        def body(carry):
            step, tok, state, live, buf, cnt, made, occ = carry
            occ = occ + jnp.sum(live.astype(jnp.int32))
            tok, state = vdec(params, tok, state)
            buf = buf.at[rows, cnt].set(
                jnp.where(live, tok, buf[rows, cnt]))
            cnt = cnt + live.astype(jnp.int32)
            made = made + live.astype(jnp.int32)
            live = live & (made < max_new_row) & (tok != eos_row)
            return step + 1, tok, state, live, buf, cnt, made, occ

        zero = jnp.zeros((), jnp.int32)
        steps, tok, state, live, buf, cnt, made, occ = jax.lax.while_loop(
            cond, body, (zero, tok, state, live, buf, cnt, made, zero))
        return tok, state, live, made, buf, cnt, steps, occ

    # no donation: the while_loop carries the pool state internally, so
    # XLA cannot alias a donated input into it (same as make_decode_loop)
    return jax.jit(chunk_step)


def make_admit_fn() -> Callable:
    """Jitted admission scatter: overwrite slot `slot` of the pool with a
    freshly prefilled batch-1 state and arm its control lanes.  This IS
    the compaction step — a freed slot is reclaimed by overwriting every
    state leaf in place; nothing is transferred to the host (tok0 stays
    on device and the next chunk's prologue emits it)."""
    def admit(state, tok, live, made, fresh, max_new_row, eos_row,
              slot, new_state, tok0, max_new, eos_id):
        state = jax.tree.map(
            lambda pool, new: pool.at[slot].set(new.astype(pool.dtype)),
            state, new_state)
        t0 = tok0[0]
        tok = tok.at[slot].set(t0)
        # same initial-liveness rule as the bucket loop: tok0 is token 1
        made = made.at[slot].set(1)
        live = live.at[slot].set((1 < max_new) & (t0 != eos_id))
        fresh = fresh.at[slot].set(True)
        max_new_row = max_new_row.at[slot].set(max_new)
        eos_row = eos_row.at[slot].set(eos_id)
        return state, tok, live, made, fresh, max_new_row, eos_row
    # donate the pool: admission is a pure scatter, aliased in place
    return jax.jit(admit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                      # (S,) int32
    max_new: int = 16
    eos_id: int = -1                 # -1: never
    arrival_s: float = 0.0           # offset from serve start (traces)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0           # trace runs: completion - arrival


def _batch_inputs(reqs: list, extra_inputs: dict) -> dict:
    toks = jnp.stack([jnp.asarray(r.prompt, jnp.int32) for r in reqs])
    batch = {"tokens": toks}
    for k, fn in extra_inputs.items():
        batch[k] = fn(len(reqs))
    return batch


def latency_stats(reqs: list) -> dict:
    """p50/p99/mean request latency (trace runs: completion - arrival)."""
    lat = sorted(r.latency_s for r in reqs)
    if not lat:
        return {"p50_s": 0.0, "p99_s": 0.0, "mean_s": 0.0}
    pick = lambda q: lat[min(int(q * (len(lat) - 1) + 0.5), len(lat) - 1)]
    return {"p50_s": round(pick(0.50), 4), "p99_s": round(pick(0.99), 4),
            "mean_s": round(sum(lat) / len(lat), 4)}


class _EngineBase:
    """Request bookkeeping shared by the bucket and continuous engines:
    the queue, the completion list, and the host-transfer counter that
    both transfer contracts (one per bucket / one per chunk) are tested
    through."""

    def __init__(self, model, params, capacity: int, cim, extra_inputs):
        self.model = model
        self.params = params
        self.capacity = capacity
        # resolve the plan request ONCE at engine construction: 'auto'
        # backend/interpret pin against the kernel registry here, so an
        # incapable backend fails loudly now instead of mid-decode, and
        # every dense() under this engine hits the plan cache with a
        # fully concrete request
        self.cim = cim.resolve() if cim is not None else None
        self.extra_inputs = extra_inputs or {}
        self._prefill = make_prefill_step(model, capacity, self.cim)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps_run = 0
        self.host_transfers = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _device_get(self, x):
        """All device->host syncs route through here (transfer
        counting)."""
        self.host_transfers += 1
        return jax.device_get(x)

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.out_tokens) for r in self.completed)

    def _arrival_pump(self, clock, sleep, try_admit, busy, serve_round):
        """Shared arrival loop for trace serving — the ONE place whose
        clock semantics both drivers inherit (the serve_continuous
        bench compares their latencies, so they must not drift):
        FIFO-sort the queue by (arrival_s, uid), offer arrived requests
        to `try_admit` (return False to defer — e.g. no free slot),
        sleep to the next arrival when nothing is `busy`, otherwise run
        one `serve_round(elapsed)`.  `serve_round` stamps `latency_s`
        as elapsed() - arrival_s (queue wait included)."""
        pending = sorted(self.queue, key=lambda r: (r.arrival_s, r.uid))
        self.queue = []
        t0 = clock()
        elapsed = lambda: clock() - t0
        while pending or busy():
            now = elapsed()
            while pending and pending[0].arrival_s <= now:
                if not try_admit(pending[0]):
                    break
                pending.pop(0)
            if not busy():
                delay = pending[0].arrival_s - elapsed()
                if delay > 0:
                    sleep(delay)
                continue
            serve_round(elapsed)
        return self.completed


class ServeEngine(_EngineBase):
    def __init__(self, model, params, capacity: int = 512,
                 max_batch: int = 8, cim=None, extra_inputs=None,
                 on_device_loop: bool = True):
        super().__init__(model, params, capacity, cim, extra_inputs)
        self.max_batch = max_batch
        self.on_device_loop = on_device_loop
        self._decode = make_decode_step(model, self.cim)
        self._loops: dict[int, Callable] = {}   # max_new cap -> jitted loop

    def _next_bucket(self) -> list[Request]:
        """Pop up to max_batch queued requests sharing one prompt length
        (single pass: partition the queue instead of list.remove per hit)."""
        if not self.queue:
            return []
        length = len(self.queue[0].prompt)
        batch, rest = [], []
        for r in self.queue:
            if len(batch) < self.max_batch and len(r.prompt) == length:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    def _batch_inputs(self, reqs: list[Request]) -> dict:
        return _batch_inputs(reqs, self.extra_inputs)

    def _decode_loop_for(self, max_new: int) -> Callable:
        # bucket the static loop width up to a power of two: max_new is
        # request-controlled, and compiling (and retaining) one jitted
        # while_loop per distinct value would grow without bound.  The
        # live-mask still exits at the true per-row budgets; only the
        # token buffer is wider.
        cap = 1 << max(max_new - 1, 0).bit_length()
        if cap not in self._loops:
            self._loops[cap] = make_decode_loop(self.model, cap, self.cim)
        return self._loops[cap]

    # ------------------------------------------------------------------
    def _run_bucket_device(self, reqs: list[Request]):
        """Fast lane: prefill, then one on-device decode loop and ONE
        host transfer for the whole bucket."""
        tok, state = self._prefill(self.params, self._batch_inputs(reqs))
        self.steps_run += 1
        max_new = max(r.max_new for r in reqs)
        loop = self._decode_loop_for(max_new)
        max_new_row = jnp.asarray([r.max_new for r in reqs], jnp.int32)
        eos_row = jnp.asarray([r.eos_id for r in reqs], jnp.int32)
        buf, counts, steps = loop(self.params, tok, state, max_new_row,
                                  eos_row)
        buf, counts, steps = self._device_get((buf, counts, steps))
        self.steps_run += int(steps)
        for r, row, cnt in zip(reqs, buf, counts):
            r.out_tokens.extend(int(t) for t in row[: int(cnt)])

    def _run_bucket_legacy(self, reqs: list[Request]):
        """Original step-by-step driver: one host sync per decode step."""
        tok, state = self._prefill(self.params, self._batch_inputs(reqs))
        self.steps_run += 1
        live = [True] * len(reqs)
        for i, (r, t) in enumerate(zip(reqs, self._device_get(tok))):
            r.out_tokens.append(int(t))
            if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                live[i] = False
        max_new = max(r.max_new for r in reqs)
        for _ in range(max_new - 1):
            if not any(live):
                break
            tok, state = self._decode(self.params, tok, state)
            self.steps_run += 1
            for i, (r, t) in enumerate(zip(reqs, self._device_get(tok))):
                if not live[i]:
                    continue
                r.out_tokens.append(int(t))
                if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                    live[i] = False

    def run(self) -> list[Request]:
        """Serve the whole queue; returns completed requests."""
        run_bucket = (self._run_bucket_device if self.on_device_loop
                      else self._run_bucket_legacy)
        while self.queue:
            reqs = self._next_bucket()
            t0 = time.monotonic()
            run_bucket(reqs)
            dt = time.monotonic() - t0
            for r in reqs:
                r.done = True
                r.latency_s = dt
                self.completed.append(r)
        return self.completed

    def run_trace(self, clock=time.monotonic, sleep=time.sleep
                  ) -> list[Request]:
        """Replay arrival-stamped requests through the bucket driver
        (the shared ``_arrival_pump``): a request becomes visible at
        its ``arrival_s``; each round serves ONE bucket of whatever has
        arrived, so new arrivals can only be admitted at bucket
        boundaries — the baseline the continuous Scheduler is
        benchmarked against."""
        run_bucket = (self._run_bucket_device if self.on_device_loop
                      else self._run_bucket_legacy)

        def admit(req):
            self.queue.append(req)
            return True

        def serve_round(elapsed):
            reqs = self._next_bucket()
            run_bucket(reqs)
            done_t = elapsed()
            for r in reqs:
                r.done = True
                r.latency_s = done_t - r.arrival_s
                self.completed.append(r)

        return self._arrival_pump(clock, sleep, admit,
                                  lambda: bool(self.queue), serve_round)


class Scheduler(_EngineBase):
    """Continuous-batching serve scheduler over a persistent slot pool.

    ``slots`` decode lanes live on device across scheduling rounds; each
    round runs one chunked decode loop (up to ``chunk`` steps, ONE
    device->host transfer), retires finished slots host-side from that
    transfer, and prefills newly arrived requests into the freed slots
    before the next round (interleaved prefill/decode).  Requests are
    admitted FIFO by ``arrival_s`` (then uid), so no request starves:
    every free slot is offered to the oldest arrived request first.

    Transfer accounting: ``host_transfers == chunks_run`` — admission
    and compaction stay on device, and a saturated uniform workload runs
    exactly ceil(decode_steps / chunk) chunks (pinned in
    tests/test_continuous.py).

    `spmd_axes` (from dist.sharding.slot_spmd_axes) shards the slot axis
    over the data-parallel mesh axes inside the chunked loop; off-mesh
    (the default) it is None and the pool is a plain leading axis.
    """

    def __init__(self, model, params, capacity: int = 512, slots: int = 8,
                 chunk: int = 8, cim=None, extra_inputs=None,
                 spmd_axes=None, clock=time.monotonic,
                 sleep=time.sleep):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        super().__init__(model, params, capacity, cim, extra_inputs)
        self.slots = slots
        self.chunk = chunk
        self._clock = clock
        self._sleep = sleep
        self._chunk_fn = make_chunked_decode_loop(model, chunk, self.cim,
                                                  spmd_axes)
        self._admit_fn = make_admit_fn()
        # device-side pool: per-slot state + control lanes
        self.pool = init_slot_pool(model, slots, capacity)
        self.tok = jnp.zeros((slots,), jnp.int32)
        self.live = jnp.zeros((slots,), jnp.bool_)
        self.made = jnp.zeros((slots,), jnp.int32)
        self.fresh = jnp.zeros((slots,), jnp.bool_)
        self.max_new_row = jnp.ones((slots,), jnp.int32)
        self.eos_row = jnp.full((slots,), -1, jnp.int32)
        # host-side bookkeeping
        self._slot_req: list[Optional[Request]] = [None] * slots
        self.chunks_run = 0
        self.decode_steps = 0
        self.occupied_slot_steps = 0

    def _admit(self, req: Request, slot: int):
        """Prefill one request and scatter its state into `slot` —
        entirely on device (tok0 is emitted by the next chunk)."""
        tok0, st = self._prefill(self.params,
                                 _batch_inputs([req], self.extra_inputs))
        self.steps_run += 1
        (self.pool, self.tok, self.live, self.made, self.fresh,
         self.max_new_row, self.eos_row) = self._admit_fn(
            self.pool, self.tok, self.live, self.made, self.fresh,
            self.max_new_row, self.eos_row,
            jnp.asarray(slot, jnp.int32), st, tok0,
            jnp.asarray(req.max_new, jnp.int32),
            jnp.asarray(req.eos_id, jnp.int32))
        self._slot_req[slot] = req

    def run(self) -> list[Request]:
        """Serve the whole queue continuously (the shared
        ``_arrival_pump``); returns completed requests."""
        def admit(req):
            # oldest arrived request into the first free slot, FIFO;
            # defer admission (False) when the pool is full
            free = [i for i, r in enumerate(self._slot_req) if r is None]
            if not free:
                return False
            self._admit(req, free[0])
            return True

        def busy():
            return any(r is not None for r in self._slot_req)

        def serve_round(elapsed):
            # one scheduling round: <= chunk decode steps on device,
            # then ONE transfer carrying everything the host needs
            occupied = [i for i, r in enumerate(self._slot_req)
                        if r is not None]
            (self.tok, self.pool, self.live, self.made, buf, cnt, steps,
             occ) = self._chunk_fn(
                self.params, self.tok, self.pool, self.live, self.made,
                self.fresh, self.max_new_row, self.eos_row)
            self.fresh = jnp.zeros((self.slots,), jnp.bool_)
            buf_h, cnt_h, live_h, steps_h, occ_h = self._device_get(
                (buf, cnt, self.live, steps, occ))
            self.chunks_run += 1
            self.decode_steps += int(steps_h)
            self.steps_run += int(steps_h)
            self.occupied_slot_steps += int(occ_h)
            done_t = elapsed()
            for s in occupied:
                req = self._slot_req[s]
                req.out_tokens.extend(
                    int(t) for t in buf_h[s, : int(cnt_h[s])])
                if not bool(live_h[s]):        # retire: slot freed for
                    req.done = True            # the next admission round
                    req.latency_s = done_t - req.arrival_s
                    self.completed.append(req)
                    self._slot_req[s] = None

        return self._arrival_pump(self._clock, self._sleep, admit, busy,
                                  serve_round)

    @property
    def slot_occupancy(self) -> float:
        """Fraction of (slot x decode-step) cells that held a live
        request — the utilization the continuous scheduler exists to
        maximize."""
        total = self.slots * self.decode_steps
        return self.occupied_slot_steps / total if total else 0.0
