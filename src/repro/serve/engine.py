"""Batched serving engine over the models' prefill/decode interface.

The paper is an inference-accelerator paper, so serving is the primary
end-to-end driver (examples/serve_cim.py): weights can be served from
packed-ternary HBM storage (the paper's density claim) by converting
params with core.cim_linear.ternarize_params — every dense() inside
prefill/decode then routes through the ternary_matmul kernel.

Engine model: requests are queued, bucketed by prompt length (identical
lengths batch exactly — no padding approximations in scoring), prefilled
as a batch, then decoded with per-row EOS/max-token termination.  The
decode batch keeps running while any row is live; finished rows keep
decoding into a scratch token that is discarded (standard fixed-batch
serving).

Two decode drivers:
  on-device (default) — ``make_decode_loop``: a single jitted
      ``lax.while_loop`` carries (token, cache, live-mask, token buffer)
      on device, checks EOS + per-row max-new in-graph, and transfers
      tokens to the host exactly ONCE per bucket.  The legacy driver
      blocked on a ``jax.device_get`` after every decode step,
      serializing host and device.
  legacy step loop (``on_device_loop=False``) — one jitted step per
      token with a host-side sync; kept for tests that pin per-step
      behavior and for debugging.

Both drivers produce identical greedy tokens; ``host_transfers`` counts
device->host syncs so the one-transfer-per-bucket contract is testable.

``make_decode_step`` is the jitted `serve_step` the multi-pod dry-run
lowers for the decode_32k / long_500k cells.

Continuous batching (``Scheduler``): the bucket engine drains one static
batch at a time, so decode slots sit empty while long requests finish
and new arrivals queue behind the whole bucket.  The Scheduler instead
keeps a persistent pool of ``slots`` decode lanes whose on-device state
(KV/carry, live-mask, per-slot max-new/EOS budgets) survives across
scheduling rounds:

  * each slot carries an independent batch-1 decode state stacked on a
    leading slot axis; ``make_chunked_decode_loop`` advances every slot
    with a vmapped single-row decode, so slots at DIFFERENT sequence
    positions coexist in one jitted ``lax.while_loop`` (the batched
    drivers share one scalar cache position and cannot do this);
  * the loop runs up to ``chunk`` decode steps, then yields to the host
    for admission with ONE device->host transfer (the PR 2 invariant,
    now per chunk instead of per bucket);
  * admission prefills newly arrived requests and scatters their state
    into freed slots in-graph (``make_admit_fn``) — compaction is the
    overwrite, no pool reshape, no extra transfer (the prefill token
    stays on device and is emitted by the next chunk's prologue);
  * finished rows are retired host-side from the per-chunk transfer and
    their slots returned to the free list.

Per-request tokens are bitwise identical to both PR 2 drivers (pinned in
tests/test_continuous.py): a slot's computation is exactly the batch-1
decode of that request, and greedy tokens are batch-shape independent.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

_LOG = logging.getLogger("repro.serve.engine")


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def make_prefill_step(model, capacity: int, cim=None) -> Callable:
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, capacity, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(prefill_step)


def make_decode_step(model, cim=None) -> Callable:
    def decode_step(params, token, state):
        logits, state = model.decode(params, token[:, None], state, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(decode_step, donate_argnums=(2,))


def make_decode_loop(model, max_new: int, cim=None) -> Callable:
    """Jitted whole-bucket decode: ``lax.while_loop`` over decode steps
    with the live-mask, per-row budgets and the token buffer all carried
    on device.

    fn(params, tok0, state, max_new_row, eos_row) ->
        (buf (B, max_new) int32, counts (B,) int32, steps () int32)

    tok0 is the prefill-sampled token (recorded at buf[:, 0], exactly
    like the legacy driver records it before its first decode step);
    counts[b] is how many of row b's buffer slots are real output
    (min(EOS position + 1, max_new_row[b])); steps is the number of
    decode steps executed (for steps_run accounting).  Rows append in
    lockstep while live, so a row's tokens always occupy buf[b, :counts].
    """
    def decode_loop(params, tok, state, max_new_row, eos_row):
        b = tok.shape[0]
        buf = jnp.zeros((b, max_new), jnp.int32).at[:, 0].set(tok)
        counts = jnp.ones((b,), jnp.int32)
        live = (counts < max_new_row) & (tok != eos_row)

        def cond(carry):
            step, tok, state, live, buf, counts = carry
            return jnp.any(live) & (step < max_new - 1)

        def body(carry):
            step, tok, state, live, buf, counts = carry
            logits, state = model.decode(params, tok[:, None], state,
                                         cim=cim)
            tok = greedy_sample(logits)
            buf = buf.at[:, step + 1].set(
                jnp.where(live, tok, buf[:, step + 1]))
            counts = counts + live.astype(jnp.int32)
            live = live & (counts < max_new_row) & (tok != eos_row)
            return step + 1, tok, state, live, buf, counts

        steps, _, _, _, buf, counts = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), tok, state, live, buf,
                         counts))
        return buf, counts, steps

    # no donate_argnums: the while_loop carries the cache internally and
    # XLA cannot alias the donated input into the loop state (it would
    # only warn on every bucket).
    return jax.jit(decode_loop)


# =====================================================================
# continuous batching: slot pool + chunked decode loop
# =====================================================================

def init_slot_pool(model, slots: int, capacity: int):
    """Pooled decode state: one batch-1 cache per slot, stacked on a new
    leading slot axis (logical axis 'slot' in repro.dist — folds over
    the data-parallel mesh axes like 'batch')."""
    one = model.init_cache(1, capacity)
    return jax.tree.map(lambda a: jnp.stack([a] * slots), one)


def make_chunked_decode_loop(model, chunk: int, cim=None, spmd_axes=None):
    """Chunked variant of ``make_decode_loop`` over a slot pool: run up
    to ``chunk`` decode steps in one jitted ``lax.while_loop``, then
    yield to the host for admission.

    fn(params, tok (P,), state_pool, live (P,), made (P,), fresh (P,),
       max_new_row (P,), eos_row (P,)) ->
        (tok, state_pool, live, made,
         buf (P, chunk+1) int32, cnt (P,) int32, steps (), occ ())

    Every slot advances with a vmapped batch-1 ``model.decode`` so slots
    at different positions coexist (each slot state carries its own
    scalar cache position).  `spmd_axes` threads the physical mesh axes
    of the slot dim into ``jax.vmap(spmd_axis_name=...)`` so activation
    constraints inside the model shard the pool over data parallelism
    (see dist.sharding.slot_spmd_axes).

    Semantics per slot are exactly ``make_decode_loop``'s per row:
    freshly admitted slots emit their prefill-sampled token at buf[:, 0]
    (already counted in ``made`` by the admit scatter), live rows append
    in per-row order at buf[row, cnt[row]], ``made`` tracks the per-slot
    budget and EOS flips ``live`` in-graph.  ``steps`` is the number of
    decode steps executed, ``occ`` the live-slot-steps (occupancy
    accounting); dead/empty slots keep decoding into scratch state, like
    finished rows in the fixed-batch drivers.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")

    def decode_one(params, tok, st):
        logits, st = model.decode(params, tok[None, None], st, cim=cim)
        return greedy_sample(logits)[0], st

    vdec = jax.vmap(decode_one, in_axes=(None, 0, 0),
                    spmd_axis_name=spmd_axes)

    def chunk_step(params, tok, state, live, made, fresh, max_new_row,
                   eos_row):
        p = tok.shape[0]
        rows = jnp.arange(p)
        # prologue: emit the admission tokens of freshly prefilled slots
        buf = jnp.zeros((p, chunk + 1), jnp.int32)
        buf = buf.at[:, 0].set(jnp.where(fresh, tok, 0))
        cnt = fresh.astype(jnp.int32)

        def cond(carry):
            step, live = carry[0], carry[3]
            return jnp.any(live) & (step < chunk)

        def body(carry):
            step, tok, state, live, buf, cnt, made, occ = carry
            occ = occ + jnp.sum(live.astype(jnp.int32))
            tok, state = vdec(params, tok, state)
            buf = buf.at[rows, cnt].set(
                jnp.where(live, tok, buf[rows, cnt]))
            cnt = cnt + live.astype(jnp.int32)
            made = made + live.astype(jnp.int32)
            live = live & (made < max_new_row) & (tok != eos_row)
            return step + 1, tok, state, live, buf, cnt, made, occ

        zero = jnp.zeros((), jnp.int32)
        steps, tok, state, live, buf, cnt, made, occ = jax.lax.while_loop(
            cond, body, (zero, tok, state, live, buf, cnt, made, zero))
        return tok, state, live, made, buf, cnt, steps, occ

    # no donation: the while_loop carries the pool state internally, so
    # XLA cannot alias a donated input into it (same as make_decode_loop)
    return jax.jit(chunk_step)


def make_admit_fn() -> Callable:
    """Jitted admission scatter: overwrite slot `slot` of the pool with a
    freshly prefilled batch-1 state and arm its control lanes.  This IS
    the compaction step — a freed slot is reclaimed by overwriting every
    state leaf in place; nothing is transferred to the host (tok0 stays
    on device and the next chunk's prologue emits it)."""
    def admit(state, tok, live, made, fresh, max_new_row, eos_row,
              slot, new_state, tok0, max_new, eos_id):
        state = jax.tree.map(
            lambda pool, new: pool.at[slot].set(new.astype(pool.dtype)),
            state, new_state)
        t0 = tok0[0]
        tok = tok.at[slot].set(t0)
        # same initial-liveness rule as the bucket loop: tok0 is token 1
        made = made.at[slot].set(1)
        live = live.at[slot].set((1 < max_new) & (t0 != eos_id))
        fresh = fresh.at[slot].set(True)
        max_new_row = max_new_row.at[slot].set(max_new)
        eos_row = eos_row.at[slot].set(eos_id)
        return state, tok, live, made, fresh, max_new_row, eos_row
    # donate the pool: admission is a pure scatter, aliased in place
    return jax.jit(admit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


# =====================================================================
# paged KV: chunked decode over the page pool
# =====================================================================

def make_paged_decode_loop(model, chunk: int, cim=None, spmd_axes=None,
                           attn_plan=None):
    """``make_chunked_decode_loop`` over the paged KV block pool
    (models/paged_kv.py): same chunk semantics, live-mask, budgets and
    ONE device->host transfer per chunk, but the per-slot cache is a
    page-table gather over a SHARED page pool instead of a private
    dense ``(1, capacity)`` buffer.

    fn(params, tok (P,), pool: PagedKVCache, page_table (P, W) int32,
       pos (P,), live, made, fresh, max_new_row, eos_row) ->
        (tok, pool, pos, live, made,
         buf (P, chunk+1) int32, cnt (P,) int32, steps (), occ ())

    Per decode step every slot runs the READ-only ``model.decode_paged``
    (vmapped; the pool itself is broadcast, only the page-table row and
    position map per slot), then ONE scatter appends all live slots'
    new K/V tokens into their current pages
    (``paged_kv.append_tokens``) — dead slots are routed to the null
    page so a freed-and-reused page is never clobbered by a scratch
    decode.  ``page_table`` is chunk-invariant (admission reserves every
    page a request can touch up front), so it rides as an operand, not
    loop state.  Tokens are bitwise identical to the dense pool: the
    gathered view feeds the same read graph, and masked page garbage
    contributes exactly zero (see models/paged_kv.py).

    With ``attn_plan`` (a resolved ``op='attention'`` ExecutionPlan,
    PagedScheduler resolves one per pool geometry) the read path is
    ``model.decode_paged_fused`` instead: one batched call whose
    planned executor consumes the page table in-kernel — the gathered
    dense KV copy the vmapped ``slot_view`` path materializes per slot
    per step never exists.  Token outputs stay bitwise identical at the
    argmax (tests/test_paged.py pins fused == gather == dense).
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    from repro.models import paged_kv

    if attn_plan is not None:
        def vread(params, pool, tok, page_table, pos):
            logits, kts, vts = model.decode_paged_fused(
                params, tok, pool, page_table, pos, cim=cim,
                attn_plan=attn_plan)
            # logits (S, 1, V) -> (S,); kts (L, S, KV, hd) ->
            # (S, L, KV, hd), the append_tokens scatter layout
            return (greedy_sample(logits), jnp.moveaxis(kts, 0, 1),
                    jnp.moveaxis(vts, 0, 1))
    else:
        def read_one(params, pool, tok, pt_row, pos):
            logits, kt, vt = model.decode_paged(params, tok[None, None],
                                                pool, pt_row, pos,
                                                cim=cim)
            return greedy_sample(logits)[0], kt[:, 0, 0], vt[:, 0, 0]

        vread = jax.vmap(read_one, in_axes=(None, None, 0, 0, 0),
                         spmd_axis_name=spmd_axes)

    def chunk_step(params, tok, pool, page_table, pos, live, made, fresh,
                   max_new_row, eos_row):
        p = tok.shape[0]
        rows = jnp.arange(p)
        buf = jnp.zeros((p, chunk + 1), jnp.int32)
        buf = buf.at[:, 0].set(jnp.where(fresh, tok, 0))
        cnt = fresh.astype(jnp.int32)

        def cond(carry):
            step, live = carry[0], carry[4]
            return jnp.any(live) & (step < chunk)

        def body(carry):
            step, tok, pool, pos, live, buf, cnt, made, occ = carry
            occ = occ + jnp.sum(live.astype(jnp.int32))
            tok_new, kts, vts = vread(params, pool, tok, page_table, pos)
            pool = paged_kv.append_tokens(pool, kts, vts, page_table,
                                          pos, live)
            tok = tok_new
            pos = pos + 1
            buf = buf.at[rows, cnt].set(
                jnp.where(live, tok, buf[rows, cnt]))
            cnt = cnt + live.astype(jnp.int32)
            made = made + live.astype(jnp.int32)
            live = live & (made < max_new_row) & (tok != eos_row)
            return step + 1, tok, pool, pos, live, buf, cnt, made, occ

        zero = jnp.zeros((), jnp.int32)
        (steps, tok, pool, pos, live, buf, cnt, made,
         occ) = jax.lax.while_loop(
            cond, body, (zero, tok, pool, pos, live, buf, cnt, made,
                         zero))
        return tok, pool, pos, live, made, buf, cnt, steps, occ

    # no donation: the while_loop carries the pool internally (same as
    # the dense chunked loop)
    return jax.jit(chunk_step)


def make_paged_admit_fn() -> Callable:
    """Lane-only admission scatter for the paged scheduler: the KV state
    lands in the page pool via ``paged_kv.write_prompt_pages``; here we
    arm the control lanes and the slot's write position (= prompt
    length).  Same initial-liveness rule as the dense pools."""
    def admit(tok, live, made, fresh, max_new_row, eos_row, pos,
              slot, tok0, max_new, eos_id, prompt_len):
        t0 = tok0[0]
        tok = tok.at[slot].set(t0)
        made = made.at[slot].set(1)
        live = live.at[slot].set((1 < max_new) & (t0 != eos_id))
        fresh = fresh.at[slot].set(True)
        max_new_row = max_new_row.at[slot].set(max_new)
        eos_row = eos_row.at[slot].set(eos_id)
        pos = pos.at[slot].set(prompt_len)
        return tok, live, made, fresh, max_new_row, eos_row, pos
    return jax.jit(admit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                      # (S,) int32
    max_new: int = 16
    eos_id: int = -1                 # -1: never
    arrival_s: float = 0.0           # offset from serve start (traces)
    priority: int = 0                # SLO class: lower is more urgent
    deadline_s: Optional[float] = None   # RELATIVE completion budget
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0           # trace runs: completion - arrival
    admit_s: float = 0.0             # trace runs: admission - serve start

    @property
    def deadline_met(self) -> bool:
        """True when the request carries no deadline or completed
        within its relative budget (latency_s <= deadline_s)."""
        return self.deadline_s is None or self.latency_s <= self.deadline_s


def _batch_inputs(reqs: list, extra_inputs: dict) -> dict:
    toks = jnp.stack([jnp.asarray(r.prompt, jnp.int32) for r in reqs])
    batch = {"tokens": toks}
    for k, fn in extra_inputs.items():
        batch[k] = fn(len(reqs))
    return batch


def percentile(vals: list, q: float) -> float:
    """Linear interpolation between order statistics (numpy's default
    method).  The previous nearest-rank rounding (``int(q*(n-1)+0.5)``)
    made small-sample p99 degenerate to the sample max — for n <= 50
    every q > ~0.5 + 1/(2(n-1)) picked the last element — which biased
    the continuous-vs-bucket p99 bench gate toward whichever driver's
    single worst request was smaller."""
    return float(np.percentile(vals, 100.0 * q))


def latency_stats(reqs: list) -> dict:
    """p50/p99/p999/mean request latency (trace runs: completion -
    arrival; percentiles interpolate between order statistics,
    ``percentile``) plus the queue-wait vs service-time breakdown:
    ``queue_wait_*`` is arrival -> admission (``admit_s - arrival_s``,
    clamped into [0, latency] — engines that admit instantly report 0)
    and ``service_*`` is admission -> completion (the remainder), so
    an overloaded trace shows WHERE latency went — waiting for a slot
    or decoding."""
    zero = {"p50_s": 0.0, "p99_s": 0.0, "p999_s": 0.0, "mean_s": 0.0,
            "queue_wait_mean_s": 0.0, "queue_wait_p99_s": 0.0,
            "service_mean_s": 0.0, "service_p99_s": 0.0}
    if not reqs:
        return zero
    lat = sorted(r.latency_s for r in reqs)
    waits = sorted(min(max(r.admit_s - r.arrival_s, 0.0), r.latency_s)
                   for r in reqs)
    service = sorted(max(r.latency_s
                         - min(max(r.admit_s - r.arrival_s, 0.0),
                               r.latency_s), 0.0) for r in reqs)
    return {"p50_s": round(percentile(lat, 0.50), 4),
            "p99_s": round(percentile(lat, 0.99), 4),
            "p999_s": round(percentile(lat, 0.999), 4),
            "mean_s": round(sum(lat) / len(lat), 4),
            "queue_wait_mean_s": round(sum(waits) / len(waits), 4),
            "queue_wait_p99_s": round(percentile(waits, 0.99), 4),
            "service_mean_s": round(sum(service) / len(service), 4),
            "service_p99_s": round(percentile(service, 0.99), 4)}


class _EngineBase:
    """Request bookkeeping shared by the bucket and continuous engines:
    the queue, the completion list, and the host-transfer counter that
    both transfer contracts (one per bucket / one per chunk) are tested
    through."""

    def __init__(self, model, params, capacity: int, cim, extra_inputs):
        self.model = model
        self.params = params
        self.capacity = capacity
        # resolve the plan request ONCE at engine construction: 'auto'
        # backend/interpret pin against the kernel registry here, so an
        # incapable backend fails loudly now instead of mid-decode, and
        # every dense() under this engine hits the plan cache with a
        # fully concrete request.  Resolution is PER PHASE (noise-aware
        # routing): a `fidelity='device'` request runs the fault-
        # injected path only for decode — prefill routes back to an
        # exact backend (a prefill upset corrupts the whole KV prefix;
        # a decode upset perturbs one sampled token).  For exact
        # requests both resolutions are identical, so the exact serving
        # path is bitwise-unchanged.
        if cim is not None:
            self.cim = cim.resolve()
            self.cim_prefill = cim.resolve(phase="prefill")
        else:
            self.cim = self.cim_prefill = None
        self.extra_inputs = extra_inputs or {}
        self._prefill = make_prefill_step(model, capacity, self.cim_prefill)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps_run = 0
        self.host_transfers = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _device_get(self, x):
        """All device->host syncs route through here (transfer
        counting)."""
        self.host_transfers += 1
        return jax.device_get(x)

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.out_tokens) for r in self.completed)

    def _arrival_pump(self, clock, sleep, try_admit, busy, serve_round):
        """Shared arrival loop for trace serving — the ONE place whose
        clock semantics both drivers inherit (the serve_continuous
        bench compares their latencies, so they must not drift):
        FIFO-sort the queue by (arrival_s, uid), offer arrived requests
        to `try_admit(req, now)` (return False to defer — e.g. no free
        slot; on success the admitter stamps `admit_s` so latency_stats
        can split queue wait from service time), sleep to the next
        arrival when nothing is `busy`, otherwise run one
        `serve_round(elapsed)`.  `serve_round` stamps `latency_s` as
        elapsed() - arrival_s (queue wait included)."""
        pending = sorted(self.queue, key=lambda r: (r.arrival_s, r.uid))
        self.queue = []
        t0 = clock()
        elapsed = lambda: clock() - t0
        while pending or busy():
            now = elapsed()
            while pending and pending[0].arrival_s <= now:
                if not try_admit(pending[0], now):
                    break
                pending.pop(0)
            if not busy():
                delay = pending[0].arrival_s - elapsed()
                if delay > 0:
                    sleep(delay)
                continue
            serve_round(elapsed)
        return self.completed


class ServeEngine(_EngineBase):
    def __init__(self, model, params, capacity: int = 512,
                 max_batch: int = 8, cim=None, extra_inputs=None,
                 on_device_loop: bool = True):
        super().__init__(model, params, capacity, cim, extra_inputs)
        self.max_batch = max_batch
        self.on_device_loop = on_device_loop
        self._decode = make_decode_step(model, self.cim)
        self._loops: dict[int, Callable] = {}   # max_new cap -> jitted loop

    def _next_bucket(self) -> list[Request]:
        """Pop up to max_batch queued requests sharing one prompt length
        (single pass: partition the queue instead of list.remove per hit)."""
        if not self.queue:
            return []
        length = len(self.queue[0].prompt)
        batch, rest = [], []
        for r in self.queue:
            if len(batch) < self.max_batch and len(r.prompt) == length:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    def _batch_inputs(self, reqs: list[Request]) -> dict:
        return _batch_inputs(reqs, self.extra_inputs)

    def _decode_loop_for(self, max_new: int) -> Callable:
        # bucket the static loop width up to a power of two: max_new is
        # request-controlled, and compiling (and retaining) one jitted
        # while_loop per distinct value would grow without bound.  The
        # live-mask still exits at the true per-row budgets; only the
        # token buffer is wider.
        cap = 1 << max(max_new - 1, 0).bit_length()
        if cap not in self._loops:
            self._loops[cap] = make_decode_loop(self.model, cap, self.cim)
        return self._loops[cap]

    # ------------------------------------------------------------------
    def _run_bucket_device(self, reqs: list[Request]):
        """Fast lane: prefill, then one on-device decode loop and ONE
        host transfer for the whole bucket."""
        tok, state = self._prefill(self.params, self._batch_inputs(reqs))
        self.steps_run += 1
        max_new = max(r.max_new for r in reqs)
        loop = self._decode_loop_for(max_new)
        max_new_row = jnp.asarray([r.max_new for r in reqs], jnp.int32)
        eos_row = jnp.asarray([r.eos_id for r in reqs], jnp.int32)
        buf, counts, steps = loop(self.params, tok, state, max_new_row,
                                  eos_row)
        buf, counts, steps = self._device_get((buf, counts, steps))
        self.steps_run += int(steps)
        for r, row, cnt in zip(reqs, buf, counts):
            r.out_tokens.extend(int(t) for t in row[: int(cnt)])

    def _run_bucket_legacy(self, reqs: list[Request]):
        """Original step-by-step driver: one host sync per decode step."""
        tok, state = self._prefill(self.params, self._batch_inputs(reqs))
        self.steps_run += 1
        live = [True] * len(reqs)
        for i, (r, t) in enumerate(zip(reqs, self._device_get(tok))):
            r.out_tokens.append(int(t))
            if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                live[i] = False
        max_new = max(r.max_new for r in reqs)
        for _ in range(max_new - 1):
            if not any(live):
                break
            tok, state = self._decode(self.params, tok, state)
            self.steps_run += 1
            for i, (r, t) in enumerate(zip(reqs, self._device_get(tok))):
                if not live[i]:
                    continue
                r.out_tokens.append(int(t))
                if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                    live[i] = False

    def run(self) -> list[Request]:
        """Serve the whole queue; returns completed requests."""
        run_bucket = (self._run_bucket_device if self.on_device_loop
                      else self._run_bucket_legacy)
        while self.queue:
            reqs = self._next_bucket()
            t0 = time.monotonic()
            run_bucket(reqs)
            dt = time.monotonic() - t0
            for r in reqs:
                r.done = True
                r.latency_s = dt
                self.completed.append(r)
        return self.completed

    def run_trace(self, clock=time.monotonic, sleep=time.sleep
                  ) -> list[Request]:
        """Replay arrival-stamped requests through the bucket driver
        (the shared ``_arrival_pump``): a request becomes visible at
        its ``arrival_s``; each round serves ONE bucket of whatever has
        arrived, so new arrivals can only be admitted at bucket
        boundaries — the baseline the continuous Scheduler is
        benchmarked against."""
        run_bucket = (self._run_bucket_device if self.on_device_loop
                      else self._run_bucket_legacy)

        def admit(req, now):
            self.queue.append(req)
            return True

        def serve_round(elapsed):
            reqs = self._next_bucket()
            # the bucket driver's real admission is the bucket pop —
            # a request "waits" until its bucket starts serving
            admit_t = elapsed()
            for r in reqs:
                r.admit_s = admit_t
            run_bucket(reqs)
            done_t = elapsed()
            for r in reqs:
                r.done = True
                r.latency_s = done_t - r.arrival_s
                self.completed.append(r)

        return self._arrival_pump(clock, sleep, admit,
                                  lambda: bool(self.queue), serve_round)


class Scheduler(_EngineBase):
    """Continuous-batching serve scheduler over a persistent slot pool.

    ``slots`` decode lanes live on device across scheduling rounds; each
    round runs one chunked decode loop (up to ``chunk`` steps, ONE
    device->host transfer), retires finished slots host-side from that
    transfer, and prefills newly arrived requests into the freed slots
    before the next round (interleaved prefill/decode).  Requests are
    admitted FIFO by ``arrival_s`` (then uid), so no request starves:
    every free slot is offered to the oldest arrived request first.

    Transfer accounting: ``host_transfers == chunks_run`` — admission
    and compaction stay on device, and a saturated uniform workload runs
    exactly ceil(decode_steps / chunk) chunks (pinned in
    tests/test_continuous.py).

    `spmd_axes` (from dist.sharding.slot_spmd_axes) shards the slot axis
    over the data-parallel mesh axes inside the chunked loop; off-mesh
    (the default) it is None and the pool is a plain leading axis.
    """

    def __init__(self, model, params, capacity: int = 512, slots: int = 8,
                 chunk: int = 8, cim=None, extra_inputs=None,
                 spmd_axes=None, clock=time.monotonic,
                 sleep=time.sleep, scrub_every: Optional[int] = 8):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        super().__init__(model, params, capacity, cim, extra_inputs)
        self.slots = slots
        self.chunk = chunk
        self._clock = clock
        self._sleep = sleep
        self._init_fidelity(scrub_every)
        # control lanes shared by the dense and paged pools
        self.tok = jnp.zeros((slots,), jnp.int32)
        self.live = jnp.zeros((slots,), jnp.bool_)
        self.made = jnp.zeros((slots,), jnp.int32)
        self.fresh = jnp.zeros((slots,), jnp.bool_)
        self.max_new_row = jnp.ones((slots,), jnp.int32)
        self.eos_row = jnp.full((slots,), -1, jnp.int32)
        # host-side bookkeeping
        self._slot_req: list[Optional[Request]] = [None] * slots
        self.chunks_run = 0
        self.decode_steps = 0
        self.occupied_slot_steps = 0
        self._init_pool(model, spmd_axes)

    # subclass hook: allocate the device pool + compile the chunk loop
    def _init_pool(self, model, spmd_axes):
        self._chunk_fn = make_chunked_decode_loop(model, self.chunk,
                                                  self.cim, spmd_axes)
        self._admit_fn = make_admit_fn()
        # device-side pool: per-slot dense batch-1 states
        self.pool = init_slot_pool(model, self.slots, self.capacity)

    # ------------------------------------------------- device fidelity
    def _init_fidelity(self, scrub_every: Optional[int]) -> None:
        """Graceful-degradation state for ``fidelity='device'`` serving:
        pristine (TL-ReRAM) weights vs the SERVED weights, which drift
        by the fault model's per-chunk disturb channel and are repaired
        every ``scrub_every`` chunks by a restore-scrub — the paper's
        DC-power-free restore as an online repair, bounding accumulated
        error at the per-scrub restore yield instead of letting it
        compound.  Exact-fidelity engines: all hooks are no-ops and the
        serving path is bitwise-unchanged."""
        self.scrub_every = scrub_every
        self.scrubs_run = 0
        self.adc_clip_lo = 0          # per-chunk ADC clip/saturation
        self.adc_clip_hi = 0          # counters (device fidelity only)
        self._fault_serving = (self.cim is not None
                               and self.cim.mode == "ternary"
                               and self.cim.fidelity == "device")
        if not self._fault_serving:
            return
        from repro import faults
        nt = self.cim.num_trits
        fm = faults.get_fault_model()
        self._fault_model = fm
        self._params_pristine = self.params
        self._drift_key = fm.key_for("serve-drift")
        self._scrub_key = fm.key_for("serve-scrub")
        self._probe_fn = jax.jit(lambda p: faults.adc_probe(
            p, adc_bits=self.cim.adc_bits, num_trits=nt))
        self._disturb_fn = jax.jit(lambda p, k: faults.disturb_packed_params(
            p, fm.drift_rate, k, num_trits=nt))
        # pristine tree passed as an ARGUMENT, not closed over: a jit
        # constant would be constant-folded through the whole restore
        # channel at compile time (minutes per weight leaf on CPU)
        self._scrub_fn = jax.jit(lambda p, k: faults.scrub_packed_params(
            p, fm.restore_yield, k, num_trits=nt))
        # power-on restore: the served weights come up through ONE
        # restore pass from the pristine ReRAM contents
        self.params = self._scrub_fn(
            self._params_pristine,
            jax.random.fold_in(self._scrub_key, self.scrubs_run))

    def _pre_chunk(self) -> None:
        """Between-chunk drift: the disturb channel compounds on the
        served weights (chunk-indexed key — deterministic campaign)."""
        if self._fault_serving and self._fault_model.drift_rate > 0.0:
            self.params = self._disturb_fn(
                self.params,
                jax.random.fold_in(self._drift_key, self.chunks_run))

    def _round_extras(self) -> tuple:
        """Device scalars appended to the round's SINGLE transfer (the
        one-transfer-per-chunk contract must hold in device mode too):
        the ADC clip/saturation probe over the served weights."""
        if self._fault_serving:
            return self._probe_fn(self.params)
        return ()

    def _absorb_round_extras(self, extras: tuple) -> None:
        if extras:
            lo, hi = extras
            self.adc_clip_lo += int(lo)
            self.adc_clip_hi += int(hi)

    def _maybe_scrub(self) -> None:
        """Periodic restore-scrub: every ``scrub_every`` chunks the
        served weights are re-restored from the pristine tree (drift
        discarded; residual error bounded by the restore yield).
        ``scrub_every=None``/0 disables repair — the degradation
        baseline the serve_fidelity bench measures against."""
        if (self._fault_serving and self.scrub_every
                and self.chunks_run % self.scrub_every == 0):
            self.scrubs_run += 1
            self.params = self._scrub_fn(
                self._params_pristine,
                jax.random.fold_in(self._scrub_key, self.scrubs_run))

    def kv_bytes(self) -> int:
        """Device bytes of the pool's KV leaves (codes + scales) — the
        resident-memory quantity the paged pool competes on.  The dense
        pool is always fully resident: every slot holds its full
        ``capacity`` whether or not a request occupies it."""
        keys = ("k", "v", "k_scale", "v_scale")
        return sum(int(v.nbytes) for k, v in self.pool.items()
                   if k in keys and hasattr(v, "nbytes"))

    def kv_bytes_resident(self) -> int:
        return self.kv_bytes()

    def _admit(self, req: Request, slot: int) -> bool:
        """Prefill one request and scatter its state into `slot` —
        entirely on device (tok0 is emitted by the next chunk)."""
        tok0, st = self._prefill(self.params,
                                 _batch_inputs([req], self.extra_inputs))
        self.steps_run += 1
        (self.pool, self.tok, self.live, self.made, self.fresh,
         self.max_new_row, self.eos_row) = self._admit_fn(
            self.pool, self.tok, self.live, self.made, self.fresh,
            self.max_new_row, self.eos_row,
            jnp.asarray(slot, jnp.int32), st, tok0,
            jnp.asarray(req.max_new, jnp.int32),
            jnp.asarray(req.eos_id, jnp.int32))
        self._slot_req[slot] = req
        return True

    def _run_chunk(self):
        """Advance the pool one chunk; returns (buf, cnt, steps, occ)
        device handles (the round's single transfer happens in
        ``_serve_round``)."""
        (self.tok, self.pool, self.live, self.made, buf, cnt, steps,
         occ) = self._chunk_fn(
            self.params, self.tok, self.pool, self.live, self.made,
            self.fresh, self.max_new_row, self.eos_row)
        return buf, cnt, steps, occ

    def _retire_slot(self, slot: int) -> None:
        """Host bookkeeping when a slot's request completes (the paged
        scheduler additionally returns the slot's pages here)."""
        self._slot_req[slot] = None

    def _serve_round(self, elapsed) -> None:
        # one scheduling round: <= chunk decode steps on device, then
        # ONE transfer carrying everything the host needs — fidelity
        # extras (ADC clip counters) ride the same transfer
        occupied = [i for i, r in enumerate(self._slot_req)
                    if r is not None]
        self._pre_chunk()
        buf, cnt, steps, occ = self._run_chunk()
        self.fresh = jnp.zeros((self.slots,), jnp.bool_)
        out = self._device_get(
            (buf, cnt, self.live, steps, occ) + self._round_extras())
        buf_h, cnt_h, live_h, steps_h, occ_h = out[:5]
        self._absorb_round_extras(out[5:])
        self.chunks_run += 1
        self.decode_steps += int(steps_h)
        self.steps_run += int(steps_h)
        self.occupied_slot_steps += int(occ_h)
        done_t = elapsed()
        for s in occupied:
            req = self._slot_req[s]
            req.out_tokens.extend(
                int(t) for t in buf_h[s, : int(cnt_h[s])])
            if not bool(live_h[s]):            # retire: slot freed for
                req.done = True                # the next admission round
                req.latency_s = done_t - req.arrival_s
                self.completed.append(req)
                self._retire_slot(s)
        self._maybe_scrub()

    # ------------------------------------------------- external pump
    # The front-end (repro.frontend.server) drives the scheduler
    # through these three instead of run(): it owns the arrival loop
    # (bounded queue, SLO admission order) but MUST reuse the same
    # admission/round machinery so tokens and the one-transfer-per-
    # chunk contract are identical to a direct run().

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._slot_req) if r is None]

    def is_busy(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def try_admit(self, req: Request, now: float = 0.0) -> bool:
        """Offer one request to the first free slot; False defers it
        (pool full — or, paged, page reservation not coverable yet).
        Stamps ``admit_s`` on success."""
        free = self.free_slots()
        if not free:
            return False
        if not self._admit(req, free[0]):
            return False
        req.admit_s = now
        return True

    def step_round(self, elapsed) -> None:
        """Run ONE scheduling round (<= chunk decode steps, exactly one
        device->host transfer); ``elapsed()`` is the caller's serve
        clock, used to stamp completion latencies."""
        self._serve_round(elapsed)

    def run(self) -> list[Request]:
        """Serve the whole queue continuously (the shared
        ``_arrival_pump``); returns completed requests."""
        # oldest arrived request into the first free slot, FIFO; defer
        # admission (False) when the pool is full — or, paged, when the
        # page pool cannot cover the request yet
        return self._arrival_pump(self._clock, self._sleep,
                                  self.try_admit, self.is_busy,
                                  self._serve_round)

    @property
    def slot_occupancy(self) -> float:
        """Fraction of (slot x decode-step) cells that held a live
        request — the utilization the continuous scheduler exists to
        maximize."""
        total = self.slots * self.decode_steps
        return self.occupied_slot_steps / total if total else 0.0


class PagedScheduler(Scheduler):
    """Continuous-batching scheduler over a paged, prefix-shared KV
    block pool (models/paged_kv.py) instead of per-slot dense caches.

    Identical scheduling semantics and transfer contract to
    :class:`Scheduler` (bitwise-identical tokens — tests/test_paged.py),
    but resident KV scales with the tokens actually held, not
    ``slots x capacity``:

      * the device pool is ``num_pages`` fixed-size pages shared by all
        slots; per-slot page tables map a slot's positions onto pages;
      * admission reserves every page the request can touch up front
        (prompt + worst-case decode budget) — all-or-nothing, so a
        request whose pages don't fit is DEFERRED (FIFO) rather than
        OOM-ing mid-decode — runs the batch-1 prefill, and scatters its
        KV into the fresh pages on device;
      * full prompt pages whose hashed token prefix already resides in
        the pool are mapped SHARED (refcounted, read-only — decode
        never writes a page holding positions below the slot's write
        point) instead of being written again: identical prefixes in a
        trace cost one copy;
      * retiring a slot releases its references; pages return to the
        free list when the last reference drops.

    When a ternary CIM config is supplied, it is re-resolved with
    ``kv_layout='paged'`` so only kernel backends that declare the
    paged capability are planned (src/repro/kernels/README.md).

    ``capacity`` bounds one request's prompt + decode budget (rounded
    up to a page multiple); ``num_pages`` defaults to the dense-pool
    equivalent (``slots * capacity / page_size``) — pass a smaller pool
    to cap resident KV below the dense baseline (admission then defers
    under overload instead of over-allocating).

    ``fused_attn`` selects the decode read path: ``'auto'`` (default)
    resolves a fused ``op='attention'`` plan — the Pallas executor that
    consumes the page table in-kernel, no gathered dense copy — and
    falls back to the ``slot_view`` gather path (logged, never silent)
    when the fused read would not help or hold: no capable backend for
    this pool (int8-KV scale pages, spmd-sharded pools), an
    interpret-mode-only platform (the emulation is slower than the
    gather path's native lowering), or a MoE config (top-k routing
    amplifies the kernel's f32 reassociation into token divergence —
    the bitwise contract needs the gather graph).  ``True`` requires
    the fused path (raises when no backend is capable; overrides the
    interpret/MoE preferences); ``False`` pins the gather path.  Token
    outputs are bitwise identical on every path 'auto' selects.
    """

    def __init__(self, model, params, capacity: int = 512,
                 slots: int = 8, chunk: int = 8, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 share_prefix: bool = True, cim=None, extra_inputs=None,
                 spmd_axes=None, clock=time.monotonic, sleep=time.sleep,
                 scrub_every: Optional[int] = 8, fused_attn="auto"):
        if not model.supports_paged_kv:
            raise ValueError(
                f"{type(model).__name__} (family "
                f"{model.cfg.family!r}) does not support paged KV; "
                f"use the dense-pool Scheduler")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        capacity = -(-capacity // page_size) * page_size
        self.page_size = page_size
        self.pages_per_slot = capacity // page_size
        self.num_pages = (1 + slots * self.pages_per_slot
                          if num_pages is None else num_pages)
        self.share_prefix = share_prefix
        self.fused_attn = fused_attn
        if cim is not None:
            cim = dataclasses.replace(cim, kv_layout="paged")
        super().__init__(model, params, capacity=capacity, slots=slots,
                         chunk=chunk, cim=cim, extra_inputs=extra_inputs,
                         spmd_axes=spmd_axes, clock=clock, sleep=sleep,
                         scrub_every=scrub_every)

    def _resolve_attn_plan(self, model, spmd_axes):
        """Resolve the fused-attention ExecutionPlan for this pool
        geometry through the capability registry (never kwargs), or
        None for the gather path.  The plan shape is the attention
        problem the chunk loop runs every step: all slots' grouped
        queries (``S*KV*rep`` rows) of head dim ``hd`` against the
        per-slot page capacity ``W*page_size``."""
        if not self.fused_attn:
            return None
        from repro.kernels import plan_matmul
        cfg = model.cfg
        why = None
        if spmd_axes is not None:
            # the fused kernel carries no sharding annotations yet; the
            # vmapped gather path keeps its spmd_axis_name contract
            why = "spmd-sharded slot pool"
        elif cfg.kv_cache_dtype == "int8":
            why = "int8 KV pool (scale pages the fused read does not " \
                  "consume)"
        elif cfg.num_experts and self.fused_attn != True:  # noqa: E712
            # MoE top-k expert routing is discontinuous: the fused
            # read's per-page summation order differs from the gather
            # graph by f32 round-off, and a router near-tie amplifies
            # that into different experts — different tokens.  The
            # scheduler's contract is bitwise parity with the dense
            # pool, so 'auto' keeps the identical gather graph here;
            # fused_attn=True overrides (correct, but only
            # round-off-equal).
            why = "MoE routing (top-k amplifies f32 round-off; " \
                  "bitwise token parity needs the gather graph)"
        else:
            shape = (self.slots * cfg.num_heads, cfg.hd,
                     self.pages_per_slot * self.page_size)
            try:
                plan = plan_matmul(shape, "decode", op="attention",
                                   domain="float", kv_layout="paged")
            except ValueError as e:
                plan, why = None, str(e)
            if plan is not None:
                if not plan.interpret or self.fused_attn is True:
                    return plan
                # interpret mode is a correctness emulation, not the
                # kernel: it is slower than the gather path's native
                # XLA lowering, so 'auto' serves wallclock through the
                # gather graph on hosts without a real lowering.  The
                # parity tests and the bench force fused_attn=True.
                why = "interpret-mode emulation on this platform " \
                      "(slower than the gather path's native lowering)"
        if self.fused_attn is True:
            raise ValueError(
                f"fused_attn=True but the fused paged-attention read "
                f"is unavailable: {why}")
        _LOG.info("PagedScheduler: fused paged-attention read "
                  "unavailable (%s); serving through the slot_view "
                  "gather path", why)
        return None

    def _init_pool(self, model, spmd_axes):
        from repro.models import paged_kv
        self._paged_kv = paged_kv
        self.attn_plan = self._resolve_attn_plan(model, spmd_axes)
        self._chunk_fn = make_paged_decode_loop(model, self.chunk,
                                                self.cim, spmd_axes,
                                                attn_plan=self.attn_plan)
        self._admit_fn = make_paged_admit_fn()
        self._write_pages = jax.jit(paged_kv.write_prompt_pages,
                                    donate_argnums=(0,))
        self.pool = paged_kv.init_page_pool(model.cfg, self.num_pages,
                                            self.page_size)
        self.allocator = paged_kv.PageAllocator(self.num_pages,
                                                self.page_size)
        self.pos = jnp.zeros((self.slots,), jnp.int32)
        # host-side page tables: uploaded per chunk (a host->device
        # copy, not a device->host sync — the transfer contract counts
        # the latter); row entries beyond a slot's reservation stay 0
        # (the null page, masked by `pos` in the gather)
        self._page_table = np.zeros((self.slots, self.pages_per_slot),
                                    np.int32)
        # device copy of the table, re-uploaded only after admission or
        # retire edits it (not on every steady-state chunk)
        self._page_table_dev = None
        self._slot_pages: list[list] = [[] for _ in range(self.slots)]

    # ------------------------------------------------------ accounting
    def kv_bytes(self) -> int:
        """Allocated device bytes of the page pool."""
        return sum(int(leaf.nbytes) for leaf in self.pool
                   if leaf is not None)

    def kv_bytes_resident(self) -> int:
        """Bytes of pages currently holding live KV."""
        return self.allocator.pages_in_use * self.pool.page_bytes

    @property
    def kv_bytes_resident_peak(self) -> int:
        return self.allocator.peak_in_use * self.pool.page_bytes

    @property
    def pages_in_use(self) -> int:
        return self.allocator.pages_in_use

    @property
    def prefix_hit_rate(self) -> float:
        return self.allocator.prefix_hit_rate

    # -------------------------------------------------------- admission
    def _admit(self, req: Request, slot: int) -> bool:
        from repro.models.paged_kv import prefix_key
        ps = self.page_size
        s_len = len(req.prompt)
        # positions written: 0..S-1 (prefill) and S..S+max_new-2
        # (decode feeds tok0 first; the last sampled token is never fed)
        last_pos = s_len + req.max_new - 2 if req.max_new >= 2 else \
            s_len - 1
        n_total = last_pos // ps + 1
        if n_total > self.pages_per_slot:
            raise ValueError(
                f"request uid={req.uid} needs {n_total} pages "
                f"(prompt {s_len} + max_new {req.max_new}) but capacity "
                f"{self.capacity} holds {self.pages_per_slot} per slot")
        if n_total > self.num_pages - 1:
            # deferring would busy-spin forever: even an empty pool can
            # never privately satisfy this reservation
            raise ValueError(
                f"request uid={req.uid} needs {n_total} pages but the "
                f"pool holds {self.num_pages - 1} usable pages "
                f"(num_pages={self.num_pages}, page 0 reserved); size "
                f"num_pages to cover one worst-case request")
        prompt_np = np.asarray(req.prompt)
        n_share = s_len // ps if self.share_prefix else 0
        pages: list = [None] * n_total
        keys = [prefix_key(prompt_np, j, ps) for j in range(n_share)]
        shared = []
        for j, key in enumerate(keys):
            pid = self.allocator.lookup_prefix(key)
            if pid is not None:
                pages[j] = pid
                shared.append(j)
        missing = [j for j in range(n_total) if pages[j] is None]
        fresh_ids = self.allocator.alloc(len(missing))
        if fresh_ids is None:
            # pool exhausted: roll back the prefix references (and
            # their stats — the deferred retry will look them up again)
            self.allocator.release([pages[j] for j in shared])
            self.allocator.prefix_hits -= len(shared)
            self.allocator.prefix_lookups -= n_share
            return False
        for j, pid in zip(missing, fresh_ids):
            pages[j] = pid
            if j < n_share:
                self.allocator.register_prefix(keys[j], pid)
        # device: batch-1 prefill, then scatter its KV into the fresh
        # pages (shared hits already hold the identical bits)
        tok0, st = self._prefill(self.params,
                                 _batch_inputs([req], self.extra_inputs))
        self.steps_run += 1
        n_prompt = -(-s_len // ps)
        hit = set(shared)
        write_src = [j for j in range(n_prompt) if j not in hit]
        if write_src:
            self.pool = self._write_pages(
                self.pool, st,
                jnp.asarray([pages[j] for j in write_src], jnp.int32),
                jnp.asarray(write_src, jnp.int32))
        (self.tok, self.live, self.made, self.fresh, self.max_new_row,
         self.eos_row, self.pos) = self._admit_fn(
            self.tok, self.live, self.made, self.fresh,
            self.max_new_row, self.eos_row, self.pos,
            jnp.asarray(slot, jnp.int32), tok0,
            jnp.asarray(req.max_new, jnp.int32),
            jnp.asarray(req.eos_id, jnp.int32),
            jnp.asarray(s_len, jnp.int32))
        row = np.zeros((self.pages_per_slot,), np.int32)
        row[:n_total] = pages
        self._page_table[slot] = row
        self._page_table_dev = None
        self._slot_pages[slot] = pages
        self._slot_req[slot] = req
        return True

    # ------------------------------------------------------ chunk round
    def _run_chunk(self):
        if self._page_table_dev is None:
            self._page_table_dev = jnp.asarray(self._page_table)
        (self.tok, self.pool, self.pos, self.live, self.made, buf, cnt,
         steps, occ) = self._chunk_fn(
            self.params, self.tok, self.pool, self._page_table_dev,
            self.pos, self.live, self.made, self.fresh,
            self.max_new_row, self.eos_row)
        return buf, cnt, steps, occ

    def _retire_slot(self, slot: int) -> None:
        self.allocator.release(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._page_table[slot] = 0
        self._page_table_dev = None
        self._slot_req[slot] = None
