"""Batched serving engine over the models' prefill/decode interface.

The paper is an inference-accelerator paper, so serving is the primary
end-to-end driver (examples/serve_cim.py): weights can be served from
packed-ternary HBM storage (the paper's density claim) by converting
params with core.cim_linear.ternarize_params — every dense() inside
prefill/decode then routes through the ternary_matmul kernel.

Engine model: requests are queued, bucketed by prompt length (identical
lengths batch exactly — no padding approximations in scoring), prefilled
as a batch, then decoded step-by-step with per-row EOS/max-token
termination.  The decode batch keeps running while any row is live;
finished rows keep decoding into a scratch token that is discarded
(standard fixed-batch serving).

``make_decode_step`` is the jitted `serve_step` the multi-pod dry-run
lowers for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def make_prefill_step(model, capacity: int, cim=None) -> Callable:
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, capacity, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(prefill_step)


def make_decode_step(model, cim=None) -> Callable:
    def decode_step(params, token, state):
        logits, state = model.decode(params, token[:, None], state, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(decode_step, donate_argnums=(2,))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                      # (S,) int32
    max_new: int = 16
    eos_id: int = -1                 # -1: never
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class ServeEngine:
    def __init__(self, model, params, capacity: int = 512,
                 max_batch: int = 8, cim=None, extra_inputs=None):
        self.model = model
        self.params = params
        self.capacity = capacity
        self.max_batch = max_batch
        self.cim = cim
        self.extra_inputs = extra_inputs or {}
        self._prefill = make_prefill_step(model, capacity, cim)
        self._decode = make_decode_step(model, cim)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps_run = 0

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _next_bucket(self) -> list[Request]:
        """Pop up to max_batch queued requests sharing one prompt length."""
        if not self.queue:
            return []
        length = len(self.queue[0].prompt)
        batch = [r for r in self.queue if len(r.prompt) == length]
        batch = batch[: self.max_batch]
        for r in batch:
            self.queue.remove(r)
        return batch

    def _batch_inputs(self, reqs: list[Request]) -> dict:
        toks = jnp.stack([jnp.asarray(r.prompt, jnp.int32) for r in reqs])
        batch = {"tokens": toks}
        for k, fn in self.extra_inputs.items():
            batch[k] = fn(len(reqs))
        return batch

    def run(self) -> list[Request]:
        """Serve the whole queue; returns completed requests."""
        while self.queue:
            reqs = self._next_bucket()
            t0 = time.monotonic()
            tok, state = self._prefill(self.params, self._batch_inputs(reqs))
            self.steps_run += 1
            live = [True] * len(reqs)
            for i, (r, t) in enumerate(zip(reqs, jax.device_get(tok))):
                r.out_tokens.append(int(t))
                if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                    live[i] = False
            max_new = max(r.max_new for r in reqs)
            for _ in range(max_new - 1):
                if not any(live):
                    break
                tok, state = self._decode(self.params, tok, state)
                self.steps_run += 1
                for i, (r, t) in enumerate(zip(reqs, jax.device_get(tok))):
                    if not live[i]:
                        continue
                    r.out_tokens.append(int(t))
                    if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                        live[i] = False
            dt = time.monotonic() - t0
            for r in reqs:
                r.done = True
                r.latency_s = dt
                self.completed.append(r)
        return self.completed

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.out_tokens) for r in self.completed)
