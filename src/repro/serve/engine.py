"""Batched serving engine over the models' prefill/decode interface.

The paper is an inference-accelerator paper, so serving is the primary
end-to-end driver (examples/serve_cim.py): weights can be served from
packed-ternary HBM storage (the paper's density claim) by converting
params with core.cim_linear.ternarize_params — every dense() inside
prefill/decode then routes through the ternary_matmul kernel.

Engine model: requests are queued, bucketed by prompt length (identical
lengths batch exactly — no padding approximations in scoring), prefilled
as a batch, then decoded with per-row EOS/max-token termination.  The
decode batch keeps running while any row is live; finished rows keep
decoding into a scratch token that is discarded (standard fixed-batch
serving).

Two decode drivers:
  on-device (default) — ``make_decode_loop``: a single jitted
      ``lax.while_loop`` carries (token, cache, live-mask, token buffer)
      on device, checks EOS + per-row max-new in-graph, and transfers
      tokens to the host exactly ONCE per bucket.  The legacy driver
      blocked on a ``jax.device_get`` after every decode step,
      serializing host and device.
  legacy step loop (``on_device_loop=False``) — one jitted step per
      token with a host-side sync; kept for tests that pin per-step
      behavior and for debugging.

Both drivers produce identical greedy tokens; ``host_transfers`` counts
device->host syncs so the one-transfer-per-bucket contract is testable.

``make_decode_step`` is the jitted `serve_step` the multi-pod dry-run
lowers for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def make_prefill_step(model, capacity: int, cim=None) -> Callable:
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, capacity, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(prefill_step)


def make_decode_step(model, cim=None) -> Callable:
    def decode_step(params, token, state):
        logits, state = model.decode(params, token[:, None], state, cim=cim)
        return greedy_sample(logits), state
    return jax.jit(decode_step, donate_argnums=(2,))


def make_decode_loop(model, max_new: int, cim=None) -> Callable:
    """Jitted whole-bucket decode: ``lax.while_loop`` over decode steps
    with the live-mask, per-row budgets and the token buffer all carried
    on device.

    fn(params, tok0, state, max_new_row, eos_row) ->
        (buf (B, max_new) int32, counts (B,) int32, steps () int32)

    tok0 is the prefill-sampled token (recorded at buf[:, 0], exactly
    like the legacy driver records it before its first decode step);
    counts[b] is how many of row b's buffer slots are real output
    (min(EOS position + 1, max_new_row[b])); steps is the number of
    decode steps executed (for steps_run accounting).  Rows append in
    lockstep while live, so a row's tokens always occupy buf[b, :counts].
    """
    def decode_loop(params, tok, state, max_new_row, eos_row):
        b = tok.shape[0]
        buf = jnp.zeros((b, max_new), jnp.int32).at[:, 0].set(tok)
        counts = jnp.ones((b,), jnp.int32)
        live = (counts < max_new_row) & (tok != eos_row)

        def cond(carry):
            step, tok, state, live, buf, counts = carry
            return jnp.any(live) & (step < max_new - 1)

        def body(carry):
            step, tok, state, live, buf, counts = carry
            logits, state = model.decode(params, tok[:, None], state,
                                         cim=cim)
            tok = greedy_sample(logits)
            buf = buf.at[:, step + 1].set(
                jnp.where(live, tok, buf[:, step + 1]))
            counts = counts + live.astype(jnp.int32)
            live = live & (counts < max_new_row) & (tok != eos_row)
            return step + 1, tok, state, live, buf, counts

        steps, _, _, _, buf, counts = jax.lax.while_loop(
            cond, body, (jnp.zeros((), jnp.int32), tok, state, live, buf,
                         counts))
        return buf, counts, steps

    # no donate_argnums: the while_loop carries the cache internally and
    # XLA cannot alias the donated input into the loop state (it would
    # only warn on every bucket).
    return jax.jit(decode_loop)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                      # (S,) int32
    max_new: int = 16
    eos_id: int = -1                 # -1: never
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class ServeEngine:
    def __init__(self, model, params, capacity: int = 512,
                 max_batch: int = 8, cim=None, extra_inputs=None,
                 on_device_loop: bool = True):
        self.model = model
        self.params = params
        self.capacity = capacity
        self.max_batch = max_batch
        self.cim = cim
        self.extra_inputs = extra_inputs or {}
        self.on_device_loop = on_device_loop
        self._prefill = make_prefill_step(model, capacity, cim)
        self._decode = make_decode_step(model, cim)
        self._loops: dict[int, Callable] = {}   # max_new cap -> jitted loop
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps_run = 0
        self.host_transfers = 0

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _device_get(self, x):
        """All device->host syncs route through here (transfer counting:
        the on-device loop must do exactly one per bucket)."""
        self.host_transfers += 1
        return jax.device_get(x)

    def _next_bucket(self) -> list[Request]:
        """Pop up to max_batch queued requests sharing one prompt length
        (single pass: partition the queue instead of list.remove per hit)."""
        if not self.queue:
            return []
        length = len(self.queue[0].prompt)
        batch, rest = [], []
        for r in self.queue:
            if len(batch) < self.max_batch and len(r.prompt) == length:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    def _batch_inputs(self, reqs: list[Request]) -> dict:
        toks = jnp.stack([jnp.asarray(r.prompt, jnp.int32) for r in reqs])
        batch = {"tokens": toks}
        for k, fn in self.extra_inputs.items():
            batch[k] = fn(len(reqs))
        return batch

    def _decode_loop_for(self, max_new: int) -> Callable:
        # bucket the static loop width up to a power of two: max_new is
        # request-controlled, and compiling (and retaining) one jitted
        # while_loop per distinct value would grow without bound.  The
        # live-mask still exits at the true per-row budgets; only the
        # token buffer is wider.
        cap = 1 << max(max_new - 1, 0).bit_length()
        if cap not in self._loops:
            self._loops[cap] = make_decode_loop(self.model, cap, self.cim)
        return self._loops[cap]

    # ------------------------------------------------------------------
    def _run_bucket_device(self, reqs: list[Request]):
        """Fast lane: prefill, then one on-device decode loop and ONE
        host transfer for the whole bucket."""
        tok, state = self._prefill(self.params, self._batch_inputs(reqs))
        self.steps_run += 1
        max_new = max(r.max_new for r in reqs)
        loop = self._decode_loop_for(max_new)
        max_new_row = jnp.asarray([r.max_new for r in reqs], jnp.int32)
        eos_row = jnp.asarray([r.eos_id for r in reqs], jnp.int32)
        buf, counts, steps = loop(self.params, tok, state, max_new_row,
                                  eos_row)
        buf, counts, steps = self._device_get((buf, counts, steps))
        self.steps_run += int(steps)
        for r, row, cnt in zip(reqs, buf, counts):
            r.out_tokens.extend(int(t) for t in row[: int(cnt)])

    def _run_bucket_legacy(self, reqs: list[Request]):
        """Original step-by-step driver: one host sync per decode step."""
        tok, state = self._prefill(self.params, self._batch_inputs(reqs))
        self.steps_run += 1
        live = [True] * len(reqs)
        for i, (r, t) in enumerate(zip(reqs, self._device_get(tok))):
            r.out_tokens.append(int(t))
            if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                live[i] = False
        max_new = max(r.max_new for r in reqs)
        for _ in range(max_new - 1):
            if not any(live):
                break
            tok, state = self._decode(self.params, tok, state)
            self.steps_run += 1
            for i, (r, t) in enumerate(zip(reqs, self._device_get(tok))):
                if not live[i]:
                    continue
                r.out_tokens.append(int(t))
                if len(r.out_tokens) >= r.max_new or int(t) == r.eos_id:
                    live[i] = False

    def run(self) -> list[Request]:
        """Serve the whole queue; returns completed requests."""
        run_bucket = (self._run_bucket_device if self.on_device_loop
                      else self._run_bucket_legacy)
        while self.queue:
            reqs = self._next_bucket()
            t0 = time.monotonic()
            run_bucket(reqs)
            dt = time.monotonic() - t0
            for r in reqs:
                r.done = True
                r.latency_s = dt
                self.completed.append(r)
        return self.completed

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.out_tokens) for r in self.completed)
