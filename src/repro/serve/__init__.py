from .engine import (ServeEngine, Scheduler, PagedScheduler, Request,
                     make_prefill_step, make_decode_step,
                     make_decode_loop, make_chunked_decode_loop,
                     make_admit_fn, make_paged_decode_loop,
                     make_paged_admit_fn, init_slot_pool, latency_stats,
                     percentile, greedy_sample)  # noqa: F401
from .trace import (poisson_arrivals, bursty_arrivals, make_trace,
                    load_trace, save_trace, validate_trace,
                    TraceError)  # noqa: F401
from .manifest import AuditedEntry  # noqa: F401
from . import manifest  # noqa: F401
