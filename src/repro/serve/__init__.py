from .engine import (ServeEngine, Scheduler, Request, make_prefill_step,
                     make_decode_step, make_decode_loop,
                     make_chunked_decode_loop, make_admit_fn,
                     init_slot_pool, latency_stats,
                     greedy_sample)  # noqa: F401
from .trace import (poisson_arrivals, bursty_arrivals, make_trace,
                    load_trace)  # noqa: F401
