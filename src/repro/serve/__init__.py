from .engine import (ServeEngine, Request, make_prefill_step,
                     make_decode_step, make_decode_loop,
                     greedy_sample)  # noqa: F401
