from .pipeline import (DataConfig, lm_batch, batch_for, class_batch,
                       ClassTaskConfig, entropy_floor)  # noqa: F401
