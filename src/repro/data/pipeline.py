"""Deterministic, shardable synthetic data pipeline.

Design requirements (DESIGN.md §3):
  * stateless — ``batch = f(config, step)``; restart/skip-ahead after a
    failure is exact (the checkpoint only stores the step number);
  * shardable — every host materializes only its row slice of the global
    batch, selected by (host_index, num_hosts); rows are generated
    independently so any partitioning yields identical global data;
  * learnable — tokens follow a fixed affine chain t_{i+1} = (a·t_i + b)
    mod V with random restarts and replacement noise, so next-token loss
    has a known entropy floor and a model that learns the chain drops
    well below log(V).  This stands in for real text offline.

The classification task mirrors the paper's CIFAR-10 experiments
(Table 3 / Fig. 10): class-conditional Gaussians pushed through a fixed
random rotation — linearly separable at high SNR, so quantization /
restore-error damage shows up as clean accuracy deltas.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# chain coefficients: any a coprime with V works; fixed across the run
_A, _B = 31, 17


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    restart_p: float = 0.02        # chain resets (irreducible entropy)
    noise_p: float = 0.02          # token replacement noise
    chain_vocab: int = 0           # 0 -> min(vocab, 4096)

    @property
    def v(self) -> int:
        return self.chain_vocab or min(self.vocab_size, 4096)


def entropy_floor(cfg: DataConfig) -> float:
    """Lower bound on achievable mean NLL (nats/token) for the chain task."""
    v = cfg.v
    p_det = (1 - cfg.restart_p) * (1 - cfg.noise_p)
    p_rand = 1 - p_det
    # deterministic next token w.p. p_det, uniform otherwise
    h = -(p_det + p_rand / v) * math.log(p_det + p_rand / v)
    h -= p_rand * (v - 1) / v * math.log(p_rand / v)
    return h


def _row(key: jax.Array, cfg: DataConfig) -> jax.Array:
    """One (seq_len + 1,) token row — chain with restarts + noise."""
    v = cfg.v
    k0, k1, k2, k3 = jax.random.split(key, 4)
    n = cfg.seq_len + 1
    restart = jax.random.bernoulli(k0, cfg.restart_p, (n,))
    restart_tok = jax.random.randint(k1, (n,), 0, v)
    noise = jax.random.bernoulli(k2, cfg.noise_p, (n,))
    noise_tok = jax.random.randint(k3, (n,), 0, v)

    def step(t, inp):
        rs, rt = inp
        nxt = jnp.where(rs, rt, (_A * t + _B) % v)
        return nxt, nxt

    t0 = restart_tok[0]
    _, chain = jax.lax.scan(step, t0, (restart[1:], restart_tok[1:]))
    chain = jnp.concatenate([t0[None], chain])
    return jnp.where(noise, noise_tok, chain).astype(jnp.int32)


@partial(jax.jit, static_argnames=("cfg", "host_index", "num_hosts"))
def lm_batch(cfg: DataConfig, step: jax.Array, host_index: int = 0,
             num_hosts: int = 1) -> dict:
    """{tokens, labels}: this host's (B_local, S) slice of global step data."""
    b_local = cfg.global_batch // num_hosts
    rows = host_index * b_local + jnp.arange(b_local)
    base = jax.random.key(cfg.seed)
    keys = jax.vmap(
        lambda r: jax.random.fold_in(jax.random.fold_in(base, step), r))(rows)
    toks = jax.vmap(lambda k: _row(k, cfg))(keys)       # (B, S+1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batch_for(model_cfg, cfg: DataConfig, step, host_index: int = 0,
              num_hosts: int = 1) -> dict:
    """Arch-aware batch: adds the stubbed modality frontend inputs
    (precomputed frame/patch embeddings) for audio/vlm families."""
    batch = lm_batch(cfg, step, host_index, num_hosts)
    if model_cfg.family in ("audio", "vlm"):
        b = batch["tokens"].shape[0]
        key = jax.random.fold_in(jax.random.key(cfg.seed + 7), step)
        feats = jax.random.normal(
            key, (b, model_cfg.encoder_seq, model_cfg.d_model), jnp.bfloat16)
        batch["frames" if model_cfg.family == "audio" else "patches"] = feats
    return batch


# ----------------------------------------------------------------------
# classification task (the paper's accuracy substrate, CIFAR-10 stand-in)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassTaskConfig:
    num_classes: int = 10
    dim: int = 128
    snr: float = 2.0               # class-mean norm / noise std
    seed: int = 0


def class_means(cfg: ClassTaskConfig) -> jax.Array:
    k = jax.random.key(cfg.seed + 101)
    mu = jax.random.normal(k, (cfg.num_classes, cfg.dim))
    return cfg.snr * mu / jnp.linalg.norm(mu, axis=1, keepdims=True)


@partial(jax.jit, static_argnames=("cfg", "batch"))
def class_batch(cfg: ClassTaskConfig, step: jax.Array, batch: int = 256) -> dict:
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    ky, kx = jax.random.split(key)
    y = jax.random.randint(ky, (batch,), 0, cfg.num_classes)
    x = class_means(cfg)[y] + jax.random.normal(kx, (batch, cfg.dim))
    return {"x": x.astype(jnp.float32), "y": y}
