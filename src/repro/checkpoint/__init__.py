from .checkpoint import (save, restore, latest_step, available_steps,
                         gc_old_steps, CheckpointManager)  # noqa: F401
