"""Sharded, atomic, resumable checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json     — tree structure, shapes, dtypes, step,
                                 completion marker (written LAST)
            arr_<i>.npy       — one file per leaf (bf16 stored as uint16
                                 with the true dtype recorded in the
                                 manifest)

Atomicity: everything is written into ``step_<N>.tmp`` and the directory
is os.rename()d only after the manifest is fsync'd — a reader never sees
a partial checkpoint, and a writer killed mid-save leaves only a .tmp
that the next save cleans up.  This is the property the fault-tolerance
runner leans on (tests/test_train_ft.py kills saves mid-flight).

Elastic re-shard: ``restore(..., shardings=tree)`` device_puts each leaf
with the *target* sharding, so a checkpoint written on one mesh reloads
onto any other mesh (the arrays are stored unsharded per-leaf; at
datacenter scale each host would store its addressable shards and
re-stitch — the manifest format already records per-leaf shapes to
support that extension).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _to_numpy(x) -> tuple[np.ndarray, str]:
    x = np.asarray(jax.device_get(x))
    dtype = str(x.dtype)
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, dtype


def _from_numpy(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return a.view(jnp.bfloat16)
    return a.astype(np.dtype(dtype), copy=False)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         _fail_after_files: Optional[int] = None) -> str:
    """Write an atomic checkpoint; returns the final directory.

    `_fail_after_files` is a test hook: raise mid-write after that many
    leaf files to simulate a crash during save.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    entries = []
    for i, (path, leaf) in enumerate(leaves):
        if _fail_after_files is not None and i >= _fail_after_files:
            raise RuntimeError("simulated crash during checkpoint save")
        arr, dtype = _to_numpy(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
        entries.append({"path": _path_str(path), "file": fname,
                        "shape": list(arr.shape), "dtype": dtype})
    manifest = {"step": step, "num_leaves": len(entries), "leaves": entries,
                "extra": extra or {}, "complete": True}
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(ckpt_dir: str) -> list[int]:
    """Steps with a COMPLETE manifest (ignores .tmp wreckage)."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in sorted(os.listdir(ckpt_dir)):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        mpath = os.path.join(ckpt_dir, name, _MANIFEST)
        if not os.path.exists(mpath):
            continue
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("complete"):
                out.append(int(m["step"]))
        except (json.JSONDecodeError, KeyError, ValueError):
            continue
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            target: Any = None, shardings: Any = None) -> tuple[Any, dict]:
    """Load (tree, extra).  With `target` (a pytree of arrays or
    ShapeDtypeStructs) the stored leaves are mapped back into that
    structure; with `shardings` each leaf is device_put with the target
    sharding (elastic re-shard onto a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    arrays = []
    for e in manifest["leaves"]:
        a = np.load(os.path.join(d, e["file"]), allow_pickle=False)
        arrays.append(_from_numpy(a, e["dtype"]))

    if target is not None:
        treedef = jax.tree_util.tree_structure(target)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
    else:
        # rebuild a nested dict from path strings
        tree = {}
        for e, a in zip(manifest["leaves"], arrays):
            node = tree
            parts = e["path"].split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = a
    if shardings is not None:
        flat_s = jax.tree_util.tree_structure(shardings)
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return tree, manifest.get("extra", {})


def gc_old_steps(ckpt_dir: str, keep: int = 3) -> list[int]:
    """Delete all but the newest `keep` complete checkpoints (+ any .tmp)."""
    removed = []
    if not os.path.isdir(ckpt_dir):
        return removed
    for name in os.listdir(ckpt_dir):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
        removed.append(s)
    return removed


class CheckpointManager:
    """Periodic save + keep-last-N + restore-or-init, in one object."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep

    def maybe_save(self, step: int, tree: Any, extra: Optional[dict] = None,
                   force: bool = False) -> Optional[str]:
        if not force and (self.interval <= 0 or step % self.interval):
            return None
        path = save(self.dir, step, tree, extra)
        gc_old_steps(self.dir, self.keep)
        return path

    def restore_or_none(self, target: Any = None, shardings: Any = None):
        if latest_step(self.dir) is None:
            return None
        return restore(self.dir, target=target, shardings=shardings)
