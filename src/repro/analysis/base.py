"""Shared types for the static-analysis passes (`repro.analysis`).

Every pass returns a flat list of :class:`Finding` records; the CLI
(`python -m repro.analysis`) prints them and exits nonzero when any
pass found anything.  A finding identifies the pass that produced it,
a stable rule/check id (documented in src/repro/analysis/README.md),
and where it points (a ``file:line`` or a lattice-cell string).
"""
from __future__ import annotations

import dataclasses
import os

# src/repro/analysis/ -> repo root (the PYTHONPATH=src layout every
# entry point in this repo uses)
REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation surfaced by an analysis pass."""
    passname: str          # capability | blockmap | sanitize | lint
    rule: str              # stable check id (README.md rule catalog)
    where: str             # file:line, lattice cell, or invariant site
    message: str

    def __str__(self) -> str:
        return f"[{self.passname}:{self.rule}] {self.where}: {self.message}"


def rel(path: str) -> str:
    """Repo-relative form of a path (stable finding locations)."""
    try:
        return os.path.relpath(path, REPO_ROOT)
    except ValueError:               # different drive (windows)
        return path
