"""CLI for the analysis passes: ``python -m repro.analysis``.

Exit status is the number of findings (capped at 125), so any
violation fails CI.  ``--inject-*`` / ``--pin-blocks`` seed violations
on purpose — they exist so tests (and curious humans) can watch each
pass actually catch its failure category.
"""
from __future__ import annotations

import argparse
import sys

from . import (PASSES, autotune_table, blockmap, capability, frontend,
               lint, sanitizer)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker + sanitizer "
                    "(src/repro/analysis/README.md)")
    p.add_argument("--passes", default=None,
                   help="comma-separated subset to run (capability,"
                        "blockmap,autotune,lint,sanitize,frontend); "
                        "default all")
    p.add_argument("--list", action="store_true",
                   help="list passes and exit")
    p.add_argument("--emit-matrix", action="store_true",
                   help="print the registry-derived capability matrix "
                        "markdown (paste into src/repro/kernels/"
                        "README.md) and exit")
    p.add_argument("--readme", default=None, metavar="PATH",
                   help="capability pass: check this README instead of "
                        "src/repro/kernels/README.md")
    p.add_argument("--autotune-table", default=None, metavar="PATH",
                   help="autotune pass: check this table instead of "
                        "BENCH_autotune.json (violation injection)")
    p.add_argument("--pin-blocks", default=None, metavar="BM,BN,BK",
                   help="blockmap pass: force these block shapes over "
                        "the sweep instead of select_block_shapes "
                        "(violation injection)")
    p.add_argument("--inject-sanitize", default=None,
                   choices=("transfer", "retrace"),
                   help="sanitize pass: seed an extra device->host "
                        "transfer or a post-warmup retrace "
                        "(violation injection)")
    p.add_argument("--inject-frontend", default=None,
                   choices=("transfer", "drop", "order"),
                   help="frontend pass: seed an extra streaming "
                        "transfer, an accounting drop, or a "
                        "non-deterministic admission order "
                        "(violation injection)")
    p.add_argument("--lint-paths", default=None, metavar="P1,P2",
                   help="lint pass: scan these paths instead of the "
                        "rules.toml [lint] paths")
    p.add_argument("--rules", default=None, metavar="PATH",
                   help="lint pass: alternate rules.toml")
    args = p.parse_args(argv)

    if args.list:
        for name, _ in PASSES:
            print(name)
        return 0
    if args.emit_matrix:
        print(capability.render_capability_matrix(), end="")
        return 0

    selected = ([s.strip() for s in args.passes.split(",") if s.strip()]
                if args.passes else [name for name, _ in PASSES])
    known = {name for name, _ in PASSES}
    unknown = [s for s in selected if s not in known]
    if unknown:
        p.error(f"unknown pass(es) {unknown}; choose from {sorted(known)}")

    pin_blocks = None
    if args.pin_blocks:
        try:
            pin_blocks = tuple(int(v) for v in args.pin_blocks.split(","))
            if len(pin_blocks) != 3:
                raise ValueError
        except ValueError:
            p.error("--pin-blocks wants three ints: BM,BN,BK")

    runners = {
        "capability": lambda: capability.run(readme_path=args.readme),
        "blockmap": lambda: blockmap.run(pin_blocks=pin_blocks),
        "autotune": lambda: autotune_table.run(
            table_path=args.autotune_table),
        "lint": lambda: lint.run(
            paths=([s.strip() for s in args.lint_paths.split(",")]
                   if args.lint_paths else None),
            config=args.rules),
        "sanitize": lambda: sanitizer.run(
            inject=(args.inject_sanitize,) if args.inject_sanitize
            else ()),
        "frontend": lambda: frontend.run(
            inject=(args.inject_frontend,) if args.inject_frontend
            else ()),
    }

    findings = []
    for name, _ in PASSES:          # canonical order, subset-filtered
        if name not in selected:
            continue
        got = runners[name]()
        print(f"[{name}] {len(got)} finding(s)")
        findings.extend(got)
    for f in findings:
        print(f" {f}")
    if findings:
        print(f"FAIL: {len(findings)} finding(s)")
    else:
        print("OK: all passes clean")
    return min(len(findings), 125)


if __name__ == "__main__":
    sys.exit(main())
