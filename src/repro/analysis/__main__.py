"""CLI for the analysis passes: ``python -m repro.analysis``.

Exit status is the number of findings (capped at 125), so any
violation fails CI.  ``--inject-*`` / ``--pin-blocks`` seed violations
on purpose — they exist so tests (and curious humans) can watch each
pass actually catch its failure category.  ``--format json`` /
``--out PATH`` emit a machine-readable findings document (pass, rule,
where, message, per-pass wall time) for the CI artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from . import (LAST_TIMINGS, PASSES, abscache, autotune_table,
               blockmap, capability, frontend, jaxpr_audit, lint,
               sanitizer, shardspec)


def _report_doc(per_pass: list, findings: list) -> dict:
    return {
        "passes": [{"name": name, "findings": n,
                    "seconds": round(dt, 3)}
                   for name, n, dt in per_pass],
        "findings": [{"pass": f.passname, "rule": f.rule,
                      "where": f.where, "message": f.message}
                     for f in findings],
        "ok": not findings,
        "abscache": abscache.stats(),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker + sanitizer "
                    "(src/repro/analysis/README.md)")
    p.add_argument("--passes", default=None,
                   help="comma-separated subset to run (capability,"
                        "blockmap,autotune,lint,shard,jaxpr,sanitize,"
                        "frontend); default all")
    p.add_argument("--list", action="store_true",
                   help="list passes (with last-run wall times, when "
                        "run in this process) and exit")
    p.add_argument("--format", default="text", choices=("text", "json"),
                   dest="fmt",
                   help="stdout format: human text or the findings "
                        "JSON document")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="also write the findings JSON document here "
                        "(the CI artifact)")
    p.add_argument("--emit-matrix", action="store_true",
                   help="print the registry-derived capability matrix "
                        "markdown (paste into src/repro/kernels/"
                        "README.md) and exit")
    p.add_argument("--emit-axes", action="store_true",
                   help="print the rules-derived logical-axis table "
                        "markdown (paste into src/repro/dist/"
                        "README.md) and exit")
    p.add_argument("--readme", default=None, metavar="PATH",
                   help="capability pass: check this README instead of "
                        "src/repro/kernels/README.md")
    p.add_argument("--dist-readme", default=None, metavar="PATH",
                   help="shard pass: check this README instead of "
                        "src/repro/dist/README.md")
    p.add_argument("--autotune-table", default=None, metavar="PATH",
                   help="autotune pass: check this table instead of "
                        "BENCH_autotune.json (violation injection)")
    p.add_argument("--pin-blocks", default=None, metavar="BM,BN,BK",
                   help="blockmap pass: force these block shapes over "
                        "the sweep instead of select_block_shapes "
                        "(violation injection)")
    p.add_argument("--inject-shard", default=None,
                   choices=("resolve", "spec", "replicate", "mirror",
                            "axis", "drift"),
                   help="shard pass: seed one sharding-contract "
                        "violation (violation injection)")
    p.add_argument("--inject-jaxpr", default=None,
                   choices=("donation", "widen", "callback",
                            "transfer"),
                   help="jaxpr pass: seed one dataflow-audit "
                        "violation (violation injection)")
    p.add_argument("--inject-sanitize", default=None,
                   choices=("transfer", "retrace"),
                   help="sanitize pass: seed an extra device->host "
                        "transfer or a post-warmup retrace "
                        "(violation injection)")
    p.add_argument("--inject-frontend", default=None,
                   choices=("transfer", "drop", "order"),
                   help="frontend pass: seed an extra streaming "
                        "transfer, an accounting drop, or a "
                        "non-deterministic admission order "
                        "(violation injection)")
    p.add_argument("--lint-paths", default=None, metavar="P1,P2",
                   help="lint pass: scan these paths instead of the "
                        "rules.toml [lint] paths")
    p.add_argument("--rules", default=None, metavar="PATH",
                   help="lint pass: alternate rules.toml")
    args = p.parse_args(argv)

    if args.list:
        for name, _ in PASSES:
            dt = LAST_TIMINGS.get(name)
            stamp = f"{dt:8.2f}s" if dt is not None else "       -"
            print(f"{name:12s}{stamp}")
        return 0
    if args.emit_matrix:
        print(capability.render_capability_matrix(), end="")
        return 0
    if args.emit_axes:
        print(shardspec.render_axis_table(), end="")
        return 0

    selected = ([s.strip() for s in args.passes.split(",") if s.strip()]
                if args.passes else [name for name, _ in PASSES])
    known = {name for name, _ in PASSES}
    unknown = [s for s in selected if s not in known]
    if unknown:
        p.error(f"unknown pass(es) {unknown}; choose from {sorted(known)}")

    pin_blocks = None
    if args.pin_blocks:
        try:
            pin_blocks = tuple(int(v) for v in args.pin_blocks.split(","))
            if len(pin_blocks) != 3:
                raise ValueError
        except ValueError:
            p.error("--pin-blocks wants three ints: BM,BN,BK")

    runners = {
        "capability": lambda: capability.run(readme_path=args.readme),
        "blockmap": lambda: blockmap.run(pin_blocks=pin_blocks),
        "autotune": lambda: autotune_table.run(
            table_path=args.autotune_table),
        "lint": lambda: lint.run(
            paths=([s.strip() for s in args.lint_paths.split(",")]
                   if args.lint_paths else None),
            config=args.rules),
        "shard": lambda: shardspec.run(
            inject=args.inject_shard, readme_path=args.dist_readme),
        "jaxpr": lambda: jaxpr_audit.run(inject=args.inject_jaxpr),
        "sanitize": lambda: sanitizer.run(
            inject=(args.inject_sanitize,) if args.inject_sanitize
            else ()),
        "frontend": lambda: frontend.run(
            inject=(args.inject_frontend,) if args.inject_frontend
            else ()),
    }

    text = args.fmt == "text"
    findings = []
    per_pass = []
    for name, _ in PASSES:          # canonical order, subset-filtered
        if name not in selected:
            continue
        t0 = time.monotonic()
        got = runners[name]()
        dt = time.monotonic() - t0
        LAST_TIMINGS[name] = dt
        per_pass.append((name, len(got), dt))
        if text:
            print(f"[{name}] {len(got)} finding(s) ({dt:.2f}s)")
        findings.extend(got)
    doc = _report_doc(per_pass, findings)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
    if text:
        for f in findings:
            print(f" {f}")
        if findings:
            print(f"FAIL: {len(findings)} finding(s)")
        else:
            print("OK: all passes clean")
    else:
        json.dump(doc, sys.stdout, indent=1)
        print()
    return min(len(findings), 125)


if __name__ == "__main__":
    sys.exit(main())
