"""`shard` — the sharding-contract prover (SD001–SD006).

The paper's premise is that weight placement is a provable static
property of the array; the systems analogue here is ``repro.dist``:
every parameter, cache, slot pool and page pool carries logical axis
names that resolve to physical mesh axes through one rules engine.
This pass proves the placement contracts over the LIVE lattice —
every ``(rules variant x mesh x model config)`` cell enumerated from
``dist.variants`` — entirely abstractly: ``Rules`` and ``MeshSpec``
carry no devices, and nothing is allocated.

| rule  | contract |
|-------|----------|
| SD001 | every axes tuple on every sharding surface resolves through ``logical_to_spec`` without raising (unknown axes, rank mismatches) |
| SD002 | each resolved PartitionSpec independently re-verifies: physical axes exist, no axis reused across dims, divisibility holds, quantum units never split, zero-size dims never shard |
| SD003 | no parameter above ``REPLICATION_FLOOR`` elements is fully replicated on a multi-chip mesh under an fsdp variant (pure-dp variants are exempt by design — see ``dist.variants.REPLICATING_VARIANTS``) |
| SD004 | ``slot_spmd_axes``/``page_spmd_axes`` agree with ``logical_to_spec`` on the pool axis for every variant, mesh and pool size |
| SD005 | every logical axis named in a ``ParamDef``/``constrain_act``/``constrain``/``named_sharding``/``logical_to_spec``/``_sds`` call anywhere in ``src/`` is known to the rules engine (AST sweep — catches typo'd axes that today silently replicate) |
| SD006 | the logical-axis table in ``src/repro/dist/README.md`` matches the live ``train_rules``/``serve_rules`` maps (CAP006-style drift check; regenerate with ``--emit-axes``) |

Violation injection (tests / ``--inject-shard``): ``resolve``,
``spec``, ``replicate``, ``mirror``, ``axis``, ``drift`` — each trips
exactly its rule against the otherwise-clean repo.
"""
from __future__ import annotations

import ast
import math
import os
from typing import Iterator, Optional

from jax.sharding import PartitionSpec as P

from .base import REPO_ROOT, Finding, rel
from . import abscache

PASS = "shard"

# Fully-replicated parameters at or above this many elements on a
# multi-chip mesh are findings (SD003).  The floor sits above the
# largest deliberate replication in the repo (xlstm's per-head
# recurrent weight `wr`, ~3.5M elements, whose output reshapes across
# any axis we could shard) and below every real weight matrix.
REPLICATION_FLOOR = 1 << 22

# Synthetic cell the non-parameter surfaces are sized with.  Sizes are
# arbitrary (resolution must hold for ANY size by the folding policy);
# these are chosen DP-divisible so SD003-adjacent replication noise
# does not mask findings.
_BATCH, _SEQ, _SLOTS, _PAGE_SIZE = 32, 256, 32, 16

# Call targets of the SD005 axis sweep: callable terminal name -> the
# positional index / keyword of its logical-axes argument.
_AXIS_CALL_SITES = {
    "ParamDef": (1, "axes"),
    "constrain_act": (1, "axes"),
    "constrain": (1, "axes"),
    "named_sharding": (0, "axes"),
    "logical_to_spec": (0, "axes"),
    "_sds": (2, "axes"),
}


def _mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.shape)


def _known_axes() -> frozenset:
    from repro.dist import sharding as shd
    return frozenset(shd.train_rules().axis_map) | \
        frozenset(shd.serve_rules().axis_map)


# ---------------------------------------------------------------------
# surfaces: every (axes, shape) pair a config puts through the engine
# ---------------------------------------------------------------------

def _surfaces(arch: str) -> Iterator[tuple[str, tuple, tuple]]:
    """Yield (label, axes, shape) for every sharding surface of one
    architecture: parameters, decode caches, the pooled slot state,
    the paged block pool, and the activation-constraint layouts."""
    cfg = abscache.config(arch)
    for key, d in abscache.param_leaves(arch):
        yield f"params{key}", d.axes, d.shape
    for key, d in abscache.cache_leaves(arch, _BATCH, _SEQ):
        yield f"cache{key}", d.axes, d.shape
    # continuous-batching slot pool: batch-1 caches stacked on 'slot'
    # (the serve.init_slot_pool / launch.slot_pool_specs layout)
    for key, d in abscache.cache_leaves(arch, 1, _SEQ):
        yield (f"slot_pool{key}", ("slot",) + d.axes,
               (_SLOTS,) + d.shape)
    yield "slot_pool.lanes", ("slot",), (_SLOTS,)
    # paged-KV block pool (launch.paged_pool_specs layout)
    pages = 1 + _SLOTS * (-(-_SEQ // _PAGE_SIZE))
    pshape = (cfg.num_layers, pages, _PAGE_SIZE, cfg.num_kv_heads,
              cfg.hd)
    paxes = ("layers", "page", "none", "kv", "none")
    yield "page_pool.kv_pages", paxes, pshape
    yield "page_pool.scale_pages", paxes[:-1], pshape[:-1]
    yield ("page_pool.table", ("slot", "none"),
           (_SLOTS, -(-_SEQ // _PAGE_SIZE)))
    # activation constraint layouts (constrain_act default + the batch
    # spec layouts train/prefill/decode anchor)
    d = cfg.d_model
    yield "act.residual", ("batch", "seq", "none"), (_BATCH, _SEQ, d)
    yield "act.tokens", ("batch", "seq"), (_BATCH, _SEQ)
    yield ("act.frontend", ("batch", "seq", "act_embed"),
           (_BATCH, _SEQ, d))
    yield "act.decode_token", ("batch", "none"), (_BATCH, 1)
    yield "act.row_lane", ("batch",), (_BATCH,)


# ---------------------------------------------------------------------
# SD002: independent spec re-verification
# ---------------------------------------------------------------------

def check_spec(axes: tuple, shape: tuple, spec, sizes: dict,
               quantum: Optional[dict]) -> list[str]:
    """Re-verify one resolved PartitionSpec against the invariants the
    engine promises, WITHOUT consulting the engine's own resolution
    code — the arithmetic here is the proof, logical_to_spec is the
    subject.  Returns human-readable problems (empty = holds)."""
    problems = []
    entries = tuple(spec)
    if len(entries) > len(shape):
        problems.append(f"spec {spec} has more entries than rank "
                        f"{len(shape)}")
        return problems
    used: dict[str, int] = {}
    for i, entry in enumerate(entries):
        if entry is None:
            continue
        axs = (entry,) if isinstance(entry, str) else tuple(entry)
        dim = shape[i]
        for a in axs:
            if a not in sizes:
                problems.append(f"dim {i} sharded over {a!r} which is "
                                f"not a mesh axis {sorted(sizes)}")
            used.setdefault(a, 0)
            used[a] += 1
        if dim == 0:
            problems.append(f"dim {i} has size 0 but spec shards it "
                            f"over {axs}")
            continue
        prod = math.prod(sizes.get(a, 1) for a in axs)
        q = (quantum or {}).get(axes[i], 1)
        if dim % q:
            problems.append(
                f"dim {i} ({axes[i]!r}, size {dim}) is not whole in "
                f"quantum units of {q} yet shards over {axs}")
        elif (dim // q) % prod:
            problems.append(
                f"dim {i} ({axes[i]!r}, size {dim}, quantum {q}) does "
                f"not divide over {axs} (fold size {prod})")
    for a, n in sorted(used.items()):
        if n > 1:
            problems.append(f"mesh axis {a!r} reused across {n} dims")
    return problems


# ---------------------------------------------------------------------
# SD001/SD002/SD003: the lattice walk
# ---------------------------------------------------------------------

def _walk_lattice(archs, inject: Optional[str]) -> list[Finding]:
    from repro.dist import mesh as mesh_lib
    from repro.dist import sharding as shd
    from repro.dist import variants as var

    findings = []
    for arch in archs:
        cfg = abscache.config(arch)
        surfaces = list(_surfaces(arch))
        if inject == "resolve" and arch == archs[0]:
            surfaces.append(("injected.bogus", ("sequence",), (8,)))
        if inject == "replicate" and arch == archs[0]:
            surfaces.append(("injected.big_replicated",
                             ("none", "none"), (2048, 2048)))
        for cell in var.enumerate_variants(cfg):
            for mesh in var.MESHES:
                sizes = mesh_lib.axis_sizes(mesh)
                where_cell = f"{arch} {cell.tag} @ {_mesh_tag(mesh)}"
                for label, axes, shape in surfaces:
                    try:
                        spec = shd.logical_to_spec(axes, shape,
                                                   cell.rules, mesh)
                    except Exception as e:
                        findings.append(Finding(
                            PASS, "SD001", f"{where_cell} {label}",
                            f"axes {axes} x shape {shape} does not "
                            f"resolve: {type(e).__name__}: {e}"))
                        continue
                    if inject == "spec" and label == "act.tokens":
                        spec = P("model", "model")
                    for problem in check_spec(axes, shape, spec, sizes,
                                              cell.rules.quantum):
                        findings.append(Finding(
                            PASS, "SD002", f"{where_cell} {label}",
                            f"resolved spec {spec} violates the "
                            f"engine's invariants: {problem}"))
                    if (cell.fsdp
                            and cell.variant not in
                            var.REPLICATING_VARIANTS
                            and label.startswith(("params",
                                                  "injected."))
                            and not len(spec)
                            and math.prod(shape) >= REPLICATION_FLOOR):
                        findings.append(Finding(
                            PASS, "SD003", f"{where_cell} {label}",
                            f"parameter of {math.prod(shape)} elements "
                            f"(shape {shape}, axes {axes}) is fully "
                            f"replicated on a "
                            f"{math.prod(mesh.shape)}-chip mesh"))
    return findings


# ---------------------------------------------------------------------
# SD004: the spmd-axes mirrors
# ---------------------------------------------------------------------

def _norm_entry(entry):
    if entry is None or isinstance(entry, str):
        return entry
    return tuple(entry)


def _check_mirrors(inject: Optional[str]) -> list[Finding]:
    from repro.dist import sharding as shd
    from repro.dist import variants as var

    cfg = abscache.config(abscache.SMOKE_ARCH)
    findings = []
    mirrors = (("slot", shd.slot_spmd_axes),
               ("page", shd.page_spmd_axes))
    for cell in var.enumerate_variants(cfg):
        for mesh in var.MESHES:
            for count in (1, 2, 8, 32, 512, 544):
                for axis, fn in mirrors:
                    spec = shd.logical_to_spec((axis,), (count,),
                                               cell.rules, mesh)
                    want = _norm_entry(spec[0] if len(spec) else None)
                    got = _norm_entry(fn(cell.rules, mesh, count))
                    if inject == "mirror" and axis == "slot" \
                            and got is None:
                        got = "model"
                    if want != got:
                        findings.append(Finding(
                            PASS, "SD004",
                            f"{cell.tag} @ {_mesh_tag(mesh)} "
                            f"{axis}={count}",
                            f"{fn.__name__} returned {got!r} but "
                            f"logical_to_spec resolves the {axis!r} "
                            f"axis to {want!r}"))
    return findings


# ---------------------------------------------------------------------
# SD005: the AST axis sweep
# ---------------------------------------------------------------------

def _call_name(func) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _axes_strings(node) -> Iterator[tuple[int, str]]:
    """(lineno, name) for every string element of a tuple literal
    anywhere inside an axes-argument expression — handles the
    ``("batch",) + d.axes`` / ``("none",) * k`` composition idioms."""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Tuple, ast.List)):
            for elt in sub.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    yield elt.lineno, elt.value


def sweep_axes(paths: tuple, known: frozenset) -> list[Finding]:
    """Walk python files for axis-bearing call sites and prove every
    literal logical-axis name is known to the rules engine."""
    findings = []
    files = []
    for root in paths:
        if os.path.isfile(root):
            files.append(root)
            continue
        for dirpath, _dirs, names in os.walk(root):
            files.extend(os.path.join(dirpath, n)
                         for n in sorted(names) if n.endswith(".py"))
    for path in files:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        findings.extend(sweep_axes_source(source, rel(path), known))
    return findings


def sweep_axes_source(source: str, rel_path: str,
                      known: frozenset) -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(PASS, "SD005", f"{rel_path}:{e.lineno}",
                        f"cannot sweep axes: {e.msg}")]
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node.func)
        if name not in _AXIS_CALL_SITES:
            continue
        pos, kw = _AXIS_CALL_SITES[name]
        arg = None
        if len(node.args) > pos:
            arg = node.args[pos]
        else:
            for k in node.keywords:
                if k.arg == kw:
                    arg = k.value
        if arg is None:
            continue
        for lineno, axis in _axes_strings(arg):
            if axis not in known:
                findings.append(Finding(
                    PASS, "SD005", f"{rel_path}:{lineno}",
                    f"{name}() names logical axis {axis!r} unknown to "
                    f"the rules engine (known: {sorted(known)}) — a "
                    f"typo here silently replicates"))
    return findings


# ---------------------------------------------------------------------
# SD006: the dist/README.md axis table
# ---------------------------------------------------------------------

_AXIS_TABLE_COLUMNS = ("logical axis", "train", "serve")


def _fmt_physical(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, str):
        return f"`{v}`"
    return "`" + ", ".join(v) + "`"


def _parse_physical(cell: str):
    cell = cell.strip().strip("`")
    if cell in ("-", ""):
        return None
    if "," in cell:
        return tuple(a.strip() for a in cell.split(","))
    return cell


def render_axis_table(notes: Optional[dict] = None) -> str:
    """The markdown logical-axis table, generated from the live rule
    sets (``--emit-axes``).  ``notes`` maps axis -> prose cell."""
    from repro.dist import sharding as shd
    notes = notes or {}
    train = shd.train_rules().axis_map
    serve = shd.serve_rules().axis_map
    rows = ["| logical axis | train | serve | notes |",
            "|--------------|-------|-------|-------|"]
    for axis in train:       # insertion order groups act/param axes
        rows.append("| " + " | ".join(
            (f"`{axis}`", _fmt_physical(train[axis]),
             _fmt_physical(serve[axis]), notes.get(axis, ""))) + " |")
    return "\n".join(rows)


def parse_axis_table(text: str) -> dict:
    """axis -> {"train": ..., "serve": ...} out of README markdown."""
    lines = text.splitlines()
    header = None
    for i, line in enumerate(lines):
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if cells and cells[0].lower() == "logical axis":
            header = [c.lower() for c in cells]
            start = i
            break
    if header is None:
        raise ValueError("no logical-axis table (header row starting "
                         "with 'logical axis') found")
    missing = [c for c in _AXIS_TABLE_COLUMNS if c not in header]
    if missing:
        raise ValueError(f"logical-axis table is missing columns "
                         f"{missing}; has {header}")
    out = {}
    for line in lines[start + 2:]:
        if not line.strip().startswith("|"):
            break
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) < len(_AXIS_TABLE_COLUMNS):
            break
        row = dict(zip(header, cells))
        out[row["logical axis"].strip("`")] = {
            "train": _parse_physical(row["train"]),
            "serve": _parse_physical(row["serve"])}
    if not out:
        raise ValueError("logical-axis table has no axis rows")
    return out


def parse_axis_notes(text: str) -> dict:
    """axis -> notes cell of an existing table (for re-rendering)."""
    lines = text.splitlines()
    notes = {}
    for line in lines:
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) >= 4 and cells[0].startswith("`") \
                and not cells[0].startswith("`logical"):
            notes[cells[0].strip("`")] = cells[3]
    return notes


DIST_README = os.path.join(REPO_ROOT, "src", "repro", "dist",
                           "README.md")


def _check_readme_axes(readme_path: str) -> list[Finding]:
    from repro.dist import sharding as shd
    where = rel(readme_path)
    try:
        with open(readme_path, encoding="utf-8") as f:
            table = parse_axis_table(f.read())
    except (OSError, ValueError) as e:
        return [Finding(PASS, "SD006", where,
                        f"cannot check logical-axis table: {e}")]
    findings = []
    live = {"train": shd.train_rules().axis_map,
            "serve": shd.serve_rules().axis_map}
    documented = set(table)
    for axis in sorted(set(live["train"]) - documented):
        findings.append(Finding(
            PASS, "SD006", where,
            f"logical axis {axis!r} missing from the README table"))
    for axis in sorted(documented - set(live["train"])):
        findings.append(Finding(
            PASS, "SD006", where,
            f"README table documents unknown logical axis {axis!r}"))
    for axis in sorted(documented & set(live["train"])):
        for mode in ("train", "serve"):
            want = live[mode][axis]
            want = tuple(want) if isinstance(want, (list, tuple)) \
                else want
            got = table[axis][mode]
            if want != got:
                findings.append(Finding(
                    PASS, "SD006", where,
                    f"axis {axis!r} {mode} mapping drifted: README "
                    f"says {got!r}, engine says {want!r} (regenerate "
                    f"with --emit-axes)"))
    return findings


# ------------------------------------------------------------- runner

def run(inject: Optional[str] = None,
        readme_path: Optional[str] = None,
        scan_paths: Optional[tuple] = None,
        archs: Optional[tuple] = None) -> list[Finding]:
    """Run the full shard pass; returns findings (empty = clean).

    ``inject`` seeds one violation (resolve/spec/replicate/mirror/
    axis/drift) for the gate-gates-itself tests; ``scan_paths``
    overrides the SD005 sweep roots (default: ``src/``)."""
    from repro import configs

    archs = tuple(archs if archs is not None else configs.ARCHS)
    findings = _walk_lattice(archs, inject)
    findings.extend(_check_mirrors(inject))

    known = _known_axes()
    paths = tuple(scan_paths) if scan_paths is not None \
        else (os.path.join(REPO_ROOT, "src"),)
    findings.extend(sweep_axes(paths, known))
    if inject == "axis":
        findings.extend(sweep_axes_source(
            'w = ParamDef((4, 4), ("embeddd", "mlp"))\n',
            "<injected>", known))

    readme = readme_path or DIST_README
    if inject == "drift":
        with open(readme, encoding="utf-8") as f:
            text = f.read().replace("| `embed` | `data` |",
                                    "| `embed` | `model` |")
        import tempfile
        with tempfile.NamedTemporaryFile(
                "w", suffix=".md", delete=False) as tmp:
            tmp.write(text)
            readme = tmp.name
        try:
            findings.extend(_check_readme_axes(readme))
        finally:
            os.unlink(readme)
    else:
        findings.extend(_check_readme_axes(readme))
    return findings
