"""Pass 6 — front-end dynamic contracts (the serving front-end's
half of lint rule RA005, checked by running it).

Three checks over ``repro.frontend`` on the smoke model, each one of
the contracts src/repro/frontend/README.md states:

  FE001  streaming transfer contract — a warmed front-end replay under
         :func:`~repro.analysis.sanitizer.sanitize` performs EXACTLY
         one device->host transfer per scheduler chunk (streaming
         consumes the chunk payload, never adds a sync), the server's
         own ``host_transfers``/``chunks`` accounting agrees, and zero
         compiles fire after warmup.
  FE002  bounded queue + explicit backpressure — replaying a burst
         against a ``queue_limit=2`` server never holds more than 2
         pending requests, and every submit is accounted for:
         ``submitted == completed + rejected`` with every reject
         carrying a reason.
  FE003  deterministic admission — the same overload trace replayed
         twice under a virtual clock (priorities + deadlines + a
         shedding SLO policy) produces identical admission logs,
         identical per-request tokens and identical shed sets.

``inject`` seeds violations for the CLI self-test
(``--inject-frontend``): 'transfer' adds a device->host sync inside
the sanitized replay (FE001), 'drop' un-accounts a rejected request
(FE002), 'order' replays the second FE003 epoch under a policy with a
perturbed tie-break (admission-log divergence).
"""
from __future__ import annotations

from .base import Finding

PASS = "frontend"

_ARCH = "internlm2-1.8b"


def _registry():
    from repro.frontend import ModelRegistry, ModelSpec
    reg = ModelRegistry()
    reg.register(ModelSpec(name="m", arch=_ARCH, smoke=True,
                           kind="paged", capacity=64, slots=2, chunk=4,
                           page_size=16))
    return reg


def _records(reg, *, deadlines=None, priorities=None, arrivals=None,
             n: int = 6):
    from repro.frontend import trace_requests
    from repro.serve import make_trace
    trace = make_trace(arrivals if arrivals is not None else [0.0] * n,
                       [8, 12], [6, 8],
                       priorities=priorities, deadlines=deadlines)
    return trace_requests(trace, reg, ["m"], seed=0)


def _check_streaming(inject=()) -> list:
    """FE001: warm, then replay the same shapes under sanitize."""
    import jax

    from repro.frontend import FIFOAdmission, FrontendServer, replay
    from .sanitizer import sanitize
    findings = []
    reg = _registry()
    server = FrontendServer(reg, FIFOAdmission(), queue_limit=16)
    records = _records(reg)
    replay(server, records)        # warmup: compiles every chunk key
    with sanitize() as rep:
        r = replay(server, records)
        if "transfer" in inject:
            # seeded violation: a device->host sync the streaming
            # layer is forbidden to add
            jax.device_get(reg.entry("m").scheduler.tok)   # lint: allow RA002 (violation injection for the frontend pass self-test)
    if rep.transfers != r["chunks"]:
        findings.append(Finding(
            PASS, "FE001", "frontend.replay[streaming]",
            f"{rep.transfers} device->host transfers over "
            f"{r['chunks']} chunks; streaming must consume the "
            f"schedulers' per-chunk payload, exactly one per chunk"))
    if r["host_transfers"] != r["chunks"]:
        findings.append(Finding(
            PASS, "FE001", "frontend.replay[streaming]",
            f"server accounting drifted: host_transfers "
            f"{r['host_transfers']}, chunks {r['chunks']}"))
    if rep.compiles:
        findings.append(Finding(
            PASS, "FE001", "frontend.replay[streaming]",
            f"{rep.compiles} compile requests after warmup (the "
            f"front-end replayed shapes the pools already compiled)"))
    return findings


def _check_backpressure(inject=()) -> list:
    """FE002: burst into a queue_limit=2 server; bounded + accounted."""
    from repro.frontend import FIFOAdmission, FrontendServer, replay
    findings = []
    reg = _registry()
    server = FrontendServer(reg, FIFOAdmission(), queue_limit=2)
    r = replay(server, _records(reg, n=8))
    if "drop" in inject:
        # seeded violation: lose a rejected request from the books
        server.rejected.pop()
    if server.max_pending_seen > server.queue_limit:
        findings.append(Finding(
            PASS, "FE002", "frontend.replay[backpressure]",
            f"pending queue reached {server.max_pending_seen} with "
            f"queue_limit={server.queue_limit}; the queue bound is a "
            f"contract, not a hint"))
    accounted = len(server.completed) + len(server.rejected)
    if server.submitted != accounted or server.in_flight:
        findings.append(Finding(
            PASS, "FE002", "frontend.replay[backpressure]",
            f"accounting hole: {server.submitted} submitted but "
            f"{accounted} accounted ({len(server.completed)} completed "
            f"+ {len(server.rejected)} rejected, {server.in_flight} "
            f"in flight) — requests must never be silently dropped"))
    unreasoned = sum(1 for s in server.rejected if not s.reason)
    if unreasoned:
        findings.append(Finding(
            PASS, "FE002", "frontend.replay[backpressure]",
            f"{unreasoned} rejected request(s) carry no reason"))
    if r["rejected"] and not r["rejects_by_reason"]:
        findings.append(Finding(
            PASS, "FE002", "frontend.replay[backpressure]",
            "rejects_by_reason empty despite rejects"))
    return findings


def _replay_virtual(reg, records, policy):
    from repro.frontend import FrontendServer, VirtualClock, replay
    clock = VirtualClock()
    server = FrontendServer(reg, policy, queue_limit=4, clock=clock)
    r = replay(server, records, sleep=clock.advance,
               tick=lambda: clock.advance(0.02), collect_tokens=True)
    return r, list(server.admission_log)


def _check_determinism(inject=()) -> list:
    """FE003: two virtual-clock replays of one overload trace must
    agree decision-for-decision and token-for-token."""
    from repro.frontend import SLOAdmission, deadline_at
    findings = []
    reg = _registry()
    records = _records(
        reg, n=8,
        arrivals=[round(0.01 * i, 3) for i in range(8)],
        priorities=[0, 1], deadlines=[0.08, None])
    policy = SLOAdmission(service_floor_s=0.02)
    # warm the pools so both measured replays see compiled shapes
    _replay_virtual(reg, records, policy)
    r1, log1 = _replay_virtual(reg, records, policy)
    if "order" in inject:
        class _Jittered(SLOAdmission):
            # seeded violation: an order that flips whenever several
            # requests are pending at once — stands in for any policy
            # whose decisions aren't a pure function of (trace, seed)
            def sort_key(self, req, now):
                return (req.priority, deadline_at(req),
                        -req.arrival_s, -req.uid)
        policy = _Jittered(service_floor_s=0.02)
    r2, log2 = _replay_virtual(reg, records, policy)
    if log1 != log2:
        diverge = next((i for i, (a, b)
                        in enumerate(zip(log1, log2)) if a != b),
                       min(len(log1), len(log2)))
        findings.append(Finding(
            PASS, "FE003", "frontend.replay[determinism]",
            f"admission logs diverge at decision #{diverge} "
            f"({log1[diverge] if diverge < len(log1) else '<end>'} vs "
            f"{log2[diverge] if diverge < len(log2) else '<end>'}); "
            f"admission must be a pure function of (trace, seed)"))
    if r1.get("out_tokens") != r2.get("out_tokens"):
        findings.append(Finding(
            PASS, "FE003", "frontend.replay[determinism]",
            "per-request tokens differ between identical replays"))
    if (r1["shed"], r1["deadline_met"]) != (r2["shed"],
                                            r2["deadline_met"]):
        findings.append(Finding(
            PASS, "FE003", "frontend.replay[determinism]",
            f"shed/deadline accounting differs: "
            f"{(r1['shed'], r1['deadline_met'])} vs "
            f"{(r2['shed'], r2['deadline_met'])}"))
    return findings


def run(inject=()) -> list:
    """The frontend pass: streaming transfer parity, bounded
    backpressure, and virtual-clock admission determinism on the smoke
    model.  ``inject`` seeds violations ('transfer', 'drop', 'order')
    for the CLI self-test (``--inject-frontend``)."""
    findings = _check_streaming(inject=inject)
    findings += _check_backpressure(inject=inject)
    findings += _check_determinism(inject=inject)
    return findings
