"""Pass 4 — AST lint encoding the repo's standing constraints.

Rules (catalog + rationale in src/repro/analysis/README.md):

  RA000  malformed suppression comment or invalid rules.toml entry
  RA001  bare/blind exception swallow: ``except:`` /
         ``except Exception:`` where the exception is not bound (or
         bound but never used) and not re-raised — failures must be
         surfaced, not passed over
  RA002  ``jax.device_get`` outside an audited ``_device_get``
         chokepoint — every device->host sync must route through the
         engines' counted chokepoint (the transfer contract pass 3
         enforces dynamically)
  RA003  routing kwargs (backend/domain/interpret/bm/bn/bk) threaded
         into ``ternary_matmul``/``ternary_matmul_int8``/``cim_matmul``
         calls outside ``src/repro/kernels/`` — routing belongs in the
         plan API (``plan_matmul``/``CimConfig``), not call sites; the
         kernels package itself (shims + runners) is the one layer
         allowed to speak kwargs
  RA004  unseeded RNG in ``benchmarks/`` — legacy ``np.random.*``
         global-state sampling, stdlib ``random.*`` module calls,
         ``default_rng()`` with no seed, or ``jax.random.key``/
         ``PRNGKey`` construction whose seed is neither an int literal
         nor a ``stable_seed(...)`` derivation — all make benchmark
         numbers irreproducible (or reshuffle when a sweep is edited)
  RA005  front-end purity (``src/repro/frontend/``): the front-end
         layers on the schedulers' audited chunk transfer, so
         ``jax.device_get`` (in any form) is banned outright there;
         admission must be deterministic given (trace, seed), so
         direct wall-clock CALLS (``time.time()``/``monotonic()``/
         ``perf_counter()`` — passing the function as an injectable
         default is fine) and global/unseeded RNG are banned; queues
         must be bounded, so ``deque()`` without ``maxlen`` is banned
         (the dynamic side of all three lives in the ``frontend``
         analysis pass)

Suppressions:

  * inline, same line as the violation::

        risky()   # lint: allow RA002 (one-line reason)

    A ``# lint:`` comment that does not parse to exactly that shape is
    itself a finding (RA000) — suppressions never fail open.
  * config, in ``src/repro/analysis/rules.toml``::

        [[suppress]]
        rule = "RA002"
        path = "src/repro/checkpoint/checkpoint.py"
        reason = "one-line reason"

    Wildcard rules and empty reasons are rejected (RA000).

  Suppressions are audited, not trusted: an inline ``# lint: allow``
  whose line has no matching finding, or a ``[[suppress]]`` entry that
  matched nothing anywhere under the scanned trees, is itself an RA000
  finding — stale suppressions would otherwise silently mask the next
  real violation at that site.  Config entries whose path lies outside
  the scanned trees are left alone (a ``--lint-paths`` subset run must
  not declare repo-wide suppressions dead).
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from typing import Optional

from .base import REPO_ROOT, Finding, rel

PASS = "lint"

DEFAULT_PATHS = ("src", "benchmarks")
CONFIG_PATH = os.path.join(os.path.dirname(__file__), "rules.toml")

# RA003: the plan-request fields that must not be threaded as call-site
# kwargs around the plan API (kernels' deprecation shims map them into
# plan_matmul; everything else goes through ExecutionPlan/CimConfig)
ROUTING_KWARGS = frozenset(
    {"backend", "domain", "interpret", "bm", "bn", "bk"})
ROUTED_CALLEES = frozenset(
    {"ternary_matmul", "ternary_matmul_int8", "cim_matmul"})
# the one layer allowed to speak routing kwargs: the shims that accept
# them and the runners that forward them into pallas kernels
RA003_EXEMPT_PREFIX = os.path.join("src", "repro", "kernels") + os.sep

# RA005: the front-end package must stay deterministic (injectable
# clock, no global RNG), transfer-free (no device_get — it consumes the
# schedulers' chunk payload), and bounded (no unbounded deque queues).
# Only CALLS are flagged: `clock=time.monotonic` as an injectable
# default argument is the sanctioned idiom.
RA005_PREFIX = os.path.join("src", "repro", "frontend") + os.sep
WALLCLOCK_FNS = frozenset(
    {"time", "monotonic", "perf_counter", "time_ns", "monotonic_ns",
     "perf_counter_ns"})

# RA004: legacy numpy global-RNG sampling + stdlib random module fns
NP_LEGACY_SAMPLERS = frozenset(
    {"rand", "randn", "randint", "random", "random_sample", "choice",
     "shuffle", "permutation", "uniform", "normal", "standard_normal"})
STDLIB_RANDOM_FNS = frozenset(
    {"random", "randint", "randrange", "choice", "choices", "shuffle",
     "sample", "uniform", "gauss", "normalvariate", "betavariate"})

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*allow\s+(RA\d{3})\s+\(([^)]+)\)")
_SUPPRESS_MARKER_RE = re.compile(r"#\s*lint\s*:")
_RULE_ID_RE = re.compile(r"^RA\d{3}$")


# ------------------------------------------------ rules.toml (3.10
# has no tomllib; this parses the strict subset the config uses:
# [section], [[table]], key = "string" / ["a", "b"] — anything else is
# a config error, surfaced as RA000)

def _parse_toml_value(text: str, where: str, findings: list):
    text = text.strip()
    if text.startswith('"') and text.endswith('"') and len(text) >= 2:
        return text[1:-1]
    if text.startswith("[") and text.endswith("]"):
        inner = text[1:-1].strip()
        if not inner:
            return []
        items = []
        for part in inner.split(","):
            part = part.strip()
            if not part:
                continue
            if not (part.startswith('"') and part.endswith('"')):
                findings.append(Finding(
                    PASS, "RA000", where,
                    f"unsupported TOML value {part!r} (quoted strings "
                    f"only)"))
                return None
            items.append(part[1:-1])
        return items
    findings.append(Finding(
        PASS, "RA000", where,
        f"unsupported TOML value {text!r} (quoted string or list of "
        f"quoted strings)"))
    return None


def _parse_toml(text: str, path: str, findings: list) -> dict:
    data: dict = {}
    current: Optional[dict] = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        where = f"{path}:{lineno}"
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            current = {}
            data.setdefault(line[2:-2].strip(), []).append(current)
        elif line.startswith("[") and line.endswith("]"):
            current = data.setdefault(line[1:-1].strip(), {})
        elif "=" in line:
            if current is None:
                findings.append(Finding(
                    PASS, "RA000", where,
                    "top-level keys are not supported; use a [section]"))
                continue
            key, _, value = line.partition("=")
            parsed = _parse_toml_value(value, where, findings)
            if parsed is not None:
                current[key.strip()] = parsed
        else:
            findings.append(Finding(
                PASS, "RA000", where, f"unparseable line {line!r}"))
    return data


def load_config(path: str, findings: list) -> dict:
    """Parse + validate rules.toml; config errors become RA000
    findings.  Returns {'paths': [...],
    'suppress': [(rule, path, where), ...]} — ``where`` locates the
    entry for the dead-suppression audit."""
    cfg = {"paths": list(DEFAULT_PATHS), "suppress": []}
    if not os.path.exists(path):
        return cfg
    with open(path, encoding="utf-8") as f:
        data = _parse_toml(f.read(), rel(path), findings)
    lint = data.get("lint", {})
    if isinstance(lint.get("paths"), list) and lint["paths"]:
        cfg["paths"] = lint["paths"]
    for i, sup in enumerate(data.get("suppress", [])):
        where = f"{rel(path)}:[[suppress]] #{i + 1}"
        rule = sup.get("rule", "")
        spath = sup.get("path", "")
        reason = sup.get("reason", "")
        if not _RULE_ID_RE.match(rule):
            findings.append(Finding(
                PASS, "RA000", where,
                f"suppression rule must be a single RAxxx id, got "
                f"{rule!r} (wildcards are not allowed)"))
            continue
        if not spath:
            findings.append(Finding(
                PASS, "RA000", where, "suppression needs a path"))
            continue
        if not reason.strip():
            findings.append(Finding(
                PASS, "RA000", where,
                "suppression needs a one-line reason"))
            continue
        cfg["suppress"].append((rule, spath, where))
    return cfg


# ------------------------------------------------ per-file checks

def _collect_inline_suppressions(source: str, path: str,
                                 findings: list) -> dict:
    """line -> set of rule ids allowed on that line; malformed
    ``# lint:`` comments are RA000.  Only real COMMENT tokens are
    inspected (tokenize), so '# lint:' inside string literals — e.g.
    this module's own docstrings — is not a suppression attempt."""
    allowed: dict = {}
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return allowed      # unparseable files are flagged by ast below
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        if not _SUPPRESS_MARKER_RE.search(tok.string):
            continue
        lineno = tok.start[0]
        matches = _SUPPRESS_RE.findall(tok.string)
        if not matches:
            findings.append(Finding(
                PASS, "RA000", f"{path}:{lineno}",
                "malformed suppression; the form is "
                "'# lint: allow RAxxx (reason)'"))
            continue
        for rule, reason in matches:
            if not reason.strip():
                findings.append(Finding(
                    PASS, "RA000", f"{path}:{lineno}",
                    "suppression needs a non-empty reason"))
                continue
            allowed.setdefault(lineno, set()).add(rule)
    return allowed


def _names_in(nodes) -> set:
    out = set()
    for node in nodes:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name):
                out.add(sub.id)
    return out


def _has_bare_raise(nodes) -> bool:
    for node in nodes:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Raise) and sub.exc is None:
                return True
    return False


def _is_blind_handler_type(node) -> bool:
    if node is None:                       # bare except:
        return True
    if isinstance(node, ast.Name):
        return node.id in ("Exception", "BaseException")
    if isinstance(node, ast.Tuple):
        return any(_is_blind_handler_type(e) for e in node.elts)
    return False


def _dotted(node) -> str:
    """'a.b.c' for nested Attribute/Name chains, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, in_benchmarks: bool,
                 ra003_exempt: bool, in_frontend: bool = False):
        self.path = path
        self.in_benchmarks = in_benchmarks
        self.ra003_exempt = ra003_exempt
        self.in_frontend = in_frontend
        self.func_stack: list = []
        self.findings: list = []

    def _flag(self, rule: str, node, message: str) -> None:
        self.findings.append(Finding(
            PASS, rule, f"{self.path}:{node.lineno}", message))

    # --- RA001 ------------------------------------------------
    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        if _is_blind_handler_type(node.type):
            if _has_bare_raise(node.body):
                pass                       # re-raised: not a swallow
            elif node.name is None:
                # neither binds nor re-raises — nothing about the
                # failure can reach a log or a caller
                kind = ("bare except:" if node.type is None
                        else f"except {ast.unparse(node.type)}:")
                self._flag("RA001", node,
                           f"{kind} swallows the exception without "
                           f"binding or re-raising it; narrow the type "
                           f"and surface the failure")
            elif node.name not in _names_in(node.body):
                self._flag("RA001", node,
                           f"except {ast.unparse(node.type)} as "
                           f"{node.name}: binds the exception but never "
                           f"uses it; narrow the type and surface the "
                           f"failure")
        self.generic_visit(node)

    # --- RA002 / RA005 (device_get) ---------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        if _dotted(node) == "jax.device_get":
            if self.in_frontend:
                self._flag("RA005", node,
                           "jax.device_get in the front-end; streaming "
                           "must consume the schedulers' per-chunk "
                           "payload (host_transfers == chunks), never "
                           "add its own device->host sync")
            elif "_device_get" not in self.func_stack:
                self._flag("RA002", node,
                           "jax.device_get outside an audited "
                           "_device_get chokepoint; route device->host "
                           "syncs through the engine's counted "
                           "chokepoint")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "jax" and any(a.name == "device_get"
                                        for a in node.names):
            rule = "RA005" if self.in_frontend else "RA002"
            self._flag(rule, node,
                       "importing device_get from jax bypasses the "
                       "audited _device_get chokepoint")
        self.generic_visit(node)

    # --- RA003 / RA004 ----------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        callee = _dotted(node.func)
        leaf = callee.rsplit(".", 1)[-1] if callee else ""
        if not self.ra003_exempt and leaf in ROUTED_CALLEES:
            threaded = sorted(k.arg for k in node.keywords
                              if k.arg in ROUTING_KWARGS)
            if threaded:
                self._flag("RA003", node,
                           f"{leaf}() threads routing kwargs "
                           f"{threaded} around the plan API; build an "
                           f"ExecutionPlan (plan_matmul) or CimConfig "
                           f"instead")
        if self.in_benchmarks:
            self._check_rng(node, callee, leaf)
        if self.in_frontend:
            self._check_frontend(node, callee, leaf)
        self.generic_visit(node)

    def _check_frontend(self, node: ast.Call, callee: str,
                        leaf: str) -> None:
        parts = callee.split(".")
        if len(parts) == 2 and parts[0] == "time" \
                and parts[1] in WALLCLOCK_FNS:
            self._flag("RA005", node,
                       f"{callee}() reads the wall clock directly; the "
                       f"front-end must read time only through an "
                       f"injected clock (pass the function as a "
                       f"default, call the injected name) so replays "
                       f"are deterministic under a virtual clock")
        elif (len(parts) == 3 and parts[0] in ("np", "numpy")
                and parts[1] == "random"
                and parts[2] in NP_LEGACY_SAMPLERS):
            self._flag("RA005", node,
                       f"{callee}() samples from numpy's global RNG; "
                       f"admission must be deterministic given "
                       f"(trace, seed) — use a seeded Generator")
        elif len(parts) == 2 and parts[0] == "random" \
                and parts[1] in STDLIB_RANDOM_FNS:
            self._flag("RA005", node,
                       f"{callee}() uses the stdlib global RNG; "
                       f"admission must be deterministic given "
                       f"(trace, seed) — use a seeded Generator")
        elif leaf == "default_rng" and not node.args and not node.keywords:
            self._flag("RA005", node,
                       "default_rng() without a seed is entropy-seeded; "
                       "the front-end must derive every draw from "
                       "(trace, seed)")
        elif leaf == "deque" and len(node.args) < 2 \
                and not any(k.arg == "maxlen" for k in node.keywords):
            self._flag("RA005", node,
                       "deque() without maxlen is an unbounded queue; "
                       "front-end queues are bounded by contract "
                       "(reject with a reason, never buffer without "
                       "limit)")

    def _check_rng(self, node: ast.Call, callee: str, leaf: str) -> None:
        parts = callee.split(".")
        if (len(parts) == 3 and parts[0] in ("np", "numpy")
                and parts[1] == "random"
                and parts[2] in NP_LEGACY_SAMPLERS):
            self._flag("RA004", node,
                       f"{callee}() samples from numpy's global RNG; "
                       f"benchmarks must use a seeded Generator "
                       f"(np.random.default_rng(seed))")
        elif (len(parts) == 2 and parts[0] == "random"
                and parts[1] in STDLIB_RANDOM_FNS):
            self._flag("RA004", node,
                       f"{callee}() uses the stdlib global RNG; "
                       f"benchmarks must use a seeded Generator")
        elif leaf == "default_rng" and not node.args and not node.keywords:
            self._flag("RA004", node,
                       "default_rng() without a seed is entropy-seeded; "
                       "benchmarks must pass an explicit seed")
        elif (len(parts) >= 2 and parts[-2] == "random"
                and parts[-1] in ("key", "PRNGKey")):
            seed = node.args[0] if node.args else None
            literal = (isinstance(seed, ast.Constant)
                       and isinstance(seed.value, int))
            derived = (isinstance(seed, ast.Call)
                       and _dotted(seed.func).rsplit(".", 1)[-1]
                       == "stable_seed")
            if not (literal or derived):
                self._flag("RA004", node,
                           f"{callee}() seed must be an int literal or "
                           f"a stable_seed(...) derivation; ad-hoc seed "
                           f"expressions (offsets, hashes) reshuffle "
                           f"benchmark draws when a sweep is edited")

    # --- function-stack tracking for RA002 --------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()


def check_file(path: str, rel_path: Optional[str] = None) -> list:
    """Lint one python file; returns findings with inline suppressions
    already applied (RA000s for malformed suppressions included)."""
    rel_path = rel_path if rel_path is not None else rel(path)
    with open(path, encoding="utf-8") as f:
        source = f.read()
    findings: list = []
    allowed = _collect_inline_suppressions(source, rel_path, findings)
    try:
        tree = ast.parse(source, filename=rel_path)
    except SyntaxError as e:
        findings.append(Finding(
            PASS, "RA000", f"{rel_path}:{e.lineno}",
            f"file does not parse: {e.msg}"))
        return findings
    in_benchmarks = rel_path.startswith("benchmarks" + os.sep)
    ra003_exempt = rel_path.startswith(RA003_EXEMPT_PREFIX)
    in_frontend = rel_path.startswith(RA005_PREFIX)
    visitor = _Visitor(rel_path, in_benchmarks, ra003_exempt,
                       in_frontend)
    visitor.visit(tree)
    used: set = set()
    for f in visitor.findings:
        lineno = int(f.where.rsplit(":", 1)[1])
        if f.rule in allowed.get(lineno, ()):
            used.add((lineno, f.rule))
            continue
        findings.append(f)
    # dead-suppression audit: an allow that matched nothing is masking
    # a violation that no longer exists — and would silently mask the
    # next one introduced on that line
    for lineno in sorted(allowed):
        for rule in sorted(allowed[lineno]):
            if (lineno, rule) not in used:
                findings.append(Finding(
                    PASS, "RA000", f"{rel_path}:{lineno}",
                    f"dead suppression: no {rule} finding on this "
                    f"line; delete the '# lint: allow' comment"))
    return findings


def _iter_py_files(paths):
    for base in paths:
        root = os.path.join(REPO_ROOT, base)
        if os.path.isfile(root):
            yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d != "__pycache__"
                           and not d.startswith(".")]
            for name in sorted(filenames):
                if name.endswith(".py"):
                    yield os.path.join(dirpath, name)


def run(paths=None, config: Optional[str] = None) -> list:
    """The lint pass over the configured trees (default: rules.toml's
    ``[lint] paths``, falling back to src/ + benchmarks/)."""
    findings: list = []
    cfg = load_config(config if config is not None else CONFIG_PATH,
                      findings)
    scan = list(paths) if paths is not None else cfg["paths"]
    suppress = cfg["suppress"]
    used: set = set()
    for path in _iter_py_files(scan):
        rel_path = rel(path)
        for f in check_file(path, rel_path):
            hit = False
            for i, (rule, spath, _) in enumerate(suppress):
                if rule == f.rule and (
                        rel_path == spath
                        or rel_path.startswith(spath.rstrip("/") + "/")):
                    used.add(i)
                    hit = True
            if not hit:
                findings.append(f)
    # dead-suppression audit, restricted to entries whose path lies
    # under the scanned trees — a --lint-paths subset run must not
    # declare repo-wide suppressions dead
    bases = [rel(os.path.join(REPO_ROOT, b)).rstrip("/") for b in scan]
    for i, (rule, spath, where) in enumerate(suppress):
        if i in used:
            continue
        norm = spath.rstrip("/")
        if any(norm == b or norm.startswith(b + os.sep) for b in bases):
            findings.append(Finding(
                PASS, "RA000", where,
                f"dead suppression: no {rule} finding under "
                f"{spath!r}; delete the [[suppress]] entry"))
    return findings
