"""Pass 1 — capability-lattice checker.

Enumerates the full (op x backend x domain x packing x kv_layout x
fidelity x platform) lattice from the LIVE backend registry in
``repro.kernels`` and proves, cell by cell:

  * every declared-capable cell resolves through the public
    ``plan_matmul`` path (current platform) or the internal cached
    resolver with an explicit platform (cross-platform cells — the
    public entry probes ``jax.default_backend()``), and
  * abstract-evaluates through ``execute`` under ``jax.eval_shape`` —
    no kernel is ever executed or compiled — producing the contracted
    ``(M, N)`` float32 output;
  * every UNdeclared cell raises the loud capability error (the
    "fails loudly with what it does support" contract of
    ``resolve_backend``), and every empty ``auto`` cell raises the
    no-capable-backend error;
  * ``auto`` resolution picks the highest-priority capable backend of
    each capable cell;
  * the hand-written capability matrix in
    ``src/repro/kernels/README.md`` matches the registry exactly
    (parse the markdown table; any drift is a finding).

One semantic footnote the lattice cannot express: ``op='cim'`` plans
accept float weights under any packing (ternarized on the fly), but a
*packed* weight must be base3 — the checker proves the trit2-packed
rejection is loud (CAP005) instead of modeling packing as a cim
capability axis.
"""
from __future__ import annotations

import os
import re
from typing import Optional

from .base import Finding, REPO_ROOT

PASS = "capability"
README_PATH = os.path.join(REPO_ROOT, "src", "repro", "kernels",
                           "README.md")

# one small shape per abstract eval; value is irrelevant (eval_shape
# never executes), it only has to satisfy packing divisibility
EVAL_SHAPE = (8, 64, 128)

# the six machine-checked matrix columns, in table order
MATRIX_COLUMNS = ("ops", "domains", "packings", "platforms", "kv layouts",
                  "fidelities")


def _registry():
    from repro.kernels import plan as plan_mod
    plan_mod._ensure_builtin_backends()
    return dict(plan_mod._REGISTRY)


def _lattice_axes(registry):
    from repro.kernels.plan import (DOMAINS, FIDELITIES, KV_LAYOUTS, OPS,
                                    PACKINGS)
    platforms = sorted(set().union(*(s.platforms
                                     for s in registry.values())))
    return OPS, DOMAINS, PACKINGS, KV_LAYOUTS, FIDELITIES, platforms


def _eval_operands(op: str, packing: str, shape):
    """ShapeDtypeStruct operands for one abstract eval of `execute`."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import PackedTernary, TRIT2_PER_BYTE
    m, k, n = shape
    if op == "attention":
        # no packed weight: the operand is the raw page-pool view
        from repro.kernels import paged_attention
        return paged_attention.eval_operands(shape)
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    if op == "cim":
        # float weights: ternarized on the fly by the runner, valid
        # under every packing request (see module docstring)
        return x, jax.ShapeDtypeStruct((k, n), jnp.float32)
    kw = k // TRIT2_PER_BYTE if packing == "trit2" else k
    w = PackedTernary(jax.ShapeDtypeStruct((kw, n), jnp.uint8),
                      jax.ShapeDtypeStruct((n,), jnp.float32), packing)
    return x, w


def _check_declared_cell(name, op, domain, packing, kv_layout, fidelity,
                         platform, current_platform) -> Optional[Finding]:
    """A declared-capable cell must resolve and abstract-eval."""
    import jax
    from repro.kernels import execute, plan_matmul
    from repro.kernels.plan import _resolve
    cell = (f"op={op} backend={name} domain={domain} packing={packing} "
            f"kv_layout={kv_layout} fidelity={fidelity} "
            f"platform={platform}")
    m, k, n = EVAL_SHAPE
    adc = 5 if (op == "cim" or fidelity == "device") else None
    try:
        if platform == current_platform:
            plan = plan_matmul(EVAL_SHAPE, op=op, backend=name,
                               domain=domain, packing=packing,
                               kv_layout=kv_layout, fidelity=fidelity)
        else:
            # the public entry probes the live platform; cross-platform
            # cells go through the same cached resolver explicitly
            plan = _resolve(op, m, k, n, "auto", name, domain, packing,
                            None, None, None, None, kv_layout, fidelity,
                            adc, adc, platform)
    except Exception as e:
        return Finding(PASS, "CAP001", cell,
                       f"declared-capable cell failed to resolve: {e!r}")
    if plan.backend != name:
        return Finding(PASS, "CAP001", cell,
                       f"resolved to backend {plan.backend!r}")
    if platform != current_platform:
        return None          # cannot abstract-eval a foreign platform's
                             # interpret/runner configuration faithfully
    try:
        x, w = _eval_operands(op, packing, EVAL_SHAPE)
        out = jax.eval_shape(lambda xx, ww: execute(plan, xx, ww), x, w)
    except Exception as e:
        return Finding(PASS, "CAP002", cell,
                       f"declared-capable cell failed abstract eval "
                       f"through execute: {e!r}")
    import jax.numpy as jnp
    if op == "attention":
        # contract: partial flash statistics (acc, m, l), all f32
        from repro.kernels import paged_attention
        want = paged_attention.eval_output(EVAL_SHAPE)
        got = tuple(tuple(o.shape) for o in out)
        if (got != want
                or any(o.dtype != jnp.float32 for o in out)):
            return Finding(PASS, "CAP002", cell,
                           f"abstract eval produced {got} "
                           f"{[str(o.dtype) for o in out]}, expected "
                           f"{want} float32 (acc, m, l)")
        return None
    if tuple(out.shape) != (m, n) or out.dtype != jnp.float32:
        return Finding(PASS, "CAP002", cell,
                       f"abstract eval produced {out.shape} {out.dtype}, "
                       f"expected ({m}, {n}) float32")
    return None


def _check_undeclared_cell(name, op, domain, packing, kv_layout, fidelity,
                           platform) -> Optional[Finding]:
    """An undeclared cell must raise the loud capability error."""
    from repro.kernels.plan import resolve_backend
    cell = (f"op={op} backend={name} domain={domain} packing={packing} "
            f"kv_layout={kv_layout} fidelity={fidelity} "
            f"platform={platform}")
    try:
        resolve_backend(op, name, domain, packing, platform, kv_layout,
                        fidelity)
    except ValueError as e:
        if "does not support" not in str(e):
            return Finding(PASS, "CAP003", cell,
                           f"capability rejection lost the loud "
                           f"'does not support' message: {e}")
        return None
    return Finding(PASS, "CAP003", cell,
                   "undeclared cell resolved without a capability error")


def _check_auto_cell(registry, op, domain, packing, kv_layout, fidelity,
                     platform) -> Optional[Finding]:
    """'auto' must pick the highest-priority capable backend, or raise
    the no-capable-backend error when the cell is empty."""
    from repro.kernels.plan import resolve_backend
    cell = (f"op={op} backend=auto domain={domain} packing={packing} "
            f"kv_layout={kv_layout} fidelity={fidelity} "
            f"platform={platform}")
    capable = [s for s in registry.values()
               if s.supports(op, domain, packing, platform, kv_layout,
                             fidelity)]
    try:
        spec = resolve_backend(op, "auto", domain, packing, platform,
                               kv_layout, fidelity)
    except ValueError as e:
        if capable:
            return Finding(PASS, "CAP004", cell,
                           f"auto failed on a capable cell: {e}")
        if "no registered backend" not in str(e):
            return Finding(PASS, "CAP004", cell,
                           f"empty cell lost the loud no-capable-backend "
                           f"message: {e}")
        return None
    if not capable:
        return Finding(PASS, "CAP004", cell,
                       f"auto resolved {spec.name!r} on an empty cell")
    best = max(capable, key=lambda s: s.priority)
    if spec.name != best.name:
        return Finding(PASS, "CAP004", cell,
                       f"auto picked {spec.name!r} (priority "
                       f"{spec.priority}) over {best.name!r} (priority "
                       f"{best.priority})")
    return None


def _check_cim_packed_trit2_rejection() -> list:
    """The loud-rejection footnote: a trit2 PackedTernary under a cim
    plan must raise (base3 carries the multi-trit planes cim needs)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import execute, plan_matmul
    from repro.kernels.ops import PackedTernary, TRIT2_PER_BYTE
    m, k, n = EVAL_SHAPE
    plan = plan_matmul(EVAL_SHAPE, op="cim")
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = PackedTernary(
        jax.ShapeDtypeStruct((k // TRIT2_PER_BYTE, n), jnp.uint8),
        jax.ShapeDtypeStruct((n,), jnp.float32), "trit2")
    try:
        jax.eval_shape(lambda xx, ww: execute(plan, xx, ww), x, w)
    except ValueError as e:
        if "base3" in str(e):
            return []
        return [Finding(PASS, "CAP005", "op=cim packed=trit2",
                        f"rejection does not name base3: {e}")]
    return [Finding(PASS, "CAP005", "op=cim packed=trit2",
                    "trit2-packed weights were accepted by a cim plan")]


# ----------------------------------------------------- README matrix

def render_capability_matrix(notes: Optional[dict] = None) -> str:
    """The markdown capability table, generated from the live registry
    (highest priority first — the order 'auto' prefers).  ``notes``
    maps backend name -> prose cell; unknown backends get ''."""
    notes = notes or {}
    registry = _registry()
    head = ("| backend | ops | domains | packings | platforms "
            "| kv layouts | fidelities | notes |")
    sep = ("|---------|-----|---------|----------|-----------"
           "|------------|------------|-------|")
    rows = [head, sep]
    for spec in sorted(registry.values(), key=lambda s: -s.priority):
        cells = [f"`{spec.name}`"]
        for vals in (spec.ops, spec.domains, spec.packings,
                     spec.platforms, spec.kv_layouts, spec.fidelities):
            cells.append(", ".join(sorted(vals)))
        cells.append(notes.get(spec.name, ""))
        rows.append("| " + " | ".join(cells) + " |")
    return "\n".join(rows)


def parse_capability_matrix(text: str) -> dict:
    """Parse the backend table out of README markdown: backend name ->
    {column -> frozenset of entries} for the machine-checked columns.
    Raises ValueError if no recognizable table is present."""
    lines = text.splitlines()
    header = None
    for i, line in enumerate(lines):
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if cells and cells[0].lower() == "backend":
            header = [c.lower() for c in cells]
            start = i
            break
    if header is None:
        raise ValueError("no capability matrix table (header row "
                         "starting with 'backend') found")
    missing = [c for c in MATRIX_COLUMNS if c not in header]
    if missing:
        raise ValueError(f"capability matrix is missing columns "
                         f"{missing}; has {header}")
    out = {}
    for line in lines[start + 2:]:
        if not line.strip().startswith("|"):
            break
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) < len(header):
            break
        row = dict(zip(header, cells))
        name = row["backend"].strip("`")
        out[name] = {
            col: frozenset(v.strip() for v in row[col].split(",")
                           if v.strip())
            for col in MATRIX_COLUMNS}
    if not out:
        raise ValueError("capability matrix table has no backend rows")
    return out


def parse_matrix_notes(text: str) -> dict:
    """backend -> notes cell of an existing matrix (for re-rendering)."""
    lines = text.splitlines()
    notes = {}
    for line in lines:
        m = re.match(r"\|\s*`([a-z0-9_]+)`\s*\|", line)
        if m:
            cells = [c.strip() for c in line.strip().strip("|").split("|")]
            notes[m.group(1)] = cells[-1]
    return notes


def _check_readme_matrix(registry, readme_path: str) -> list:
    findings = []
    where = os.path.relpath(readme_path, REPO_ROOT) \
        if os.path.isabs(readme_path) else readme_path
    try:
        with open(readme_path) as f:
            table = parse_capability_matrix(f.read())
    except (OSError, ValueError) as e:
        return [Finding(PASS, "CAP006", where,
                        f"cannot check capability matrix: {e}")]
    documented = set(table)
    live = set(registry)
    for name in sorted(live - documented):
        findings.append(Finding(PASS, "CAP006", where,
                                f"registered backend {name!r} missing "
                                f"from the capability matrix"))
    for name in sorted(documented - live):
        findings.append(Finding(PASS, "CAP006", where,
                                f"matrix documents unregistered backend "
                                f"{name!r}"))
    attr = {"ops": "ops", "domains": "domains", "packings": "packings",
            "platforms": "platforms", "kv layouts": "kv_layouts",
            "fidelities": "fidelities"}
    for name in sorted(documented & live):
        spec = registry[name]
        for col, field in attr.items():
            want = frozenset(getattr(spec, field))
            got = table[name][col]
            if want != got:
                findings.append(Finding(
                    PASS, "CAP006", where,
                    f"backend {name!r} column {col!r} drifted: matrix "
                    f"says {sorted(got)}, registry says {sorted(want)}"))
    return findings


# ------------------------------------------------------------- runner

def run(readme_path: Optional[str] = None,
        registry: Optional[dict] = None) -> list:
    """Run the full capability pass; returns findings (empty = clean).

    ``readme_path`` / ``registry`` exist for violation injection in
    tests; the defaults are the live registry and the tracked README.
    """
    registry = registry if registry is not None else _registry()
    (ops, domains, packings, kv_layouts, fidelities,
     platforms) = _lattice_axes(registry)
    import jax
    current = jax.default_backend()
    findings = []
    cells = 0
    for op in ops:
        for domain in domains:
            for packing in packings:
                for kv_layout in kv_layouts:
                    for fidelity in fidelities:
                        for platform in platforms:
                            for name, spec in sorted(registry.items()):
                                cells += 1
                                if spec.supports(op, domain, packing,
                                                 platform, kv_layout,
                                                 fidelity):
                                    f = _check_declared_cell(
                                        name, op, domain, packing,
                                        kv_layout, fidelity, platform,
                                        current)
                                else:
                                    f = _check_undeclared_cell(
                                        name, op, domain, packing,
                                        kv_layout, fidelity, platform)
                                if f:
                                    findings.append(f)
                            f = _check_auto_cell(registry, op, domain,
                                                 packing, kv_layout,
                                                 fidelity, platform)
                            if f:
                                findings.append(f)
    findings.extend(_check_cim_packed_trit2_rejection())
    findings.extend(_check_readme_matrix(
        registry, readme_path or README_PATH))
    return findings
