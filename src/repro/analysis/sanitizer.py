"""Pass 3 — transfer/retrace sanitizer.

:func:`sanitize` is a context manager that turns the serving stack's
accounting *claims* into enforced invariants:

  * **device->host transfers** — every explicit sync in the repo
    routes through ``jax.device_get`` (the engines' audited
    ``_device_get`` chokepoint, enforced by lint rule RA002); the
    sanitizer wraps it to count calls.  Implicit device->host
    transfers are additionally put under ``jax.transfer_guard``
    (meaningful on accelerator platforms; the CPU host aliases device
    and host memory, so counting the explicit chokepoint is the
    binding check there).
  * **retraces/compiles** — a ``jax.monitoring`` listener counts
    compile requests, so "zero retraces after warmup" is an assertion,
    not a hope.  Any compile event inside a sanitized region after
    warmup means a jitted function saw a new (shape, static-arg) key.

Usage (the pattern tests/test_analysis.py pins around
``serve.Scheduler`` / ``serve.PagedScheduler``)::

    with sanitize() as rep:
        scheduler.run()
    assert rep.transfers == scheduler.chunks_run   # one per chunk
    assert rep.compiles == 0                       # no retrace

Pass expectations at entry and violations raise :class:`SanitizeError`
on exit::

    with sanitize(max_transfers=n_chunks, max_compiles=0):
        scheduler.run()

``run()`` is the CLI pass: it drives a warmed dense ``Scheduler`` and
``PagedScheduler`` on the smoke model under ``sanitize`` and converts
violations of the one-transfer-per-chunk / zero-retrace contracts into
findings.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

from .base import Finding

PASS = "sanitize"

# any monitoring event with this marker is one XLA compile request
_COMPILE_EVENT_MARKER = "compile_requests"

_compile_count = 0
_listener_registered = False


def _on_event(name: str, **kw) -> None:
    global _compile_count
    if _COMPILE_EVENT_MARKER in name:
        _compile_count += 1


def _ensure_listener() -> None:
    # jax.monitoring has no per-listener deregistration; register one
    # module-level counter once and let sanitize() snapshot it
    global _listener_registered
    if not _listener_registered:
        import jax.monitoring
        jax.monitoring.register_event_listener(_on_event)
        _listener_registered = True


class SanitizeError(AssertionError):
    """A sanitized region broke its transfer/retrace budget."""


@dataclasses.dataclass
class SanitizeReport:
    """Counters observed inside one ``sanitize()`` region."""
    transfers: int = 0      # explicit jax.device_get calls
    compiles: int = 0       # XLA compile requests (retraces after warmup)


@contextlib.contextmanager
def sanitize(*, max_transfers: Optional[int] = None,
             max_compiles: Optional[int] = None,
             transfer_guard: str = "disallow"):
    """Count device->host transfers and compiles inside the region.

    ``max_transfers`` / ``max_compiles``, when given, are enforced on
    exit with :class:`SanitizeError`.  ``transfer_guard`` is the
    ``jax.transfer_guard_device_to_host`` level applied to implicit
    transfers ('disallow' by default; pass 'allow' to only count).
    """
    import jax
    _ensure_listener()
    rep = SanitizeReport()
    orig = jax.device_get   # lint: allow RA002 (the sanitizer IS the auditor: it wraps the chokepoint to count transfers)
    compile_base = _compile_count

    def counted_device_get(x):
        rep.transfers += 1
        return orig(x)

    jax.device_get = counted_device_get   # lint: allow RA002 (installing the counting wrapper, not performing a transfer)
    try:
        with jax.transfer_guard_device_to_host(transfer_guard):
            yield rep
    finally:
        jax.device_get = orig   # lint: allow RA002 (restoring the unwrapped function)
        rep.compiles = _compile_count - compile_base
    if max_transfers is not None and rep.transfers > max_transfers:
        raise SanitizeError(
            f"sanitized region performed {rep.transfers} device->host "
            f"transfers; budget is {max_transfers}")
    if max_compiles is not None and rep.compiles > max_compiles:
        raise SanitizeError(
            f"sanitized region triggered {rep.compiles} compiles; "
            f"budget is {max_compiles} (retrace after warmup)")


# ----------------------------------------------------- the CLI pass

def _smoke_requests(cfg, uids, prompt_len: int = 8, max_new: int = 6):
    import jax
    from repro.serve import Request
    key = jax.random.key(0)
    return [Request(uid=u,
                    prompt=jax.random.randint(jax.random.fold_in(key, u),
                                              (prompt_len,), 0,
                                              cfg.vocab_size),
                    max_new=max_new) for u in uids]


def _check_scheduler(make_sched, label: str, inject=()) -> list:
    """Warm one scheduler on a fixed workload, then replay the same
    shapes under ``sanitize`` and check the per-chunk transfer contract
    and zero retraces."""
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import registry as model_registry
    findings = []
    cfg = dataclasses.replace(configs.smoke("internlm2-1.8b"),
                              dtype=jnp.float32)
    model = model_registry.build(cfg)
    params = model.init(jax.random.key(0))
    sched = make_sched(model, params)
    # warmup: compiles every (prefill-length x chunk-loop) key
    for r in _smoke_requests(cfg, range(3)):
        sched.submit(r)
    sched.run()
    chunks_before = sched.chunks_run
    transfers_before = sched.host_transfers
    with sanitize() as rep:
        for r in _smoke_requests(cfg, range(10, 13)):
            sched.submit(r)
        sched.run()
        if "transfer" in inject:
            # seeded violation: an extra device->host sync outside the
            # audited per-chunk transfer
            jax.device_get(sched.tok)   # lint: allow RA002 (violation injection for the sanitize pass self-test)
        if "retrace" in inject:
            # seeded violation: a fresh jit key compiles mid-region
            jax.jit(lambda x: x + 1)(1.0)
    chunks = sched.chunks_run - chunks_before
    engine_transfers = sched.host_transfers - transfers_before
    if rep.transfers != chunks:
        findings.append(Finding(
            PASS, "SAN001", label,
            f"{rep.transfers} device->host transfers over {chunks} "
            f"chunks; the contract is exactly one per chunk"))
    if engine_transfers != chunks:
        findings.append(Finding(
            PASS, "SAN001", label,
            f"engine accounting drifted: host_transfers counted "
            f"{engine_transfers}, chunks_run {chunks}"))
    if rep.compiles:
        findings.append(Finding(
            PASS, "SAN002", label,
            f"{rep.compiles} compile requests after warmup (retrace: "
            f"some jitted function saw a new shape/static key)"))
    return findings


def run(inject=()) -> list:
    """The sanitize pass: dense and paged schedulers on the smoke
    model, one-transfer-per-chunk and zero-retrace enforced.
    ``inject`` seeds violations ('transfer', 'retrace') for the CLI
    self-test (``--inject-sanitize``)."""
    from repro.serve import PagedScheduler, Scheduler
    findings = _check_scheduler(
        lambda model, params: Scheduler(model, params, capacity=64,
                                        slots=2, chunk=4),
        "serve.Scheduler[dense]", inject=inject)
    findings += _check_scheduler(
        lambda model, params: PagedScheduler(model, params, capacity=64,
                                             slots=2, chunk=4,
                                             page_size=16),
        "serve.PagedScheduler[paged]", inject=inject)
    return findings
