"""Pass 2 — Pallas block/index-map analyzer.

Symbolically checks every ``select_block_shapes`` outcome (and any
pinned override) over a representative shape sweep for the three
Pallas kernels (``ternary_matmul`` float, ``ternary_matmul_int8``,
``cim_mac``), against the invariants the kernels' correctness rests
on:

  * BM001 — tile alignment: positive blocks, ``bm`` a sublane
    multiple for the arithmetic domain (f32: 8, int8: 32), ``bn``/
    ``bk`` lane multiples (128 — which also keeps the trit2 packed
    tile ``bk/4`` whole), and ``bk`` a ``ROWS_PER_GROUP`` (16)
    multiple for the cim kernel;
  * BM002 — exact grid coverage: the padded iteration space is
    covered by grid x block with zero residue and less than one
    block of overhang per axis;
  * BM003 — index maps in bounds: every BlockSpec index map, at every
    corner of the grid, lands its block inside the padded operand;
  * BM004 — the double-buffered VMEM working set fits the budget the
    selector promises (unless already at the ``bk`` floor);
  * BM005 — masking identities: the padded regions provably
    contribute zero — the w pad byte decodes to exactly 0 in both
    packing modes and both arithmetic domains, x pads with zeros,
    and the cim ADC clip window contains 0 so zero-padded K groups
    pass through unclipped;
  * BM006 — dtype consistency: the kernel abstract-evaluates (under
    ``jax.eval_shape``, no execution) to the contracted output dtype
    for the domain (f32 epilogue for ternary, int32 for the raw cim
    MAC).

``pin_blocks`` injects a block choice over the whole sweep (the
violation-seeding hook the CLI exposes as ``--pin-blocks``).
"""
from __future__ import annotations

import itertools
from typing import Optional

from .base import Finding

PASS = "blockmap"

# (M, K, N) sweep: decode-skinny M, ragged every-axis shapes, exact
# tile multiples, prefill-sized M, deep-K decode shapes
SHAPE_SWEEP = (
    (1, 13, 50),
    (1, 64, 128),
    (4, 4096, 1),
    (7, 96, 333),
    (8, 256, 1000),
    (16, 1024, 128),
    (100, 4096, 16),
    (128, 512, 256),
    (333, 77, 129),
    (256, 4096, 1024),
)

# shapes small enough to also push through jax.eval_shape per cell
EVAL_SHAPES = ((1, 13, 50), (7, 96, 333), (128, 512, 256))


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _cell(kernel, mode, domain, shape, blocks):
    m, k, n = shape
    return (f"{kernel} mode={mode} domain={domain} shape=({m},{k},{n}) "
            f"blocks={tuple(blocks)}")


def _check_alignment(cell, bm, bn, bk, mode, domain, *, cim=False) -> list:
    from repro.kernels.cim_mac import ROWS_PER_GROUP
    from repro.kernels.ternary_matmul import (INT8_SUBLANE, MXU_LANE,
                                              SUBLANE, TRIT2_PER_BYTE)
    out = []
    if min(bm, bn, bk) < 1:
        return [Finding(PASS, "BM001", cell, "non-positive block shape")]
    sublane = INT8_SUBLANE if domain == "int8" else SUBLANE
    if bm % sublane:
        out.append(Finding(PASS, "BM001", cell,
                           f"bm={bm} is not a multiple of the {domain} "
                           f"sublane quantum {sublane}"))
    if bn % MXU_LANE:
        out.append(Finding(PASS, "BM001", cell,
                           f"bn={bn} is not lane-aligned ({MXU_LANE})"))
    if bk % MXU_LANE:
        out.append(Finding(PASS, "BM001", cell,
                           f"bk={bk} is not lane-aligned ({MXU_LANE})"))
    if mode == "trit2" and bk % TRIT2_PER_BYTE:
        out.append(Finding(PASS, "BM001", cell,
                           f"bk={bk} splits the trit2 packed byte "
                           f"({TRIT2_PER_BYTE} trits/byte)"))
    if cim and bk % ROWS_PER_GROUP:
        out.append(Finding(PASS, "BM001", cell,
                           f"bk={bk} splits the cim ADC row group "
                           f"({ROWS_PER_GROUP} rows)"))
    return out


def _check_coverage_and_maps(cell, m, kdim, n, mode, bm, bn, bk) -> list:
    """Recompute the kernels' pad rule from first principles, then
    drive every BlockSpec index map over the grid corners and check
    each block lands inside the padded operand."""
    from repro.kernels.ternary_matmul import TRIT2_PER_BYTE
    out = []
    mp = _round_up(m, bm)
    np_ = _round_up(n, bn)
    kp = _round_up(kdim, bk)
    grid = (mp // bm, np_ // bn, kp // bk)
    # exact coverage: zero residue, less than one block of overhang
    for name, padded, extent, blk, cells in (
            ("M", mp, m, bm, grid[0]), ("N", np_, n, bn, grid[1]),
            ("K", kp, kdim, bk, grid[2])):
        if padded % blk or cells * blk != padded:
            out.append(Finding(PASS, "BM002", cell,
                               f"grid does not tile the padded {name} "
                               f"axis exactly: {cells} x {blk} != "
                               f"{padded}"))
        if padded - extent >= blk:
            out.append(Finding(PASS, "BM002", cell,
                               f"{name} axis pads {padded - extent} >= "
                               f"one full block ({blk}): wasted grid "
                               f"cells"))
    bkw = bk // TRIT2_PER_BYTE if mode == "trit2" else bk
    kwp = kp // TRIT2_PER_BYTE if mode == "trit2" else kp
    # (block_shape, index_map, padded operand extents) per BlockSpec,
    # mirroring the pallas_call in kernels/ternary_matmul.py
    specs = (
        ("x", (bm, bk), lambda i, j, k: (i, k), (mp, kp)),
        ("w", (bkw, bn), lambda i, j, k: (k, j), (kwp, np_)),
        ("scale", (bn,), lambda i, j, k: (j,), (np_,)),
        ("out", (bm, bn), lambda i, j, k: (i, j), (mp, np_)),
    )
    corners = itertools.product(*((0, g - 1) for g in grid))
    for gi, gj, gk in corners:
        for name, blk, index_map, extents in specs:
            idx = index_map(gi, gj, gk)
            for axis, (bidx, bsz, ext) in enumerate(zip(idx, blk,
                                                        extents)):
                if bidx < 0 or (bidx + 1) * bsz > ext:
                    out.append(Finding(
                        PASS, "BM003", cell,
                        f"{name} index map at grid ({gi},{gj},{gk}) "
                        f"puts block {bidx} (size {bsz}) outside the "
                        f"padded axis-{axis} extent {ext}"))
    return out


def _check_vmem(cell, bm, bn, bk, mode, domain) -> list:
    from repro.kernels.ternary_matmul import (MXU_LANE,
                                              VMEM_BUDGET_BYTES,
                                              _vmem_working_set)
    used = _vmem_working_set(bm, bn, bk, mode, domain)
    if used > VMEM_BUDGET_BYTES and bk > MXU_LANE:
        return [Finding(PASS, "BM004", cell,
                        f"working set {used} B exceeds the "
                        f"{VMEM_BUDGET_BYTES} B budget with bk={bk} "
                        f"still above the {MXU_LANE} floor")]
    return []


def _check_masking(cell, mode, domain) -> list:
    """Prove the pad regions contribute zero: run the kernel's own
    decode on a tile of the pad byte (tiny concrete arrays — decode
    only, never a matmul)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.ternary_matmul import (BASE3_OFFSET,
                                              TRIT2_PER_BYTE, _decode_w)
    out = []
    pad_val = BASE3_OFFSET if mode == "base3" else 0
    tile = jnp.full((TRIT2_PER_BYTE, 8), pad_val, jnp.uint8)
    dtype = jnp.int8 if domain == "int8" else jnp.float32
    dec = np.asarray(_decode_w(tile, mode, dtype))
    if dec.any():
        out.append(Finding(PASS, "BM005", cell,
                           f"pad byte {pad_val} decodes to nonzero "
                           f"values in {dtype}: padded K rows would "
                           f"contribute to the dot"))
    return out


def _check_pad_rule(cell, mode) -> list:
    """Drive ``_pad_to_blocks`` on a tiny ragged operand and verify the
    padded regions hold exactly the zero-decoding constants."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.ternary_matmul import (BASE3_OFFSET,
                                              TRIT2_PER_BYTE,
                                              _pad_to_blocks)
    out = []
    m, kdim, n = 3, 8, 5
    kw = kdim // TRIT2_PER_BYTE if mode == "trit2" else kdim
    x = jnp.ones((m, kdim), jnp.float32)
    w = jnp.full((kw, n), 7, jnp.uint8)
    scale = jnp.ones((n,), jnp.float32)
    xp, wp, sp, mp = _pad_to_blocks(x, w, scale, mode, 8, 8, 16)
    pad_val = BASE3_OFFSET if mode == "base3" else 0
    if np.asarray(xp)[:, kdim:].any() or np.asarray(xp)[m:, :].any():
        out.append(Finding(PASS, "BM005", cell,
                           "x pad region is not zero"))
    wnp = np.asarray(wp)
    if (wnp[kw:, :] != pad_val).any() or (wnp[:, n:] != pad_val).any():
        out.append(Finding(PASS, "BM005", cell,
                           f"w pad region is not the zero-decoding "
                           f"byte {pad_val}"))
    if np.asarray(sp)[n:].any():
        out.append(Finding(PASS, "BM005", cell,
                           "scale pad region is not zero"))
    return out


def _check_cim_clip_window(cell, adc_bits: int = 5) -> list:
    from repro.kernels.cim_mac import ROWS_PER_GROUP
    lo = ROWS_PER_GROUP - 2 ** adc_bits + 1
    hi = ROWS_PER_GROUP
    if not (lo <= 0 <= hi):
        return [Finding(PASS, "BM005", cell,
                        f"ADC clip window [{lo}, {hi}] excludes 0: "
                        f"zero-padded K groups would saturate")]
    return []


def _check_abstract_eval(cell, m, k, n, mode, domain, bm, bn, bk) -> list:
    """Abstract-eval the real kernel with these blocks (pallas
    validates BlockSpec consistency at trace time; nothing runs)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ternary_matmul import (TRIT2_PER_BYTE,
                                              ternary_matmul,
                                              ternary_matmul_int8)
    kdim = _round_up(k, TRIT2_PER_BYTE) if mode == "trit2" else k
    kw = kdim // TRIT2_PER_BYTE if mode == "trit2" else kdim
    x_dt = jnp.int8 if domain == "int8" else jnp.float32
    x = jax.ShapeDtypeStruct((m, kdim), x_dt)
    w = jax.ShapeDtypeStruct((kw, n), jnp.uint8)
    scale = jax.ShapeDtypeStruct((n,), jnp.float32)
    try:
        if domain == "int8":
            xs = jax.ShapeDtypeStruct((m,), jnp.float32)
            out = jax.eval_shape(
                lambda a, b, c, d: ternary_matmul_int8(
                    a, b, c, d, mode=mode, bm=bm, bn=bn, bk=bk,
                    interpret=True), x, xs, w, scale)
        else:
            out = jax.eval_shape(
                lambda a, b, c: ternary_matmul(
                    a, b, c, mode=mode, bm=bm, bn=bn, bk=bk,
                    interpret=True), x, w, scale)
    except Exception as e:
        return [Finding(PASS, "BM006", cell,
                        f"kernel failed abstract eval with these "
                        f"blocks: {e!r}")]
    if tuple(out.shape) != (m, n) or out.dtype != jnp.float32:
        return [Finding(PASS, "BM006", cell,
                        f"kernel abstract-evals to {out.shape} "
                        f"{out.dtype}, expected ({m}, {n}) float32")]
    return []


def _check_cim_abstract_eval(cell, m, k, n, bm, bn, bk) -> list:
    import jax
    import jax.numpy as jnp
    from repro.kernels.cim_mac import cim_mac
    x = jax.ShapeDtypeStruct((5, m, k), jnp.int8)
    w = jax.ShapeDtypeStruct((5, k, n), jnp.int8)
    try:
        out = jax.eval_shape(
            lambda a, b: cim_mac(a, b, adc_bits=5, bm=bm, bn=bn, bk=bk,
                                 interpret=True), x, w)
    except Exception as e:
        return [Finding(PASS, "BM006", cell,
                        f"cim_mac failed abstract eval: {e!r}")]
    if tuple(out.shape) != (m, n) or out.dtype != jnp.int32:
        return [Finding(PASS, "BM006", cell,
                        f"cim_mac abstract-evals to {out.shape} "
                        f"{out.dtype}, expected ({m}, {n}) int32")]
    return []


def check_ternary_cell(m: int, k: int, n: int, mode: str, domain: str,
                       blocks: Optional[tuple] = None) -> list:
    """All invariants for one ternary-kernel cell; ``blocks`` pins the
    tile choice (violation injection), default = the live selector."""
    from repro.kernels.ternary_matmul import (TRIT2_PER_BYTE,
                                              select_block_shapes)
    kdim = _round_up(k, TRIT2_PER_BYTE) if mode == "trit2" else k
    if blocks is None:
        blocks = select_block_shapes(m, kdim, n, mode, domain=domain)
    bm, bn, bk = blocks
    kernel = "ternary_matmul_int8" if domain == "int8" else \
        "ternary_matmul"
    cell = _cell(kernel, mode, domain, (m, k, n), blocks)
    findings = _check_alignment(cell, bm, bn, bk, mode, domain)
    if any(f.rule == "BM001" and "non-positive" in f.message
           for f in findings):
        return findings           # everything downstream divides by these
    findings += _check_coverage_and_maps(cell, m, kdim, n, mode,
                                         bm, bn, bk)
    findings += _check_vmem(cell, bm, bn, bk, mode, domain)
    findings += _check_masking(cell, mode, domain)
    findings += _check_pad_rule(cell, mode)
    if not findings and (m, k, n) in EVAL_SHAPES:
        findings += _check_abstract_eval(cell, m, k, n, mode, domain,
                                         bm, bn, bk)
    return findings


def check_cim_cell(m: int, k: int, n: int,
                   blocks: Optional[tuple] = None) -> list:
    from repro.kernels.plan import CIM_DEFAULT_BLOCKS
    if blocks is None:
        blocks = CIM_DEFAULT_BLOCKS
    bm, bn, bk = blocks
    cell = _cell("cim_mac", "planes", "int32", (m, k, n), blocks)
    findings = _check_alignment(cell, bm, bn, bk, "base3", "float",
                                cim=True)
    if any(f.rule == "BM001" and "non-positive" in f.message
           for f in findings):
        return findings
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    grid = (mp // bm, np_ // bn, kp // bk)
    specs = (
        ("x", (None, bm, bk), lambda i, j, k: (0, i, k), (1, mp, kp)),
        ("w", (None, bk, bn), lambda i, j, k: (0, k, j), (1, kp, np_)),
        ("out", (bm, bn), lambda i, j, k: (i, j), (mp, np_)),
    )
    for gi, gj, gk in itertools.product(*((0, g - 1) for g in grid)):
        for name, blk, index_map, extents in specs:
            idx = index_map(gi, gj, gk)
            for bidx, bsz, ext in zip(idx, blk, extents):
                if bsz is None:
                    continue      # whole-axis (trit-plane) dimension
                if bidx < 0 or (bidx + 1) * bsz > ext:
                    findings.append(Finding(
                        PASS, "BM003", cell,
                        f"{name} index map at grid ({gi},{gj},{gk}) "
                        f"out of bounds"))
    findings += _check_cim_clip_window(cell)
    if not findings and m <= 32 and k <= 256 and n <= 256:
        findings += _check_cim_abstract_eval(cell, m, k, n, bm, bn, bk)
    return findings


def run(pin_blocks: Optional[tuple] = None) -> list:
    """The full blockmap pass over the shape sweep (every packing x
    domain cell of both ternary kernels, plus the cim kernel).
    ``pin_blocks`` overrides the selector everywhere — the violation
    injection the CLI exposes as ``--pin-blocks BM,BN,BK``."""
    findings = []
    for m, k, n in SHAPE_SWEEP:
        for mode in ("base3", "trit2"):
            for domain in ("float", "int8"):
                findings += check_ternary_cell(m, k, n, mode, domain,
                                               blocks=pin_blocks)
    for m, k, n in ((1, 13, 50), (8, 160, 64), (16, 256, 256),
                    (100, 4096, 16)):
        findings += check_cim_cell(m, k, n, blocks=pin_blocks)
    return findings
