"""Shared abstract-eval cache for the shard + jaxpr passes.

Both new static passes work entirely on abstract values — ParamDef
trees, ShapeDtypeStructs, closed jaxprs — and both need the same
expensive-to-build objects: model definitions per architecture and the
reduced smoke model the jitted entry points are traced against.  This
module memoizes them so one `make analyze` run builds each exactly
once no matter how many passes (or injection reruns in tests) consume
them; ``stats()`` exposes the hit counts the CLI surfaces next to the
per-pass timings.

Nothing here allocates device memory: models are definition objects,
"params"/"caches" are ShapeDtypeStructs, and tracing happens under
``jax.eval_shape``-equivalent machinery in the passes themselves.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ParamDef, abstract_params, is_def

# The reduced architecture the jaxpr pass traces entry points against —
# same one the sanitize/frontend passes drive dynamically, in f32 so
# dtype-discipline findings are real promotions, not bf16 casts.
SMOKE_ARCH = "internlm2-1.8b"


@lru_cache(maxsize=None)
def config(arch: str):
    from repro import configs
    return configs.get(arch)


@lru_cache(maxsize=None)
def model(arch: str):
    """Full-size model object (ParamDef/cache_defs only; no weights)."""
    from repro.models import registry
    return registry.build(config(arch))


@lru_cache(maxsize=None)
def smoke_model():
    from repro import configs
    from repro.models import registry
    cfg = dataclasses.replace(configs.smoke(SMOKE_ARCH),
                              dtype=jnp.float32)
    return registry.build(cfg)


def _named_leaves(defs: Any) -> tuple:
    leaves = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)[0]
    return tuple((jax.tree_util.keystr(path), leaf)
                 for path, leaf in leaves if isinstance(leaf, ParamDef))


@lru_cache(maxsize=None)
def param_leaves(arch: str) -> tuple:
    """((keypath, ParamDef), ...) for one architecture's parameters."""
    return _named_leaves(model(arch).param_defs)


@lru_cache(maxsize=None)
def cache_leaves(arch: str, batch: int, capacity: int) -> tuple:
    """((keypath, ParamDef), ...) for the decode-state defs."""
    return _named_leaves(model(arch).cache_defs(batch, capacity))


def abstract(defs: Any, dtype=jnp.float32) -> Any:
    """ParamDef tree -> plain ShapeDtypeStructs (no shardings)."""
    return abstract_params(defs, dtype)


def stats() -> dict:
    """Per-entry lru_cache counters (the CLI's cache-sharing report)."""
    out = {}
    for fn in (config, model, smoke_model, param_leaves, cache_leaves):
        info = fn.cache_info()
        out[fn.__name__] = {"hits": info.hits, "misses": info.misses,
                            "size": info.currsize}
    return out


def clear() -> None:
    for fn in (config, model, smoke_model, param_leaves, cache_leaves):
        fn.cache_clear()
