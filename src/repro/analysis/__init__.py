"""Static contract checker + sanitizer for plans, kernels, and serve
loops (`python -m repro.analysis`, `make analyze`).

Six passes, each a ``run() -> list[Finding]``:

  * ``capability`` — the (op x backend x domain x packing x kv_layout
    x platform) lattice from the live kernel registry: declared cells
    resolve and abstract-eval, undeclared cells fail loudly, and the
    markdown matrix in src/repro/kernels/README.md matches.
  * ``blockmap`` — ``select_block_shapes`` outputs over a shape sweep:
    alignment, exact grid coverage, in-bounds index maps, VMEM budget,
    and the padded-region masking identities.
  * ``autotune`` — the measured block-shape table
    (``BENCH_autotune.json``): structure, the same alignment/VMEM
    invariants, duplicate cells, current-platform sweep coverage, and
    canonical serialization.  The runtime loader degrades quietly to
    the heuristic; this pass is where a doctored table fails loudly.
  * ``sanitize`` — the serve transfer/retrace contract: exactly one
    device->host transfer per chunk, zero retraces after warmup, on
    both ``Scheduler`` and ``PagedScheduler``.  The :func:`sanitize`
    context manager is also importable for tests.
  * ``lint`` — AST rules for the standing constraints (no blind
    except swallows, no device_get outside the audited chokepoint, no
    routing kwargs around the plan API, no unseeded benchmark RNG, and
    the front-end purity rules of RA005).
  * ``frontend`` — the serving front-end's dynamic contracts:
    streaming adds zero transfers (one per chunk survives the
    front-end), the pending queue stays bounded with every reject
    accounted, and admission replays deterministically under a virtual
    clock.

Rule catalog and suppression syntax: src/repro/analysis/README.md.
"""
from .base import Finding, rel  # noqa: F401
from .sanitizer import (SanitizeError, SanitizeReport,  # noqa: F401
                        sanitize)
from . import (autotune_table, blockmap, capability,  # noqa: F401
               frontend, lint, sanitizer)

# CLI/run order: cheap static passes first, the model-building
# dynamic passes last
PASSES = (("capability", capability.run),
          ("blockmap", blockmap.run),
          ("autotune", autotune_table.run),
          ("lint", lint.run),
          ("sanitize", sanitizer.run),
          ("frontend", frontend.run))


def run_all() -> list:
    """Every pass with default settings; the aggregate findings."""
    findings = []
    for _, fn in PASSES:
        findings.extend(fn())
    return findings
