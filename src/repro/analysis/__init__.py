"""Static contract checker + sanitizer for plans, kernels, sharding
rules, and serve loops (`python -m repro.analysis`, `make analyze`).

Eight passes, each a ``run() -> list[Finding]``:

  * ``capability`` — the (op x backend x domain x packing x kv_layout
    x platform) lattice from the live kernel registry: declared cells
    resolve and abstract-eval, undeclared cells fail loudly, and the
    markdown matrix in src/repro/kernels/README.md matches.
  * ``blockmap`` — ``select_block_shapes`` outputs over a shape sweep:
    alignment, exact grid coverage, in-bounds index maps, VMEM budget,
    and the padded-region masking identities.
  * ``autotune`` — the measured block-shape table
    (``BENCH_autotune.json``): structure, the same alignment/VMEM
    invariants, duplicate cells, current-platform sweep coverage, and
    canonical serialization.  The runtime loader degrades quietly to
    the heuristic; this pass is where a doctored table fails loudly.
  * ``lint`` — AST rules for the standing constraints (no blind
    except swallows, no device_get outside the audited chokepoint, no
    routing kwargs around the plan API, no unseeded benchmark RNG, and
    the front-end purity rules of RA005), plus the dead-suppression
    audit: an ``# lint: allow`` or rules.toml entry matching no
    finding is itself a finding.
  * ``shard`` — the sharding-contract prover: every (rules variant x
    mesh x model config) cell of the live ``dist.variants`` lattice
    resolves abstractly, resolved specs re-verify independently, no
    large parameter replicates on a multi-chip mesh, the
    slot/page-pool mirrors agree with the engine, every logical axis
    named in ``src/`` is known, and the dist/README axis table
    matches.
  * ``jaxpr`` — static dataflow audit of the audited jitted entry
    points (serve/train/frontend manifests): declared donations
    actually alias, no f64/weak-type widening, no callback primitives,
    and the transfer contract holds in the closed jaxpr.
  * ``sanitize`` — the serve transfer/retrace contract: exactly one
    device->host transfer per chunk, zero retraces after warmup, on
    both ``Scheduler`` and ``PagedScheduler``.  The :func:`sanitize`
    context manager is also importable for tests.
  * ``frontend`` — the serving front-end's dynamic contracts:
    streaming adds zero transfers (one per chunk survives the
    front-end), the pending queue stays bounded with every reject
    accounted, and admission replays deterministically under a virtual
    clock.

The ``shard`` and ``jaxpr`` passes share one abstract-eval cache
(:mod:`.abscache`) so model definitions are built once per run.

Rule catalog and suppression syntax: src/repro/analysis/README.md.
"""
from .base import Finding, rel  # noqa: F401
from .sanitizer import (SanitizeError, SanitizeReport,  # noqa: F401
                        sanitize)
from . import (abscache, autotune_table, blockmap,  # noqa: F401
               capability, frontend, jaxpr_audit, lint, sanitizer,
               shardspec)

# CLI/run order: cheap static passes first, the model-building
# dynamic passes last (shard/jaxpr are static but build abstract
# models, so they sit between the pure-AST passes and the dynamic
# smoke drivers)
PASSES = (("capability", capability.run),
          ("blockmap", blockmap.run),
          ("autotune", autotune_table.run),
          ("lint", lint.run),
          ("shard", shardspec.run),
          ("jaxpr", jaxpr_audit.run),
          ("sanitize", sanitizer.run),
          ("frontend", frontend.run))

# pass name -> wall seconds of the most recent run in this process
# (the CLI records these; `--list` reports them)
LAST_TIMINGS: dict = {}


def run_all() -> list:
    """Every pass with default settings; the aggregate findings."""
    findings = []
    for _, fn in PASSES:
        findings.extend(fn())
    return findings
