"""`jaxpr` — static dataflow audit of the jitted entry points
(JX001–JX004).

The serve/train/frontend packages name their jitted surfaces in
audited manifests (``repro.serve.manifest`` et al.: factory, abstract
inputs, declared donation + output arity).  This pass traces each
entry to a closed jaxpr — no device code runs — and proves the
contracts the dynamic ``sanitize``/``frontend`` passes can only
observe:

| rule  | contract |
|-------|----------|
| JX001 | declared buffer donations actually alias in the lowered artifact: the ``tf.aliasing_output`` count equals the donated leaf count and lowering emits no donation warning (a silently-copied donated KV pool is 2x cache memory) |
| JX002 | dtype discipline on the hot path: no float64/complex128 aval anywhere in the jaxpr (including sub-jaxprs) and no weak-typed top-level output (a python scalar escaping the graph re-promotes downstream) |
| JX003 | no host round-trip primitives inside jitted regions: ``pure_callback``/``io_callback``/``debug_callback``/infeed/outfeed never appear |
| JX004 | transfer contract: the closed jaxpr carries zero effects (the return value is the ONE per-chunk transfer — an effect is an extra channel) and the traced output arity matches the manifest's hand-audited declaration |

Violation injection (tests / ``--inject-jaxpr``): ``donation``,
``widen``, ``callback``, ``transfer``.
"""
from __future__ import annotations

import math
import warnings
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp

from .base import Finding
from . import abscache

PASS = "jaxpr"

_BANNED_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})

_BANNED_DTYPES = ("float64", "complex128")


def _subjaxprs(value) -> Iterator:
    from jax.extend import core as jex_core
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jex_core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)


def _iter_eqns(jaxpr) -> Iterator:
    """Every equation in a jaxpr, recursing through sub-jaxprs
    (while_loop bodies, scans, custom_jvp remat regions...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _subjaxprs(param):
                yield from _iter_eqns(sub)


def _check_entry(entry, model, inject: Optional[str]) -> list[Finding]:
    findings = []
    fn, args = entry.build(model)
    where = entry.name

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        traced = fn.trace(*args)
        lowered = traced.lower()
    closed = traced.jaxpr

    # ---- JX001: donation aliasing --------------------------------
    donated_leaves = sum(len(jax.tree.leaves(args[i]))
                         for i in entry.donated_argnums)
    aliased = lowered.as_text().count("tf.aliasing_output")
    if aliased != donated_leaves:
        findings.append(Finding(
            PASS, "JX001", where,
            f"{donated_leaves} donated buffer leaf(s) declared but "
            f"{aliased} alias in the lowered module — XLA will copy "
            f"the non-aliased donations"))
    for w in caught:
        if "donated" in str(w.message).lower():
            findings.append(Finding(
                PASS, "JX001", where,
                f"lowering warned about donation: {w.message}"))

    # ---- JX002: dtype discipline ---------------------------------
    bad_dtypes = set()
    for eqn in _iter_eqns(closed.jaxpr):
        for var in (*eqn.invars, *eqn.outvars):
            aval = getattr(var, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _BANNED_DTYPES:
                bad_dtypes.add((dt, eqn.primitive.name))
    for dt, prim in sorted(bad_dtypes):
        findings.append(Finding(
            PASS, "JX002", where,
            f"{dt} aval on primitive {prim!r} — an unintended "
            f"promotion doubles hot-path bandwidth"))
    for i, aval in enumerate(closed.out_avals):
        if getattr(aval, "weak_type", False) \
                and jnp.issubdtype(aval.dtype, jnp.floating):
            findings.append(Finding(
                PASS, "JX002", where,
                f"output {i} is weak-typed {aval.dtype} — a python "
                f"scalar escaped the graph and will re-promote "
                f"downstream"))

    # ---- JX003: no host round-trips ------------------------------
    banned_seen = set()
    for eqn in _iter_eqns(closed.jaxpr):
        if eqn.primitive.name in _BANNED_PRIMITIVES:
            banned_seen.add(eqn.primitive.name)
    for prim in sorted(banned_seen):
        findings.append(Finding(
            PASS, "JX003", where,
            f"host-callback primitive {prim!r} inside the jitted "
            f"region — a hidden device->host round trip per dispatch"))

    # ---- JX004: transfer contract --------------------------------
    if closed.effects:
        findings.append(Finding(
            PASS, "JX004", where,
            f"jaxpr carries effects {sorted(map(str, closed.effects))} "
            f"— the per-chunk transfer must be the only channel out"))
    outs = jax.eval_shape(fn, *args)
    arity = len(outs) if isinstance(outs, (tuple, list)) else 1
    if arity != entry.out_arity:
        findings.append(Finding(
            PASS, "JX004", where,
            f"traced output arity {arity} != manifest's audited "
            f"arity {entry.out_arity} — the host-side unpack of the "
            f"per-chunk transfer has drifted"))
    return findings


# ---------------------------------------------------------------------
# injected entries (the gate-gates-itself tests)
# ---------------------------------------------------------------------

def _injected_entry(inject: str):
    from repro.serve.manifest import AuditedEntry

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    if inject == "donation":
        def build(_model):
            # donated input used but returned in a different dtype:
            # XLA cannot alias it and warns at lower time
            fn = jax.jit(lambda a: (a.astype(jnp.bfloat16) * 2,),
                         donate_argnums=(0,))
            return fn, (x,)
        return AuditedEntry("injected.donation", build, (0,), 1)
    if inject == "widen":
        def build(_model):
            def widen(a):
                with jax.experimental.enable_x64():
                    return (a.astype(jnp.float64).sum(),)
            return jax.jit(widen), (x,)
        return AuditedEntry("injected.widen", build, (), 1)
    if inject == "callback":
        def build(_model):
            def chatty(a):
                jax.debug.print("mean={m}", m=a.mean())
                return (a * 2,)
            return jax.jit(chatty), (x,)
        return AuditedEntry("injected.callback", build, (), 1)
    if inject == "transfer":
        def build(_model):
            return jax.jit(lambda a: (a, a * 2, a.sum())), (x,)
        # declared arity 2, traced arity 3: the host unpack drifted
        return AuditedEntry("injected.transfer", build, (), 2)
    raise ValueError(f"unknown jaxpr injection {inject!r}")


def manifest_entries() -> tuple:
    """The audited jitted surface across serve, train and frontend."""
    from repro.frontend import manifest as frontend_manifest
    from repro.serve import manifest as serve_manifest
    from repro.train import manifest as train_manifest
    return (serve_manifest.entries() + train_manifest.entries()
            + frontend_manifest.entries())


# ------------------------------------------------------------- runner

def run(inject: Optional[str] = None) -> list[Finding]:
    """Trace every audited entry point and prove JX001–JX004."""
    model = abscache.smoke_model()
    entries = list(manifest_entries())
    if inject is not None:
        entries.append(_injected_entry(inject))
    findings = []
    for entry in entries:
        try:
            findings.extend(_check_entry(entry, model, inject))
        except Exception as e:                # a broken build IS a finding
            findings.append(Finding(
                PASS, "JX004", entry.name,
                f"entry fails to trace: {type(e).__name__}: {e}"))
    return findings
