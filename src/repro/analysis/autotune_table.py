"""Pass — autotune-table checker.

The measured block-shape table (``BENCH_autotune.json``, written by
``python -m repro.kernels.autotune``) is consulted by plan resolution:
a warm hit puts *measured* tiles into every pallas plan the serve path
executes.  The runtime loader is deliberately lenient — a doctored or
stale table degrades to the ``select_block_shapes`` heuristic with a
warning, because a serving box must keep serving.  THIS pass is the
loud half of that split: ``make analyze`` fails on any table the
runtime would have quietly rejected or under-used.

Checks (rule catalog in this package's README):

  * AT001/AT002/AT003 — ``kernels.autotune.validate_table``: structure
    and enum membership, the alignment + VMEM invariants the pallas
    kernels' correctness rests on, duplicate cell keys.
  * AT004 — presence + coverage: the table exists and covers every
    ``(shape, phase, packing, domain)`` cell of the tuning sweep for
    the *current* platform (a stale table silently starves plan
    resolution back onto the heuristic — visible in logs, fatal here).
  * AT005 — canonical serialization: the file is byte-identical to
    ``canonical_bytes`` of its own entries (hand-edits that reorder or
    reformat break the deterministic round trip the persistence tests
    pin).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from .base import Finding, REPO_ROOT, rel

PASS = "autotune"


def _sweep_cells(platform: str) -> list:
    from repro.kernels import autotune
    from repro.kernels.plan import DOMAINS, PACKINGS
    cells = []
    for shapes, phase in ((autotune.DECODE_SHAPES, "decode"),
                          (autotune.PREFILL_SHAPES, "prefill")):
        for (m, k, n) in shapes:
            for packing in PACKINGS:
                for domain in DOMAINS:
                    cells.append(autotune.cell_key(
                        m, k, n, phase, platform, packing, domain))
    return cells


def run(table_path: Optional[str] = None) -> list:
    """Check the tracked autotune table (or an injected one); returns
    findings (empty = clean).  ``table_path`` exists for violation
    injection in tests — the default is the tracked repo-root artifact,
    NOT ``$REPRO_AUTOTUNE_TABLE``: analyze gates what the repo ships,
    a test fixture pointing the env var elsewhere must not mask it.
    """
    from repro.kernels import autotune
    path = table_path or os.path.join(REPO_ROOT,
                                      autotune.DEFAULT_TABLE_BASENAME)
    where = rel(path)
    if not os.path.exists(path):
        return [Finding(PASS, "AT004", where,
                        "autotune table is missing; regenerate with "
                        "`python -m repro.kernels.autotune`")]
    try:
        with open(path) as f:
            text = f.read()
        payload = json.loads(text)
    except (OSError, ValueError) as e:
        return [Finding(PASS, "AT001", where,
                        f"table is not readable JSON: {e}")]
    findings = [Finding(PASS, rule, f"{where} {cell}", message)
                for rule, cell, message in autotune.validate_table(payload)]
    if findings:
        return findings            # coverage/canonical checks would
                                   # only echo the structural damage
    entries = payload["entries"]
    have = {autotune.cell_key(e["m"], e["k"], e["n"], e["phase"],
                              e["platform"], e["packing"], e["domain"])
            for e in entries}
    import jax
    platform = jax.default_backend()
    missing = [c for c in _sweep_cells(platform) if c not in have]
    for m, k, n, phase, plat, packing, domain in missing:
        findings.append(Finding(
            PASS, "AT004", where,
            f"stale table: sweep cell ({m},{k},{n}) {phase} "
            f"{packing}/{domain} has no measurement for the current "
            f"platform {plat!r}; regenerate with "
            f"`python -m repro.kernels.autotune`"))
    if text != autotune.canonical_bytes(entries):
        findings.append(Finding(
            PASS, "AT005", where,
            "table is not in canonical serialization (sorted cells, "
            "sorted keys, 2-space indent, trailing newline) — "
            "hand-edited?  `python -m repro.kernels.autotune` rewrites "
            "canonically"))
    return findings
