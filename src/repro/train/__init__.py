from .step import TrainState, make_train_step, make_abstract_state  # noqa: F401
from .runner import Trainer, TrainerConfig, FailurePlan, SimulatedFailure  # noqa: F401
from . import manifest  # noqa: F401
