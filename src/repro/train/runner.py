"""Fault-tolerant training runner.

What "fault tolerance" means here, concretely:

* **checkpoint/restart** — atomic periodic checkpoints (repro.checkpoint);
  on any failure the runner rolls back to the latest complete checkpoint
  and replays.  The data pipeline is stateless-by-step, so replay is
  bitwise identical (tested in tests/test_train_ft.py).
* **failure injection** — a FailurePlan schedules simulated node crashes
  (including crashes *mid-checkpoint-save*, which exercise atomicity)
  at specific steps; the runner treats them exactly as it would a real
  preemption: tear down, restore, continue.
* **straggler mitigation** — per-step wall time is tracked in a rolling
  window; steps slower than `straggler_factor` x median are counted and,
  past a threshold, the runner "re-slices" the workload (in a real
  deployment: re-shard away from the slow host; here: recorded in
  metrics + the mitigation hook fires, which tests assert on).
* **elastic restart** — `restore()` accepts target shardings, so a
  checkpoint written on one mesh restarts on a smaller/larger mesh
  (exercised by tests with different sharding rule sets).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.data import DataConfig, batch_for
from repro.optim import Optimizer
from .step import TrainState, init_state, make_train_step


class SimulatedFailure(RuntimeError):
    """Raised by the failure injector to emulate a node crash."""


@dataclasses.dataclass
class FailurePlan:
    """crash_at: steps that die before the update is applied;
    crash_in_save: steps whose checkpoint save dies halfway through."""
    crash_at: tuple = ()
    crash_in_save: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.crash_at and ("c", step) not in self._fired:
            self._fired.add(("c", step))
            raise SimulatedFailure(f"injected crash at step {step}")

    def save_hook(self, step: int) -> Optional[int]:
        if step in self.crash_in_save and ("s", step) not in self._fired:
            self._fired.add(("s", step))
            return 1           # fail after writing 1 leaf file
        return None


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_interval: int = 20
    ckpt_keep: int = 3
    log_interval: int = 10
    microbatches: int = 1
    straggler_factor: float = 3.0
    straggler_window: int = 20
    straggler_patience: int = 3
    seed: int = 0
    error_feedback: bool = False


class Trainer:
    def __init__(self, model, optimizer: Optimizer, data_cfg: DataConfig,
                 cfg: TrainerConfig, cim=None, rules=None, mesh=None,
                 failure_plan: Optional[FailurePlan] = None,
                 step_time_fn: Optional[Callable] = None):
        self.model = model
        self.optimizer = optimizer
        self.data_cfg = data_cfg
        self.cfg = cfg
        self.failure_plan = failure_plan or FailurePlan()
        self.step_time_fn = step_time_fn        # test hook: fake durations
        self.manager = ckpt_lib.CheckpointManager(
            cfg.ckpt_dir, cfg.ckpt_interval, cfg.ckpt_keep)
        self._step_fn = jax.jit(make_train_step(
            model, optimizer, cim=cim, microbatches=cfg.microbatches,
            rules=rules, mesh=mesh), donate_argnums=(0,))
        self.history: list[dict] = []
        self.restarts = 0
        self.straggler_events = 0
        self.mitigations = 0
        self._durations: list[float] = []

    # ------------------------------------------------------------------
    def _fresh_state(self) -> TrainState:
        return init_state(self.model, self.optimizer,
                          jax.random.key(self.cfg.seed),
                          error_feedback=self.cfg.error_feedback)

    def _restore_or_init(self) -> TrainState:
        fresh = self._fresh_state()
        got = self.manager.restore_or_none(target=fresh)
        if got is None:
            return fresh
        tree, extra = got
        return TrainState(*tree) if not isinstance(tree, TrainState) else tree

    def _save(self, state: TrainState, force: bool = False):
        step = int(state.step)
        fail = self.failure_plan.save_hook(step)
        if fail is not None:
            # crash mid-save: the atomic writer leaves only .tmp wreckage
            try:
                ckpt_lib.save(self.cfg.ckpt_dir, step, state,
                              _fail_after_files=fail)
            finally:
                raise SimulatedFailure(f"crash during save at step {step}")
        self.manager.maybe_save(step, state, force=force)

    def _track_straggler(self, dt: float) -> bool:
        self._durations.append(dt)
        win = self._durations[-self.cfg.straggler_window:]
        if len(win) < 5:
            return False
        med = statistics.median(win[:-1])
        if dt > self.cfg.straggler_factor * max(med, 1e-9):
            self.straggler_events += 1
            if self.straggler_events % self.cfg.straggler_patience == 0:
                self.mitigations += 1       # re-shard / reissue hook
            return True
        return False

    # ------------------------------------------------------------------
    def run(self) -> TrainState:
        """Run to total_steps, surviving every injected failure."""
        state = self._restore_or_init()
        while int(state.step) < self.cfg.total_steps:
            try:
                state = self._run_segment(state)
            except SimulatedFailure:
                self.restarts += 1
                state = self._restore_or_init()
        self._save(state, force=True)
        return state

    def _run_segment(self, state: TrainState) -> TrainState:
        while int(state.step) < self.cfg.total_steps:
            step = int(state.step)
            self.failure_plan.check(step)
            batch = batch_for(self.model.cfg, self.data_cfg,
                              jnp.asarray(step, jnp.int32))
            t0 = time.monotonic()
            state, metrics = self._step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = (self.step_time_fn(step) if self.step_time_fn
                  else time.monotonic() - t0)
            metrics["step"] = step
            metrics["straggler"] = self._track_straggler(dt)
            self.history.append(metrics)
            self._save(state)
        return state
