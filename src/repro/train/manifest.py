"""Audited manifest of the training step's jitted entry point.

Companion to ``repro.serve.manifest`` (same :class:`AuditedEntry`
record): names the jitted train step for the ``jaxpr`` analysis pass.
The TrainState donation is the one that matters at scale — a
non-aliased donated state doubles parameter + optimizer memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import abstract_params
from repro.serve.manifest import AuditedEntry

B, S = 2, 32            # tiny trace geometry (contracts are shape-free)


def _train_step(model):
    from repro.optim import adamw
    from .step import TrainState, jit_train_step, make_train_step

    opt = adamw(3e-4)
    fn = jit_train_step(make_train_step(model, opt))
    state = TrainState(
        abstract_params(model.param_defs, model.cfg.dtype),
        abstract_params(opt.state_defs(model.param_defs), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32), None)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    return fn, (state, batch)


def entries() -> tuple[AuditedEntry, ...]:
    return (
        AuditedEntry("train.train_step", _train_step, (0,), 2,
                     "TrainState donated: params + optimizer state + "
                     "step must all alias (in-place update, no 2x "
                     "parameter memory)"),
    )
