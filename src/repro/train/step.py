"""The jitted training step: loss -> grads -> (compressed) sync -> update.

Structure notes that matter at 1000+ chips:

* **Microbatching as a scan** — gradient accumulation over `microbatches`
  slices of the per-step batch runs as jax.lax.scan, so XLA pipelines the
  per-microbatch reduce-scatters of the FSDP gradient sync against the
  next microbatch's compute (collective/compute overlap without manual
  double buffering).
* **Sharding comes in through in_shardings** — parameters and optimizer
  state carry NamedShardings derived from the logical-axis rules
  (repro.dist.sharding); the step body itself is sharding-free except
  for an activation constraint on the batch.
* **Donation** — params and optimizer state are donated, so the update
  is in-place at the XLA level (no 2x parameter memory).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models.config import ParamDef, abstract_params, is_def
from repro.optim import Optimizer, ef_init


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array                 # scalar int32
    ef_residual: Any = None         # error-feedback buffers (optional)


def init_state(model, optimizer: Optimizer, key, dtype=None,
               error_feedback: bool = False) -> TrainState:
    params = model.init(key, dtype)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32),
                      ef_init(params) if error_feedback else None)


def make_abstract_state(model, optimizer: Optimizer, rules, mesh,
                        dtype=None) -> tuple[TrainState, TrainState]:
    """(ShapeDtypeStruct state, PartitionSpec state) for the dry-run —
    built entirely from ParamDefs; nothing is allocated."""
    pd = model.param_defs
    od = optimizer.state_defs(pd)
    mk_sharding = lambda d: shd.named_sharding(d.axes, d.shape, rules, mesh)
    params = abstract_params(pd, model.cfg.dtype if dtype is None else dtype,
                             mk_sharding)
    opt = abstract_params(od, jnp.float32, mk_sharding)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    specs = TrainState(shd.spec_tree(pd, rules, mesh),
                       shd.spec_tree(od, rules, mesh),
                       jax.sharding.PartitionSpec(), None)
    return TrainState(params, opt, step, None), specs


def make_train_step(model, optimizer: Optimizer, cim=None,
                    microbatches: int = 1, rules=None, mesh=None,
                    compress_grads: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    `batch` leaves have leading dim = global_batch; with microbatching
    the leading dim must divide by `microbatches`.
    """
    shd.set_activation_context(rules, mesh)
    # resolve the CIM plan request once at step construction (backend
    # capability check + interpret probe), not per traced matmul
    cim = cim.resolve() if cim is not None else None

    def loss_fn(params, mb):
        return model.loss(params, mb, cim=cim)

    def _constrain_batch(tree):
        if rules is None or mesh is None:
            return tree
        return jax.tree.map(
            lambda x: shd.constrain(x, ("batch",) + ("none",) * (x.ndim - 1),
                                    rules, mesh), tree)

    def compute_grads(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def slice_mb(i):
            # re-anchor the batch sharding on every microbatch slice —
            # without this, XLA loses the DP sharding through the
            # reshape+dynamic-slice and replicates the whole microbatch
            # on every device (observed 16x flops inflation).
            return _constrain_batch(jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:])[i],
                batch))

        def body(carry, i):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, slice_mb(i))
            grad_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                    grad_acc, g)
            return (loss_acc + l, grad_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros),
            jnp.arange(microbatches))
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if rules is not None and mesh is not None:
            batch = jax.tree.map(
                lambda x: shd.constrain(x, ("batch",) + ("none",) *
                                        (x.ndim - 1), rules, mesh), batch)
        loss, grads = compute_grads(state.params, batch)
        residual = state.ef_residual
        if compress_grads and mesh is not None:
            from repro.optim import int8_allgather_sync
            grads, residual = int8_allgather_sync(
                grads, mesh, axes=("pod", "data"), residual=residual)
        new_params, new_opt, om = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt, state.step + 1,
                          residual), metrics

    return train_step


def jit_train_step(train_step, state_specs: Optional[TrainState] = None,
                   batch_spec=None, mesh=None):
    """jit with shardings + donation; falls back to plain jit off-mesh."""
    if state_specs is None or mesh is None:
        return jax.jit(train_step, donate_argnums=(0,))
    from jax.sharding import NamedSharding

    def ns(spec):
        return NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(ns, state_specs,
                          is_leaf=lambda x: isinstance(
                              x, jax.sharding.PartitionSpec)),
             jax.tree.map(ns, batch_spec,
                          is_leaf=lambda x: isinstance(
                              x, jax.sharding.PartitionSpec)))
    return jax.jit(train_step, in_shardings=in_sh, donate_argnums=(0,))
