"""Optimizers as (init, state_defs, update) triples.

``state_defs(param_defs)`` mirrors the ParamDef system used for model
parameters so the multi-pod dry-run can construct ShapeDtypeStructs with
NamedShardings for the optimizer state without ever allocating it — the
optimizer state inherits the logical axes of its parameter (AdamW
moments) or the axes minus the factored dim (Adafactor).

Adafactor keeps factored f32 second moments (row/col vectors), the
ZeRO-friendly choice that makes 1T-param training state fit (DESIGN.md
§Kimi-K2 feasibility note).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ParamDef, is_def


class Optimizer(NamedTuple):
    init: Callable          # params -> state
    state_defs: Callable    # param_defs -> ParamDef pytree (dry-run)
    update: Callable        # (grads, state, params, step) -> (params, state)


# ------------------------------------------------------------- schedules

def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_lr(v: float) -> Callable:
    return lambda step: jnp.asarray(v, jnp.float32)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), grads), g


# ---------------------------------------------------------------- AdamW

def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          max_grad_norm: float = 1.0, state_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_lr(lr)

    def init(params):
        zero = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"mu": jax.tree.map(zero, params),
                "nu": jax.tree.map(zero, params)}

    def state_defs(param_defs):
        like = lambda d: ParamDef(d.shape, d.axes, "zeros", state_dtype)
        return {"mu": jax.tree.map(like, param_defs, is_leaf=is_def),
                "nu": jax.tree.map(like, param_defs, is_leaf=is_def)}

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        else:
            gnorm = global_norm(grads)
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def leaf(p, g, mu, nu):
            g = g.astype(state_dtype)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            upd = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(state_dtype)
            return (p - lr_t * upd.astype(p.dtype)).astype(p.dtype), mu, nu

        out = jax.tree.map(leaf, params, grads, state["mu"], state["nu"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_mu, "nu": new_nu}, {"grad_norm": gnorm,
                                                     "lr": lr_t}

    return Optimizer(init, state_defs, update)


# ------------------------------------------------------------ Adafactor

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor(lr: Callable | float, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, max_grad_norm: float = 1.0,
              min_dim_size_to_factor: int = 2) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018), the
    memory-frugal choice for >=7B training: state is O(rows + cols) per
    matrix instead of O(rows*cols)."""
    lr_fn = lr if callable(lr) else constant_lr(lr)

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(leaf, params)

    def state_defs(param_defs):
        def leaf(d: ParamDef):
            if _factored(d.shape):
                return {"r": ParamDef(d.shape[:-1], d.axes[:-1], "zeros",
                                      jnp.float32),
                        "c": ParamDef(d.shape[:-2] + d.shape[-1:],
                                      d.axes[:-2] + d.axes[-1:], "zeros",
                                      jnp.float32)}
            return {"v": ParamDef(d.shape, d.axes, "zeros", jnp.float32)}
        return jax.tree.map(leaf, param_defs, is_leaf=is_def)

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        else:
            gnorm = global_norm(grads)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def leaf(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "r" in s:
                r = beta * s["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(axis=-2)
                # rank-1 reconstruction of the second moment
                denom = (r[..., None] / jnp.maximum(
                    r.mean(axis=-1, keepdims=True), eps)[..., None]) * \
                    c[..., None, :]
                upd = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return (p - lr_t * upd.astype(p.dtype)).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        outs = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        return new_p, new_s, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, state_defs, update)


# ----------------------------------------------------------------- SGD

def sgd(lr: Callable | float, momentum: float = 0.0,
        max_grad_norm: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_lr(lr)

    def init(params):
        if momentum:
            return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                      params)}
        return {}

    def state_defs(param_defs):
        if momentum:
            like = lambda d: ParamDef(d.shape, d.axes, "zeros", jnp.float32)
            return {"m": jax.tree.map(like, param_defs, is_leaf=is_def)}
        return {}

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        else:
            gnorm = global_norm(grads)
        lr_t = lr_fn(step)
        if momentum:
            m = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                             state["m"], grads)
            new_p = jax.tree.map(lambda p, m: (p - lr_t * m).astype(p.dtype),
                                 params, m)
            return new_p, {"m": m}, {"grad_norm": gnorm, "lr": lr_t}
        new_p = jax.tree.map(lambda p, g: (p - lr_t * g).astype(p.dtype),
                             params, grads)
        return new_p, {}, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, state_defs, update)
