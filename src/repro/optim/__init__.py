from .optimizers import (Optimizer, adamw, adafactor, sgd,
                         warmup_cosine, constant_lr)  # noqa: F401
from .compression import (compress_int8, decompress_int8,
                          ef_init, ef_compress_grads,
                          int8_allgather_sync)  # noqa: F401
