"""Gradient compression: int8 quantization with error feedback, and an
explicit int8 all-gather gradient sync for the DP axis.

The paper's core trick is *narrow on-the-wire representations backed by a
full-precision compute medium* (trits in ReRAM, restored into SRAM).  The
distributed-training analogue is compressing the gradient before it
crosses the interconnect: each DP shard quantizes its local gradient to
int8 (+ f32 scale), all-gathers the compressed bytes over the 'data'
axis, and sums the dequantized shards — 2x fewer collective bytes than
bf16, 4x fewer than f32.  Error feedback (Karimireddy et al., 2019)
accumulates the per-shard quantization residual locally so the bias
vanishes over steps.

``int8_allgather_sync`` is written with shard_map + lax collectives so
the int8 all-gather is visible in the dry-run HLO (the collective-bytes
reduction is measurable in §Roofline, not just claimed).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: g ~= q * scale."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_init(params: Any) -> Any:
    """Error-feedback residual buffers (same shapes as grads, f32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_grads(grads: Any, residual: Any) -> tuple[Any, Any]:
    """(compressed-then-decompressed grads, new residuals).

    The returned grads are exactly what the other DP shards would
    reconstruct; the residual carries this shard's quantization error
    into the next step.
    """
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(leaf, grads, residual)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


def int8_allgather_sync(grads: Any, mesh, axes: tuple = ("data",),
                        residual: Any | None = None):
    """Sync DP-sharded gradients with int8 on the wire.

    Inside shard_map over `axes`: quantize the local (microbatch) grad to
    int8, all_gather the bytes, dequantize and mean.  Equivalent to
    psum(grad)/N up to int8 rounding; with `residual` the rounding error
    is fed back.  Returns (synced grads, new residual).
    """
    axes = tuple(a for a in axes if a in mesh.axis_names
                 and mesh.shape[a] > 1)
    if residual is not None:
        grads, residual = ef_compress_grads(grads, residual)
    if not axes:
        return grads, residual

    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def sync(g):
        def one(x):
            q, s = compress_int8(x)
            qs = jax.lax.all_gather(q, axes, tiled=False)   # (N, ...) int8
            ss = jax.lax.all_gather(s, axes, tiled=False)   # (N,) f32
            qs = qs.reshape((n,) + x.shape)
            ss = ss.reshape((n,) + (1,) * x.ndim)
            return (jnp.sum(qs.astype(jnp.float32) * ss, axis=0) / n
                    ).astype(x.dtype)
        return jax.tree.map(one, g)

    from jax.experimental.shard_map import shard_map
    specs = jax.tree.map(lambda _: P(), grads)
    synced = shard_map(sync, mesh=mesh, in_specs=(specs,), out_specs=specs,
                       check_rep=False)(grads)
    return synced, residual
