"""zamba2-7b — Mamba2 backbone + shared (weight-tied) attention+MLP block.

[arXiv:2411.15242] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000 ssm_state=64.  The shared transformer block runs after every
6 Mamba2 layers (weights tied across invocations; separate KV caches).
Sub-quadratic (SSM state) -> runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_kind="mamba2",
    attn_every=6,
    remat="full",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=6,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_kind="mamba2",
    attn_every=3,
)
