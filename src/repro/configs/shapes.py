"""The assigned input-shape set (4 cells per architecture, 40 total).

Shape semantics (assignment):
  train_4k     seq=4,096   global_batch=256  -> lowers ``train_step``
  prefill_32k  seq=32,768  global_batch=32   -> lowers ``prefill_step``
  decode_32k   seq=32,768  global_batch=128  -> lowers ``serve_step``
                (one new token against a KV cache/state of seq_len)
  long_500k    seq=524,288 global_batch=1    -> ``serve_step``; requires
                sub-quadratic attention (SSM state / rolling SWA window)

``runnable(cfg, cell)`` encodes the assignment's skip rules:
  * ``long_500k`` only for SSM / hybrid / sliding-window archs — a full-
    attention KV cache at 524,288 tokens is quadratic-cost and the cell
    is skipped (documented in DESIGN.md §Shape-cell skips);
  * decode shapes are skipped for encoder-only archs (none assigned —
    whisper is enc-dec and decodes against self+cross caches).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def runnable(cfg, cell: ShapeCell) -> tuple[bool, str]:
    """(ok, reason) — reason explains a skip."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k-token KV cache is "
                       "quadratic-cost; skipped per assignment")
    return True, ""
