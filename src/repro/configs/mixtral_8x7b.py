"""mixtral-8x7b — 8 experts top-2 with sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096.  The rolling-window KV
cache is window-bounded -> sub-quadratic -> runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1e6,
    remat="full",
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    sliding_window=16,
)
