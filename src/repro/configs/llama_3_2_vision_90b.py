"""llama-3.2-vision-90b — dense decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision scaled] 100L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256; a gated cross-attention block every
5th layer attends to precomputed patch embeddings (vision frontend is a
stub per the assignment: input_specs() provides 1601 patch embeddings).
Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    encoder_seq=1601,
    rope_theta=500000.0,
    remat="full",
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    cross_attn_every=2,
    encoder_seq=16,
)
