"""xlstm-125m — alternating sLSTM + mLSTM blocks.

[arXiv:2405.04517] 12L d_model=768 4H (GQA kv=4) d_ff=0 (projections are
block-internal) vocab=50304.  Sub-quadratic (recurrent state) ->
runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,                # 6 (mLSTM, sLSTM) pairs
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_kind="xlstm",
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=96,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    ssm_kind="xlstm",
)
