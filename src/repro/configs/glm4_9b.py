"""glm4-9b — dense transformer with extreme GQA (kv=2).

[hf:THUDM/glm-4-9b] 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552.  kv=2 stresses the TP sharding rules: a 16-way model axis
cannot split 2 kv heads, so wk/wv fall back to replicated (the
divisibility invariant in dist.sharding).  Full attention -> long_500k
skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=1e6,
    remat="full",
)

SMOKE = ModelConfig(
    name="glm4-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
)
