"""whisper-large-v3 — encoder-decoder transformer, conv frontend stubbed.

[arXiv:2212.04356] 32L (decoder; 32 encoder layers too) d_model=1280
20H (kv=20) d_ff=5120 vocab=51866.  input_specs() supplies precomputed
frame embeddings (1500 frames = 30 s of audio at 50 Hz after the conv
stem); the conv frontend itself is a stub per the assignment.
Full attention -> long_500k skipped; decode runs (enc-dec has a decoder).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    encoder_layers=32,
    encoder_seq=1500,
    remat="full",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    encoder_layers=2,
    encoder_seq=32,
)
