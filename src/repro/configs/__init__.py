"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines CONFIG (the exact published configuration from the
assignment table) and SMOKE (a reduced same-family configuration used by
CPU smoke tests).  Full configs are exercised ONLY via the dry-run
(ShapeDtypeStruct; no allocation).
"""
from __future__ import annotations

import importlib

from .shapes import SHAPES, ShapeCell, runnable  # noqa: F401

ARCHS = (
    "zamba2-7b",
    "xlstm-125m",
    "whisper-large-v3",
    "kimi-k2-1t-a32b",
    "mixtral-8x7b",
    "llama-3.2-vision-90b",
    "qwen3-14b",
    "phi3-mini-3.8b",
    "glm4-9b",
    "internlm2-1.8b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str):
    """Full (paper-table) ModelConfig for an assigned architecture."""
    return _mod(name).CONFIG


def smoke(name: str):
    """Reduced same-family ModelConfig for CPU smoke tests."""
    return _mod(name).SMOKE


def cells(name: str):
    """All 4 assigned shape cells with their runnability for this arch."""
    cfg = get(name)
    return [(c, *runnable(cfg, c)) for c in SHAPES.values()]
