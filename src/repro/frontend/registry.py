"""Model registry: several architectures behind one submit path.

The front-end multiplexes models the way the paper's density argument
multiplexes networks on one chip (and CIMPool multiplexes models over
a shared CIM pool): each registered :class:`ModelSpec` names an
architecture from ``repro.configs`` and owns its OWN scheduler pool —
per-model slots, chunk size and KV capacity — while
``FrontendServer.submit(model=...)`` is the single entry point.

Instantiation is lazy: registering a spec is free; the model is built,
its params initialized and its scheduler compiled the first time a
request targets it (``entry``).  ``capacity_report`` summarizes every
registered model — including uninstantiated ones — so an operator can
see what a deployment would resident before paying for it.

Per the seams rule, everything execution-related rides existing
registry/plan machinery: ``configs.smoke``/``configs.get`` +
``models.registry.build`` + ``serve.PagedScheduler`` — no new kwargs
through ops or CIMConfig.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """One serveable model: an architecture name plus its scheduler
    pool geometry.  ``capacity`` bounds one request's prompt + decode
    budget (requests over it are rejected at submit with
    ``over-capacity``, never mid-decode).  ``overrides`` is a tuple of
    ``(field, value)`` pairs applied to the resolved ModelConfig
    (hashable, so specs stay frozen); ``dtype='float32'`` by default —
    the offline-CI pools serve f32 on the CPU host."""

    name: str
    arch: str
    smoke: bool = True               # configs.smoke vs configs.get
    kind: str = "paged"              # 'paged' | 'dense' scheduler pool
    capacity: int = 64
    slots: int = 4
    chunk: int = 4
    page_size: int = 16
    num_pages: Optional[int] = None
    seed: int = 0
    dtype: str = "float32"
    overrides: tuple = ()


@dataclasses.dataclass
class ModelEntry:
    """A lazily built model: config + model + params + scheduler."""

    spec: ModelSpec
    cfg: object
    model: object
    params: object
    scheduler: object


class ModelRegistry:
    """Named :class:`ModelSpec`s with lazy scheduler instantiation."""

    def __init__(self):
        self._specs: dict[str, ModelSpec] = {}
        self._entries: dict[str, ModelEntry] = {}
        self._cfgs: dict[str, object] = {}

    def register(self, spec: ModelSpec) -> None:
        if spec.name in self._specs:
            raise ValueError(f"model {spec.name!r} already registered")
        if spec.kind not in ("paged", "dense"):
            raise ValueError(f"model {spec.name!r}: kind must be "
                             f"'paged' or 'dense', got {spec.kind!r}")
        self._specs[spec.name] = spec

    def names(self) -> list[str]:
        return sorted(self._specs)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def spec(self, name: str) -> ModelSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(f"unknown model {name!r}; registered: "
                           f"{self.names()}") from None

    def is_instantiated(self, name: str) -> bool:
        return name in self._entries

    def config(self, name: str):
        """The resolved ModelConfig for a registered model — cheap
        (no weights); cached so submit-path vocab lookups don't
        re-resolve."""
        if name not in self._cfgs:
            import jax.numpy as jnp

            from repro import configs

            spec = self.spec(name)
            cfg = (configs.smoke(spec.arch) if spec.smoke
                   else configs.get(spec.arch))
            fields = dict(spec.overrides)
            fields.setdefault("dtype", getattr(jnp, spec.dtype))
            self._cfgs[name] = dataclasses.replace(cfg, **fields)
        return self._cfgs[name]

    def entry(self, name: str) -> ModelEntry:
        """The live scheduler for a model, building it on first use."""
        if name not in self._entries:
            import jax

            from repro.models import registry as model_registry
            from repro.serve import PagedScheduler, Scheduler

            spec = self.spec(name)
            cfg = self.config(name)
            model = model_registry.build(cfg)
            params = model.init(jax.random.key(spec.seed))
            if spec.kind == "paged":
                sched = PagedScheduler(
                    model, params, capacity=spec.capacity,
                    slots=spec.slots, chunk=spec.chunk,
                    page_size=spec.page_size, num_pages=spec.num_pages)
            else:
                sched = Scheduler(model, params, capacity=spec.capacity,
                                  slots=spec.slots, chunk=spec.chunk)
            self._entries[name] = ModelEntry(
                spec=spec, cfg=cfg, model=model, params=params,
                scheduler=sched)
        return self._entries[name]

    def capacity_report(self) -> dict:
        """Per-model capacity summary (registered AND uninstantiated
        models both appear; live pools add their accounting)."""
        report = {}
        for name in self.names():
            spec = self._specs[name]
            row = {"arch": spec.arch, "kind": spec.kind,
                   "slots": spec.slots, "chunk": spec.chunk,
                   "capacity": spec.capacity,
                   "instantiated": name in self._entries}
            if name in self._entries:
                ent = self._entries[name]
                sched = ent.scheduler
                row.update(
                    family=ent.cfg.family,
                    params_m=round(ent.cfg.param_count() / 1e6, 2),
                    vocab_size=ent.cfg.vocab_size,
                    kv_bytes_pool=sched.kv_bytes(),
                    host_transfers=sched.host_transfers,
                    chunks=sched.chunks_run)
                if spec.kind == "paged":
                    row.update(page_size=sched.page_size,
                               num_pages=sched.num_pages,
                               pages_in_use=sched.pages_in_use)
            report[name] = row
        return report
