"""Admission policies for the serving front-end.

A policy answers two questions each time the server polls, both as
pure functions of the pending request set and the server's clock value
``now`` (seconds since the serve epoch):

  * in what ORDER should pending requests be offered to free slots
    (``sort_key`` — Python's sort is stable, so equal keys keep
    submission order);
  * which pending requests should be SHED instead of admitted
    (``shed_reason`` — a non-None reason string rejects the request;
    the server counts it, it is never silently dropped).

Determinism is a contract, not a hope: policies take the clock VALUE
as an argument and carry no RNG or wall-clock reads of their own, so
admission is reproducible given (trace, seed) — lint rule RA005
enforces the no-wall-clock/no-global-RNG part statically and the
``frontend`` analysis pass replays a trace twice under a virtual clock
and diffs the admission logs.

Deadlines are RELATIVE: ``Request.deadline_s`` is a completion budget
from the request's ``arrival_s`` (``deadline_at`` converts to the
absolute serve-clock deadline the policies compare against).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def deadline_at(req) -> float:
    """Absolute serve-clock deadline (arrival + relative budget);
    +inf when the request carries no deadline."""
    if req.deadline_s is None:
        return float("inf")
    return req.arrival_s + req.deadline_s


class FIFOAdmission:
    """Pure FIFO: admit in (arrival, uid) order, never shed.  The
    baseline the SLO policy's goodput is benchmarked against."""

    name = "fifo"

    def sort_key(self, req, now: float):
        return (req.arrival_s, req.uid)

    def shed_reason(self, req, now: float) -> Optional[str]:
        return None


@dataclasses.dataclass(frozen=True)
class SLOAdmission:
    """Priority classes + earliest-deadline-first + load shedding.

    Admission order (``sort_key``): ``priority`` first (lower = more
    urgent — an urgent class preempts FIFO order at ADMISSION; running
    slots are never revoked), then the absolute deadline (EDF — the
    deadline-based deferral of loose requests behind tight ones), then
    (arrival, uid) as the stable FIFO tie-break.

    Shedding (``shed_reason``): a pending request whose deadline can no
    longer be met is rejected instead of occupying a slot another
    request could still use — ``deadline-passed`` when ``now`` is
    already at/past the absolute deadline, and (with a configured
    ``service_floor_s`` estimate of the minimum time a request needs
    once admitted) ``deadline-unmeetable`` when ``now +
    service_floor_s`` overshoots it.  Requests without a deadline are
    never shed.
    """

    service_floor_s: float = 0.0
    name: str = "slo"

    def sort_key(self, req, now: float):
        return (req.priority, deadline_at(req), req.arrival_s, req.uid)

    def shed_reason(self, req, now: float) -> Optional[str]:
        dl = deadline_at(req)
        if dl == float("inf"):
            return None
        if now >= dl:
            return "deadline-passed"
        if now + self.service_floor_s > dl:
            return "deadline-unmeetable"
        return None
