"""Audited manifest of the frontend pump's jitted surface.

The :class:`FrontendServer` owns no jit of its own: ``poll()``
advances one model's scheduler by one round (``step_round``), whose
jitted entry is the chunked decode loop built with the
:class:`ModelSpec` pool geometry — the default registry pool is the
paged scheduler (``kind='paged'``).  The pump entry here traces
exactly that loop at the ModelSpec default geometry, so the frontend's
one-transfer-per-chunk streaming contract (FE001, dynamic) has a
static jaxpr-level counterpart: no callback, no host transfer, no
widening anywhere in the graph the pump dispatches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import abstract_params
from repro.serve.manifest import AuditedEntry


def _pump(model):
    import dataclasses

    from repro.models.paged_kv import PagedKVCache
    from repro.serve.engine import make_paged_decode_loop
    from .registry import ModelSpec

    # the registry's default pool geometry (fields, not a guess)
    spec_fields = {f.name: f.default
                   for f in dataclasses.fields(ModelSpec)}
    slots, chunk = spec_fields["slots"], spec_fields["chunk"]
    capacity, page = spec_fields["capacity"], spec_fields["page_size"]
    per_slot = -(-capacity // page)
    num_pages = 1 + slots * per_slot

    fn = make_paged_decode_loop(model, chunk)
    cfg = model.cfg
    pshape = (cfg.num_layers, num_pages, page, cfg.num_kv_heads, cfg.hd)
    pool = PagedKVCache(jax.ShapeDtypeStruct(pshape, cfg.dtype),
                        jax.ShapeDtypeStruct(pshape, cfg.dtype))
    lane = lambda dt=jnp.int32: jax.ShapeDtypeStruct((slots,), dt)
    table = jax.ShapeDtypeStruct((slots, per_slot), jnp.int32)
    return fn, (abstract_params(model.param_defs, cfg.dtype), lane(),
                pool, table, lane(), lane(jnp.bool_), lane(),
                lane(jnp.bool_), lane(), lane())


def entries() -> tuple[AuditedEntry, ...]:
    return (
        AuditedEntry("frontend.pump", _pump, (), 9,
                     "the paged chunk loop as the frontend registry "
                     "builds it — the only jit the pump dispatches"),
    )
