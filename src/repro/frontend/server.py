"""In-process serving front-end over the continuous schedulers.

``FrontendServer`` is the request/response layer the library
schedulers don't have: one ``submit()`` path multiplexing every model
in a :class:`~repro.frontend.registry.ModelRegistry`, a BOUNDED
pending queue with explicit backpressure, SLO-aware admission
(``repro.frontend.admission``), and per-request incremental token
streaming.  It is offline-CI-friendly: no sockets, no threads — the
caller pumps it (``poll``/``drain``), and the load generator
(``repro.frontend.loadgen``) replays arrival traces against it
open-loop.

Contracts (tested in tests/test_frontend.py, enforced by the
``frontend`` analysis pass):

  * **Bitwise token parity** — the server never re-implements
    scheduling: it drives each model's scheduler through the public
    pump API (``try_admit``/``step_round``), the same machinery
    ``Scheduler.run()`` uses, so per-request tokens are bitwise
    identical to driving ``PagedScheduler`` directly.
  * **Bounded queue, explicit backpressure** — at most ``queue_limit``
    requests wait for admission; past that ``submit`` REJECTS with a
    reason (``queue-full``), never silently drops.  Every submitted
    request is accounted for: ``submitted == len(completed) +
    len(rejected) + in_flight`` at all times.
  * **Streaming adds no transfers** — the scheduler's round already
    lands every new token on the host in its ONE per-chunk transfer
    (``Request.out_tokens`` grows as the chunk buffer is absorbed);
    streaming just drains that growth into the request's
    :class:`Stream` after each round.  ``host_transfers == chunks``
    survives the front-end (lint rule RA005 keeps ``jax.device_get``
    out of this package entirely).
  * **Deterministic admission** — the server reads time ONLY through
    the injected ``clock`` (seconds; ``time.monotonic`` by default,
    a virtual clock under test/bench), and every admit/shed/reject
    decision is appended to ``admission_log`` in decision order, so
    two replays of one (trace, seed) produce identical logs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.serve import Request

from .admission import FIFOAdmission
from .registry import ModelRegistry


@dataclasses.dataclass
class Stream:
    """Per-request handle: incremental tokens plus terminal status.

    ``status`` walks queued -> running -> done, or ends at rejected
    (at submit) / shed (a queued request whose deadline became
    unmeetable).  ``tokens`` grows per scheduler round (per chunk);
    ``ttft_s`` is stamped when the first tokens land.  ``on_tokens``,
    when set, is called as ``on_tokens(stream, new_tokens)`` on every
    increment — the delivery hook an adapter (SSE, websocket) would
    attach to.
    """

    uid: int
    model: str
    req: Optional[Request]
    status: str = "queued"
    reason: Optional[str] = None
    tokens: list = dataclasses.field(default_factory=list)
    ttft_s: Optional[float] = None
    on_tokens: Optional[Callable] = None

    @property
    def accepted(self) -> bool:
        return self.status not in ("rejected", "shed")

    @property
    def finished(self) -> bool:
        return self.status in ("done", "rejected", "shed")


class FrontendServer:
    def __init__(self, registry: ModelRegistry, admission=None,
                 queue_limit: int = 64, clock=time.monotonic):
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1 (the queue is "
                             f"bounded by contract), got {queue_limit}")
        self.registry = registry
        self.admission = admission if admission is not None \
            else FIFOAdmission()
        self.queue_limit = queue_limit
        self._clock = clock
        self._t0: Optional[float] = None
        self._next_uid = 0
        self._pending: list[Stream] = []
        self._running: dict[str, list[Stream]] = {}
        self._rr = 0                    # round-robin cursor over models
        # accounting: every submit ends in exactly one of these
        self.submitted = 0
        self.completed: list[Stream] = []
        self.rejected: list[Stream] = []
        self.rejects_by_reason: dict[str, int] = {}
        self.max_pending_seen = 0
        self.admission_log: list[tuple] = []

    # ------------------------------------------------------ serve clock
    def begin(self) -> None:
        """Start (or restart) the serve epoch: ``now()`` reads 0 here.
        Replays call this per epoch so arrival stamps stay comparable."""
        self._t0 = self._clock()

    def now(self) -> float:
        if self._t0 is None:
            self.begin()
        return self._clock() - self._t0

    # -------------------------------------------------------- interface
    def submit(self, model: str, prompt, max_new: int = 16,
               eos_id: int = -1, arrival_s: Optional[float] = None,
               priority: int = 0, deadline_s: Optional[float] = None,
               on_tokens: Optional[Callable] = None) -> Stream:
        """Offer one request; returns its :class:`Stream` — possibly
        already terminal (``status == 'rejected'``) when backpressure
        or validation rejects it.  ``arrival_s`` defaults to ``now()``
        (an open-loop replayer passes the trace's stamp)."""
        now = self.now()
        uid = self._next_uid
        self._next_uid += 1
        self.submitted += 1
        arrival = now if arrival_s is None else float(arrival_s)
        stream = Stream(uid=uid, model=model, req=None,
                        on_tokens=on_tokens)
        if model not in self.registry:
            return self._reject(stream, "unknown-model", "rejected")
        spec = self.registry.spec(model)
        if len(prompt) + max_new > spec.capacity:
            return self._reject(stream, "over-capacity", "rejected")
        if len(self._pending) >= self.queue_limit:
            return self._reject(stream, "queue-full", "rejected")
        stream.req = Request(uid=uid, prompt=prompt, max_new=max_new,
                             eos_id=eos_id, arrival_s=arrival,
                             priority=priority, deadline_s=deadline_s)
        self._pending.append(stream)
        self.max_pending_seen = max(self.max_pending_seen,
                                    len(self._pending))
        return stream

    def poll(self) -> bool:
        """One pump iteration: shed doomed pending requests, admit in
        policy order, then advance ONE busy model by one scheduler
        round and stream its new tokens.  Returns True while the
        server still holds work (pending or running)."""
        now = self.now()
        self._shed(now)
        self._admit_pending(now)
        stepped = self._step_one_round()
        return stepped or bool(self._pending)

    def drain(self) -> None:
        """Pump until every accepted request completed (no new
        arrivals — an open-loop replayer interleaves submits with
        ``poll`` instead)."""
        while self.poll():
            pass

    # ------------------------------------------------------- accounting
    @property
    def pending_count(self) -> int:
        return len(self._pending)

    @property
    def in_flight(self) -> int:
        return len(self._pending) + sum(len(v)
                                        for v in self._running.values())

    @property
    def host_transfers(self) -> int:
        """Device->host syncs across every instantiated pool (the
        streaming-adds-no-transfers claim compares this to chunks)."""
        return sum(self.registry.entry(m).scheduler.host_transfers
                   for m in self.registry.names()
                   if self.registry.is_instantiated(m))

    @property
    def chunks(self) -> int:
        return sum(self.registry.entry(m).scheduler.chunks_run
                   for m in self.registry.names()
                   if self.registry.is_instantiated(m))

    # --------------------------------------------------------- internals
    def _reject(self, stream: Stream, reason: str, status: str) -> Stream:
        stream.status = status
        stream.reason = reason
        self.rejected.append(stream)
        self.rejects_by_reason[reason] = \
            self.rejects_by_reason.get(reason, 0) + 1
        self.admission_log.append(("reject", stream.uid, reason))
        return stream

    def _shed(self, now: float) -> None:
        doomed = []
        for stream in self._pending:
            reason = self.admission.shed_reason(stream.req, now)
            if reason is not None:
                doomed.append((stream, reason))
        for stream, reason in doomed:
            self._pending.remove(stream)
            self._reject(stream, reason, "shed")

    def _admit_pending(self, now: float) -> None:
        """Offer pending streams to their schedulers in policy order.
        Per model, the first deferral (pool full / pages short) stops
        further offers to THAT model this poll — admission order within
        a model must match the policy's, not skip ahead."""
        self._pending.sort(
            key=lambda s: self.admission.sort_key(s.req, now))
        deferred_models: set[str] = set()
        admitted = []
        for stream in self._pending:
            if stream.model in deferred_models:
                continue
            sched = self.registry.entry(stream.model).scheduler
            if sched.try_admit(stream.req, now):
                stream.status = "running"
                self._running.setdefault(stream.model, []).append(stream)
                self.admission_log.append(
                    ("admit", stream.uid, stream.model))
                admitted.append(stream)
            else:
                deferred_models.add(stream.model)
        for stream in admitted:
            self._pending.remove(stream)

    def _step_one_round(self) -> bool:
        """Advance one busy model by one scheduling round (one chunk,
        one transfer), round-robin across busy models so no model's
        traffic starves another's, then stream the round's tokens."""
        busy = [m for m in sorted(self._running)
                if self._running[m]]
        if not busy:
            return False
        model = busy[self._rr % len(busy)]
        self._rr += 1
        self.registry.entry(model).scheduler.step_round(self.now)
        self._stream_round(model)
        return True

    def _stream_round(self, model: str) -> None:
        """Drain the round's new tokens out of each running request.
        The tokens are ALREADY on the host — the scheduler's single
        per-chunk transfer put them in ``req.out_tokens`` — so this
        is list slicing, not a device sync."""
        now = self.now()
        still = []
        for stream in self._running[model]:
            new = stream.req.out_tokens[len(stream.tokens):]
            if new:
                if stream.ttft_s is None:
                    stream.ttft_s = now - stream.req.arrival_s
                stream.tokens.extend(new)
                if stream.on_tokens is not None:
                    stream.on_tokens(stream, new)
            if stream.req.done:
                stream.status = "done"
                self.completed.append(stream)
            else:
                still.append(stream)
        self._running[model] = still
