"""SLO-aware serving front-end over the continuous schedulers.

Layered strictly on top of ``repro.serve`` (the schedulers' public
pump API — no new kwargs through ops/CIMConfig): a bounded-queue
in-process server with explicit backpressure and per-chunk token
streaming (:mod:`.server`), priority/deadline admission with load
shedding (:mod:`.admission`), a lazy multi-model registry
(:mod:`.registry`), and an open-loop trace-replay load harness
(:mod:`.loadgen`).  Contracts and overload semantics:
src/repro/frontend/README.md.
"""
from .admission import (FIFOAdmission, SLOAdmission,  # noqa: F401
                        deadline_at)
from .registry import ModelEntry, ModelRegistry, ModelSpec  # noqa: F401
from .server import FrontendServer, Stream  # noqa: F401
from .loadgen import (VirtualClock, replay, replay_direct,  # noqa: F401
                      trace_requests)
from . import manifest  # noqa: F401
