"""Open-loop trace replay against the front-end.

Open-loop means arrivals NEVER wait on completions: the replay submits
every request whose ``arrival_s`` has passed on every iteration,
regardless of how far behind the schedulers are — the load model under
which backpressure, shedding and goodput are meaningful at all (a
closed loop self-throttles and can never overload the server).

The replay is paced by the SERVER's injected clock: on a real clock it
sleeps real time between arrivals; under a :class:`VirtualClock` the
caller passes ``sleep=clock.advance`` and a per-poll ``tick`` cost, so
an overload scenario replays deterministically — same admissions, same
sheds, same tokens — which is exactly what the ``frontend`` analysis
pass checks.

Reported metrics (the ``serve_frontend`` bench section's currency):
p50/p99/p999 latency with the queue-wait/service split
(``serve.latency_stats``), time-to-first-token percentiles, tok/s, and
goodput — DEADLINE-MET tokens per second, the throughput that counts
under overload (tokens of requests that missed their deadline, or were
shed, earn nothing).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.seeding import stable_seed
from repro.serve import Request, latency_stats, percentile, validate_trace


class VirtualClock:
    """A callable clock the test/bench advances by hand: ``clock()``
    reads the current virtual time, ``advance``/``sleep`` move it.
    Inject into both :class:`~repro.frontend.server.FrontendServer`
    (``clock=``) and :func:`replay` (``sleep=clock.advance``) so
    pacing and latency stamps share one timeline."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += max(float(dt), 0.0)

    sleep = advance


def trace_requests(trace, registry, models, seed: int = 0) -> list[dict]:
    """Materialize a ``serve.trace`` into submit records: per-request
    prompts from per-request seeded Generators (``stable_seed`` keyed
    by (tag, seed, index) — editing the trace never reshuffles a
    neighbor's prompt), model assignment cycling over ``models`` unless
    a record pins its own ``"model"``.  The SAME records feed the
    front-end replay and the direct-scheduler parity baseline, so their
    prompts are bitwise shared."""
    records = []
    for i, rec in enumerate(validate_trace(trace)):
        model = rec.get("model") or models[i % len(models)]
        vocab = registry.config(model).vocab_size
        rng = np.random.default_rng(
            stable_seed("frontend-loadgen", seed, i))
        prompt = rng.integers(0, vocab, size=rec["prompt_len"],
                              dtype=np.int32)
        records.append({"uid": i, "model": model, "prompt": prompt,
                        "max_new": rec["max_new"],
                        "eos_id": rec["eos_id"],
                        "arrival_s": rec["arrival_s"],
                        "priority": rec["priority"],
                        "deadline_s": rec["deadline_s"]})
    return records


def replay(server, records, *, sleep=time.sleep, tick=None,
           collect_tokens: bool = False) -> dict:
    """One open-loop epoch: submit each record at its arrival offset,
    pump the server between arrivals, drain, report.

    ``sleep(dt)`` is called only when the server is fully idle and the
    next arrival is in the future; ``tick()``, when given, is called
    after every busy poll (a virtual clock charges its per-round cost
    here).  Counters are reported as DELTAS over this epoch, so one
    warm server can be replayed repeatedly (best-of-N benches)."""
    base_completed = len(server.completed)
    base_rejected = len(server.rejected)
    base_submitted = server.submitted
    base_rejects = dict(server.rejects_by_reason)
    base_transfers = server.host_transfers
    base_chunks = server.chunks
    server.max_pending_seen = 0
    server.begin()

    i = 0
    while True:
        now = server.now()
        while i < len(records) and records[i]["arrival_s"] <= now:
            rec = records[i]
            server.submit(rec["model"], rec["prompt"],
                          max_new=rec["max_new"], eos_id=rec["eos_id"],
                          arrival_s=rec["arrival_s"],
                          priority=rec["priority"],
                          deadline_s=rec["deadline_s"])
            i += 1
        busy = server.poll()
        if busy:
            if tick is not None:
                tick()
            continue
        if i < len(records):
            delay = records[i]["arrival_s"] - server.now()
            if delay > 0:
                sleep(delay)
            continue
        break

    wall = server.now()
    completed = server.completed[base_completed:]
    rejected = server.rejected[base_rejected:]
    reqs = [s.req for s in completed]
    tokens = sum(len(s.tokens) for s in completed)
    good_tokens = sum(len(s.tokens) for s in completed
                      if s.req.deadline_met)
    with_deadline = [s for s in completed if s.req.deadline_s is not None]
    shed = [s for s in rejected if s.status == "shed"]
    met = sum(1 for s in with_deadline if s.req.deadline_met)
    deadline_total = len(with_deadline) + len(shed)
    ttfts = sorted(s.ttft_s for s in completed if s.ttft_s is not None)
    rejects_by_reason = {
        k: v - base_rejects.get(k, 0)
        for k, v in server.rejects_by_reason.items()
        if v - base_rejects.get(k, 0)}
    out = {
        "submitted": server.submitted - base_submitted,
        "completed": len(completed),
        "rejected": len(rejected),
        "shed": len(shed),
        "rejects_by_reason": rejects_by_reason,
        "max_pending_seen": server.max_pending_seen,
        "wall_s": round(wall, 3),
        "tokens": tokens,
        "tok_per_s": round(tokens / max(wall, 1e-9), 1),
        "goodput_tokens": good_tokens,
        "tok_per_s_goodput": round(good_tokens / max(wall, 1e-9), 1),
        "deadline_met": met,
        "deadline_total": deadline_total,
        "ttft_p50_s": round(percentile(ttfts, 0.50), 4) if ttfts else 0.0,
        "ttft_p99_s": round(percentile(ttfts, 0.99), 4) if ttfts else 0.0,
        "host_transfers": server.host_transfers - base_transfers,
        "chunks": server.chunks - base_chunks,
        **latency_stats(reqs),
    }
    if collect_tokens:
        out["out_tokens"] = {s.uid: list(s.tokens) for s in completed}
    return out


def replay_direct(registry, records, clock=time.perf_counter
                  ) -> tuple[dict, dict]:
    """Parity baseline: the same records driven straight into each
    model's scheduler (``Scheduler.run()``'s own arrival pump — no
    front-end), per model on the SAME engine instance the registry
    serves, so the comparison isolates the front-end layer.  Returns
    ``(stats, {uid: tokens})``."""
    per_model: dict[str, list] = {}
    for rec in records:
        per_model.setdefault(rec["model"], []).append(rec)
    t0 = clock()
    tokens_by_uid: dict[int, list] = {}
    total_tokens = 0
    for model in sorted(per_model):
        sched = registry.entry(model).scheduler
        done0, tok0 = len(sched.completed), sched.generated_tokens
        for rec in per_model[model]:
            sched.submit(Request(
                uid=rec["uid"], prompt=rec["prompt"],
                max_new=rec["max_new"], eos_id=rec["eos_id"],
                arrival_s=rec["arrival_s"], priority=rec["priority"],
                deadline_s=rec["deadline_s"]))
        sched.run()
        total_tokens += sched.generated_tokens - tok0
        for r in sched.completed[done0:]:
            tokens_by_uid[r.uid] = list(r.out_tokens)
    wall = clock() - t0
    stats = {"wall_s": round(wall, 3), "tokens": total_tokens,
             "tok_per_s": round(total_tokens / max(wall, 1e-9), 1)}
    return stats, tokens_by_uid
