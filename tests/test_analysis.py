"""The analysis gate gates (ISSUE 6 acceptance):

  * every pass runs clean on the repo as merged — no baseline file of
    pre-existing violations;
  * a seeded violation in each category (capability drift, block/
    index-map violation, extra transfer / retrace, lint rule) is
    caught, and the CLI exits nonzero on it;
  * ``analysis.sanitize()`` enforces the serve transfer/retrace
    contract around ``Scheduler``/``PagedScheduler``: exactly one
    device->host transfer per chunk, zero retraces after warmup;
  * lint rules RA000-RA005 fire (and suppress) on the exact shapes
    they document (RA005 only inside ``src/repro/frontend/``);
  * the ``frontend`` pass catches each seeded violation (extra
    transfer, dropped accounting, perturbed admission order);
  * kernel-registry mutation edges: ``override=True`` replacement,
    unknown unregister, and plan-cache invalidation (stale plans must
    not resolve to — or execute on — an unregistered backend).
"""
import dataclasses
import json
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.analysis import (Finding, SanitizeError, sanitize, abscache,
                            blockmap, capability, jaxpr_audit, lint,
                            sanitizer, shardspec)
from repro.analysis.__main__ import main as cli_main
from repro.kernels import plan as plan_mod
from repro.kernels.plan import (execute, get_backend, plan_matmul,
                                register_backend, resolve_backend,
                                unregister_backend)
from repro.models import registry
from repro.serve import PagedScheduler, Request, Scheduler

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------- clean gate

def test_capability_pass_clean():
    assert capability.run() == []


def test_blockmap_pass_clean():
    assert blockmap.run() == []


def test_lint_pass_clean():
    """src/ + benchmarks/ as merged carry zero lint findings — the
    gate landed with its findings fixed, not baselined."""
    assert lint.run() == []


# ------------------------------------------------- capability drift

def test_capability_matrix_round_trips():
    reg = capability._registry()
    parsed = capability.parse_capability_matrix(
        capability.render_capability_matrix())
    assert set(parsed) == set(reg)


def test_capability_readme_drift_is_flagged(tmp_path):
    text = capability.render_capability_matrix()
    doctored = text.replace("cpu, gpu, tpu", "cpu", 1)
    assert doctored != text
    readme = tmp_path / "README.md"
    readme.write_text("# doctored\n\n" + doctored)
    findings = capability._check_readme_matrix(capability._registry(),
                                               str(readme))
    assert findings and all(f.rule == "CAP006" for f in findings)


def test_capability_readme_missing_backend_is_flagged(tmp_path):
    text = capability.render_capability_matrix()
    kept = [ln for ln in text.splitlines() if "`ref`" not in ln]
    readme = tmp_path / "README.md"
    readme.write_text("# doctored\n\n" + "\n".join(kept) + "\n")
    findings = capability._check_readme_matrix(capability._registry(),
                                               str(readme))
    assert any("ref" in f.message for f in findings)


def test_cli_capability_drift_exits_nonzero(tmp_path):
    readme = tmp_path / "README.md"
    readme.write_text("# no matrix here\n")
    assert cli_main(["--passes", "capability",
                     "--readme", str(readme)]) != 0


# ------------------------------------------------- blockmap violations

def test_blockmap_pinned_misaligned_blocks_flagged():
    findings = blockmap.run(pin_blocks=(100, 100, 100))
    rules = {f.rule for f in findings}
    assert "BM001" in rules            # 100 breaks sublane/lane multiples


def test_cli_blockmap_pinned_exits_nonzero():
    assert cli_main(["--passes", "blockmap",
                     "--pin-blocks", "100,100,100"]) != 0


def test_blockmap_live_selector_cells_clean():
    assert blockmap.check_ternary_cell(333, 77, 129, "trit2", "float") == []
    assert blockmap.check_cim_cell(16, 256, 256) == []


# ------------------------------------------------- sanitize: unit

def test_sanitize_counts_transfers_and_restores():
    orig = jax.device_get
    with sanitize() as rep:
        jax.device_get(jnp.ones((3,)))
        jax.device_get((jnp.ones((2,)), jnp.zeros((2,))))
    assert rep.transfers == 2
    assert jax.device_get is orig      # wrapper uninstalled on exit


def test_sanitize_transfer_budget_enforced():
    with pytest.raises(SanitizeError, match="budget is 0"):
        with sanitize(max_transfers=0):
            jax.device_get(jnp.ones((3,)))


def test_sanitize_counts_compiles():
    with sanitize() as rep:
        jax.jit(lambda x: x * 3 + 1)(jnp.ones((4,)))   # fresh jit: compiles
    assert rep.compiles >= 1
    with pytest.raises(SanitizeError, match="retrace"):
        with sanitize(max_compiles=0):
            jax.jit(lambda x: x * 5 - 2)(jnp.ones((4,)))


def test_sanitize_clean_region_counts_nothing():
    f = jax.jit(lambda x: x + 2)
    f(jnp.ones((4,)))                  # warmup outside the region
    with sanitize(max_transfers=0, max_compiles=0) as rep:
        f(jnp.ones((4,)))              # cached: no compile, no transfer
    assert rep.transfers == 0 and rep.compiles == 0


# ------------------------------------------------- sanitize: serve

def _smoke_scheduler(kind):
    cfg = dataclasses.replace(configs.smoke("internlm2-1.8b"),
                              dtype=jnp.float32)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    if kind == "paged":
        sched = PagedScheduler(model, params, capacity=64, slots=2,
                               chunk=4, page_size=16)
    else:
        sched = Scheduler(model, params, capacity=64, slots=2, chunk=4)
    return cfg, sched


def _reqs(cfg, uids):
    key = jax.random.key(0)
    return [Request(uid=u,
                    prompt=jax.random.randint(jax.random.fold_in(key, u),
                                              (8,), 0, cfg.vocab_size),
                    max_new=6) for u in uids]


@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_scheduler_one_transfer_per_chunk_zero_retrace(kind):
    """PR 3/5's accounting claims as enforced invariants: the measured
    region performs exactly chunks_run device->host transfers (the
    engine's own counter agrees) and compiles nothing after warmup."""
    cfg, sched = _smoke_scheduler(kind)
    for r in _reqs(cfg, range(3)):     # warmup at the same shapes
        sched.submit(r)
    sched.run()
    chunks0, transfers0 = sched.chunks_run, sched.host_transfers
    with sanitize() as rep:
        for r in _reqs(cfg, range(10, 13)):
            sched.submit(r)
        sched.run()
    chunks = sched.chunks_run - chunks0
    assert chunks > 0
    assert rep.transfers == chunks
    assert sched.host_transfers - transfers0 == chunks
    assert rep.compiles == 0


def test_sanitize_pass_catches_injected_violations():
    findings = sanitizer._check_scheduler(
        lambda model, params: Scheduler(model, params, capacity=64,
                                        slots=2, chunk=4),
        "dense", inject=("transfer", "retrace"))
    rules = {f.rule for f in findings}
    assert "SAN001" in rules           # the extra device_get
    assert "SAN002" in rules           # the mid-region fresh jit


def test_cli_sanitize_injection_exits_nonzero():
    assert cli_main(["--passes", "sanitize",
                     "--inject-sanitize", "retrace"]) != 0


# ------------------------------------------------- lint rules

def _lint(tmp_path, source, rel_path="src/x.py"):
    p = tmp_path / "x.py"
    p.write_text(textwrap.dedent(source))
    return lint.check_file(str(p), rel_path=rel_path)


def test_ra001_bare_except(tmp_path):
    fs = _lint(tmp_path, """\
        try:
            pass
        except:
            pass
        """)
    assert [f.rule for f in fs] == ["RA001"]


def test_ra001_blind_except_exception(tmp_path):
    fs = _lint(tmp_path, """\
        try:
            pass
        except Exception:
            pass
        """)
    assert [f.rule for f in fs] == ["RA001"]


def test_ra001_bound_but_unused(tmp_path):
    fs = _lint(tmp_path, """\
        try:
            pass
        except Exception as e:
            pass
        """)
    assert [f.rule for f in fs] == ["RA001"]
    assert "never uses it" in fs[0].message


def test_ra001_clean_variants(tmp_path):
    fs = _lint(tmp_path, """\
        try:
            pass
        except ValueError:
            pass
        try:
            pass
        except Exception:
            raise
        try:
            pass
        except Exception as e:
            print(e)
        """)
    assert fs == []


def test_ra002_device_get_outside_chokepoint(tmp_path):
    fs = _lint(tmp_path, """\
        import jax
        def f(x):
            return jax.device_get(x)
        """)
    assert [f.rule for f in fs] == ["RA002"]


def test_ra002_chokepoint_and_suppression_clean(tmp_path):
    fs = _lint(tmp_path, """\
        import jax
        def _device_get(x):
            return jax.device_get(x)
        def g(x):
            return jax.device_get(x)   # lint: allow RA002 (test fixture)
        """)
    assert fs == []


def test_ra002_from_import(tmp_path):
    fs = _lint(tmp_path, "from jax import device_get\n")
    assert [f.rule for f in fs] == ["RA002"]


def test_ra003_routing_kwargs(tmp_path):
    src = """\
        from repro.kernels import ops
        def f(x, w):
            return ops.ternary_matmul(x, w, backend="xla", bm=128)
        """
    fs = _lint(tmp_path, src, rel_path="src/repro/serve/x.py")
    assert [f.rule for f in fs] == ["RA003"]
    # the kernels package itself is the one layer allowed kwargs
    assert _lint(tmp_path, src, rel_path="src/repro/kernels/x.py") == []


def test_ra004_unseeded_rng_benchmarks_only(tmp_path):
    src = """\
        import numpy as np
        import random
        def f():
            a = np.random.randn(3)
            b = random.random()
            rng = np.random.default_rng()
            ok = np.random.default_rng(0)
            return a, b, rng, ok
        """
    fs = _lint(tmp_path, src, rel_path="benchmarks/x.py")
    assert [f.rule for f in fs] == ["RA004"] * 3
    assert _lint(tmp_path, src, rel_path="src/x.py") == []


def test_ra004_jax_key_seed_derivation(tmp_path):
    src = """\
        import jax
        from benchmarks.common import stable_seed
        def f(n):
            bad = jax.random.key(100 + n)
            bad2 = jax.random.PRNGKey(hash("x"))
            ok = jax.random.key(42)
            ok2 = jax.random.key(stable_seed("sweep", n))
            return bad, bad2, ok, ok2
        """
    fs = _lint(tmp_path, src, rel_path="benchmarks/x.py")
    assert [f.rule for f in fs] == ["RA004"] * 2
    assert all("stable_seed" in f.message for f in fs)
    # seed hygiene is a benchmarks-only contract
    assert _lint(tmp_path, src, rel_path="src/x.py") == []


def test_ra000_malformed_suppression(tmp_path):
    fs = _lint(tmp_path, "x = 1   # lint: allow everything\n")
    assert [f.rule for f in fs] == ["RA000"]


def test_suppression_in_string_literal_is_not_parsed(tmp_path):
    fs = _lint(tmp_path, "doc = 'use # lint: allow RAxxx (reason)'\n")
    assert fs == []


def test_cli_lint_violation_exits_nonzero(tmp_path):
    (tmp_path / "bad.py").write_text(
        "try:\n    pass\nexcept Exception:\n    pass\n")
    assert cli_main(["--passes", "lint",
                     "--lint-paths", str(tmp_path)]) != 0


# ------------------------------------------------- RA005: frontend purity

FRONTEND_REL = "src/repro/frontend/x.py"


def test_ra005_device_get_banned_even_in_chokepoint(tmp_path):
    src = """\
        import jax
        def _device_get(x):
            return jax.device_get(x)
        """
    fs = _lint(tmp_path, src, rel_path=FRONTEND_REL)
    assert [f.rule for f in fs] == ["RA005"]
    assert "per-chunk payload" in fs[0].message
    # outside the frontend the audited chokepoint idiom stays legal
    assert _lint(tmp_path, src, rel_path="src/repro/serve/x.py") == []


def test_ra005_from_import_device_get(tmp_path):
    fs = _lint(tmp_path, "from jax import device_get\n",
               rel_path=FRONTEND_REL)
    assert [f.rule for f in fs] == ["RA005"]


def test_ra005_wallclock_calls_vs_injectable_default(tmp_path):
    src = """\
        import time
        def bad():
            return time.monotonic()
        def worse():
            return time.perf_counter_ns()
        def ok(clock=time.monotonic):
            return clock()
        """
    fs = _lint(tmp_path, src, rel_path=FRONTEND_REL)
    assert [f.rule for f in fs] == ["RA005"] * 2
    assert all("inject" in f.message for f in fs)
    # wall-clock hygiene is a frontend-only contract
    assert _lint(tmp_path, src, rel_path="src/repro/serve/x.py") == []


def test_ra005_rng_and_unbounded_deque(tmp_path):
    src = """\
        import random
        from collections import deque
        import numpy as np
        def f():
            a = np.random.randn(3)
            b = random.random()
            c = np.random.default_rng()
            q = deque()
            ok = np.random.default_rng(0)
            ok2 = deque(maxlen=8)
            ok3 = deque([1, 2], 2)
            return a, b, c, q, ok, ok2, ok3
        """
    fs = _lint(tmp_path, src, rel_path=FRONTEND_REL)
    assert [f.rule for f in fs] == ["RA005"] * 4
    assert _lint(tmp_path, src, rel_path="src/repro/serve/x.py") == []


# ------------------------------------------------- frontend pass

def test_frontend_pass_clean():
    from repro.analysis import frontend
    assert frontend.run() == []


def test_frontend_pass_catches_injected_transfer():
    from repro.analysis import frontend
    fs = frontend._check_streaming(inject=("transfer",))
    assert fs and all(f.rule == "FE001" for f in fs)


def test_frontend_pass_catches_dropped_accounting():
    from repro.analysis import frontend
    fs = frontend._check_backpressure(inject=("drop",))
    assert fs and all(f.rule == "FE002" for f in fs)
    assert any("silently dropped" in f.message for f in fs)


def test_frontend_pass_catches_perturbed_admission_order():
    from repro.analysis import frontend
    fs = frontend._check_determinism(inject=("order",))
    assert any(f.rule == "FE003" and "diverge" in f.message for f in fs)


def test_cli_frontend_injection_exits_nonzero():
    assert cli_main(["--passes", "frontend",
                     "--inject-frontend", "drop"]) != 0


# ------------------------------------------------- lint config hygiene

def test_repo_rules_toml_is_valid_and_wildcard_free():
    findings = []
    cfg = lint.load_config(lint.CONFIG_PATH, findings)
    assert findings == []              # every entry has rule + reason
    assert all(lint._RULE_ID_RE.match(rule)
               for rule, _, _ in cfg["suppress"])


def test_config_rejects_wildcards_and_empty_reasons(tmp_path):
    bad = tmp_path / "rules.toml"
    bad.write_text(textwrap.dedent("""\
        [[suppress]]
        rule = "*"
        path = "src"
        reason = "everything"

        [[suppress]]
        rule = "RA001"
        path = "src"
        reason = ""
        """))
    findings = []
    cfg = lint.load_config(str(bad), findings)
    assert cfg["suppress"] == []       # neither suppression applies
    assert len(findings) == 2
    assert all(f.rule == "RA000" for f in findings)


def test_config_suppression_applies_by_path(tmp_path):
    (tmp_path / "bad.py").write_text(
        "import jax\nx = jax.device_get(1)\n")
    cfg = tmp_path / "rules.toml"
    cfg.write_text(textwrap.dedent(f"""\
        [[suppress]]
        rule = "RA002"
        path = "{lint.rel(str(tmp_path))}"
        reason = "test fixture tree"
        """))
    assert lint.run(paths=[str(tmp_path)], config=str(cfg)) == []
    # same tree without the suppression: the finding is live
    assert [f.rule for f in lint.run(paths=[str(tmp_path)],
                                     config=str(tmp_path / "none.toml"))
            ] == ["RA002"]


# ------------------------------------------------- registry mutation

_SHAPE = (8, 64, 32)


def _spec_clone(name, priority, base="xla"):
    return dataclasses.replace(get_backend(base), name=name,
                               priority=priority)


def test_register_existing_requires_override():
    with pytest.raises(ValueError, match="override=True"):
        register_backend(_spec_clone("xla", 1))


def test_register_override_replaces_builtin():
    original = get_backend("xla")
    try:
        register_backend(dataclasses.replace(original, priority=1),
                         override=True)
        assert get_backend("xla").priority == 1
        # the builtin keeps resolving by name with its new priority
        assert resolve_backend(backend="xla").priority == 1
    finally:
        register_backend(original, override=True)
    assert get_backend("xla").priority == original.priority


def test_unregister_unknown_is_noop():
    before = set(plan_mod.backend_names())
    unregister_backend("no-such-backend")
    assert set(plan_mod.backend_names()) == before


def test_plan_cache_invalidation_on_registry_mutation():
    """Stale cached plans must not resolve to an unregistered backend:
    registering a higher-priority backend re-routes auto plans, and
    unregistering it both re-routes new plans AND makes any plan still
    holding the dead name fail loudly in execute."""
    baseline = plan_matmul(_SHAPE).backend
    turbo = _spec_clone("turbo", 10_000)
    try:
        register_backend(turbo)
        stale = plan_matmul(_SHAPE)
        assert stale.backend == "turbo"    # cache was invalidated
    finally:
        unregister_backend("turbo")
    assert plan_matmul(_SHAPE).backend == baseline
    with pytest.raises(ValueError, match="unknown backend"):
        execute(stale, jnp.ones((8, 64)), jnp.ones((64, 32)))


# ------------------------------------------------- shard pass (SD001-SD006)

_SMOKE = (abscache.SMOKE_ARCH,)


def test_shard_pass_clean():
    """Every (variant x mesh x arch) cell of the live lattice resolves
    and re-verifies — on the repo as merged, with zero devices."""
    assert shardspec.run() == []


def test_sd001_unresolvable_axes_flagged():
    fs = shardspec.run(inject="resolve", archs=_SMOKE)
    assert fs and all(f.rule == "SD001" for f in fs)


def test_sd002_invalid_spec_flagged():
    fs = shardspec.run(inject="spec", archs=_SMOKE)
    assert fs and all(f.rule == "SD002" for f in fs)


def test_sd003_large_replication_flagged():
    fs = shardspec.run(inject="replicate", archs=_SMOKE)
    assert fs and all(f.rule == "SD003" for f in fs)


def test_sd004_mirror_divergence_flagged():
    fs = shardspec.run(inject="mirror", archs=_SMOKE)
    assert fs and all(f.rule == "SD004" for f in fs)


def test_sd005_unknown_axis_flagged():
    fs = shardspec.run(inject="axis", archs=_SMOKE)
    assert [f.rule for f in fs] == ["SD005"]
    assert "embeddd" in fs[0].message


def test_sd006_readme_drift_flagged():
    fs = shardspec.run(inject="drift", archs=_SMOKE)
    assert fs and all(f.rule == "SD006" for f in fs)


def test_typod_axis_in_model_file_caught_without_devices(tmp_path):
    """ISSUE 10 acceptance: a typo'd logical axis in a model file is a
    finding from the static pass alone — no mesh, no device code."""
    (tmp_path / "model.py").write_text(textwrap.dedent("""\
        from repro.models.registry import ParamDef
        wq = ParamDef((512, 512), ("embed", "headz"))
        """))
    fs = shardspec.run(scan_paths=(str(tmp_path),), archs=_SMOKE)
    assert [f.rule for f in fs] == ["SD005"]
    assert "headz" in fs[0].message and "model.py" in fs[0].where


def test_cli_shard_injection_exits_nonzero():
    assert cli_main(["--passes", "shard", "--inject-shard", "axis"]) != 0


def test_axis_table_round_trips():
    parsed = shardspec.parse_axis_table(shardspec.render_axis_table())
    assert parsed  # and it matches the live rules
    assert shardspec._check_readme_axes(shardspec.DIST_README) == []


# ------------------------------------------------- jaxpr pass (JX001-JX004)

def _jaxpr_injected(inject):
    entry = jaxpr_audit._injected_entry(inject)
    return jaxpr_audit._check_entry(entry, abscache.smoke_model(),
                                    inject)


def test_jaxpr_pass_clean_and_shares_abscache():
    """Every audited serve/train/frontend entry traces clean; the
    shard pass run just before it hits the shared model cache."""
    abscache.clear()
    assert shardspec.run(archs=_SMOKE) == []
    assert jaxpr_audit.run() == []
    st = abscache.stats()
    assert st["smoke_model"]["misses"] == 1     # built once...
    assert st["config"]["hits"] >= 1            # ...reused across passes


def test_jx001_unaliased_donation_flagged():
    fs = _jaxpr_injected("donation")
    assert fs and all(f.rule == "JX001" for f in fs)


def test_jx002_widening_flagged():
    fs = _jaxpr_injected("widen")
    assert fs and all(f.rule == "JX002" for f in fs)
    assert any("float64" in f.message for f in fs)


def test_jx003_callback_flagged():
    fs = _jaxpr_injected("callback")
    rules = {f.rule for f in fs}
    # the debug print is both a banned primitive (JX003) and a debug
    # effect — an extra channel out of the graph (JX004); both correct
    assert "JX003" in rules


def test_jx004_arity_drift_flagged():
    fs = _jaxpr_injected("transfer")
    assert [f.rule for f in fs] == ["JX004"]
    assert "arity" in fs[0].message


def test_cli_jaxpr_injection_exits_nonzero():
    assert cli_main(["--passes", "jaxpr",
                     "--inject-jaxpr", "transfer"]) != 0


def test_manifest_entries_declare_unique_names():
    names = [e.name for e in jaxpr_audit.manifest_entries()]
    assert len(names) == len(set(names)) and len(names) >= 9


# ------------------------------------------------- dead suppressions

def test_dead_inline_suppression_is_ra000(tmp_path):
    fs = _lint(tmp_path, "x = 1   # lint: allow RA002 (stale)\n")
    assert [f.rule for f in fs] == ["RA000"]
    assert "dead suppression" in fs[0].message


def test_matched_inline_suppression_is_not_dead(tmp_path):
    fs = _lint(tmp_path, """\
        import jax
        x = jax.device_get(1)   # lint: allow RA002 (fixture)
        """)
    assert fs == []


def test_dead_config_suppression_is_ra000(tmp_path):
    (tmp_path / "ok.py").write_text("x = 1\n")
    cfg = tmp_path / "rules.toml"
    cfg.write_text(textwrap.dedent(f"""\
        [[suppress]]
        rule = "RA002"
        path = "{lint.rel(str(tmp_path))}"
        reason = "stale fixture"
        """))
    fs = lint.run(paths=[str(tmp_path)], config=str(cfg))
    assert [f.rule for f in fs] == ["RA000"]
    assert "dead suppression" in fs[0].message


def test_config_suppression_outside_scan_is_not_audited(tmp_path):
    """A --lint-paths subset run must not declare repo-wide
    suppressions dead: only entries under the scanned trees are
    audited."""
    (tmp_path / "ok.py").write_text("x = 1\n")
    cfg = tmp_path / "rules.toml"
    cfg.write_text(textwrap.dedent("""\
        [[suppress]]
        rule = "RA002"
        path = "src/somewhere/else.py"
        reason = "lives outside this scan"
        """))
    assert lint.run(paths=[str(tmp_path)], config=str(cfg)) == []


# ------------------------------------------------- findings artifact

def test_cli_json_out_writes_findings_document(tmp_path):
    out = tmp_path / "findings.json"
    rc = cli_main(["--passes", "lint", "--format", "json",
                   "--out", str(out)])
    doc = json.loads(out.read_text())
    assert rc == 0 and doc["ok"] is True
    assert doc["passes"][0]["name"] == "lint"
    assert "seconds" in doc["passes"][0]
    assert "abscache" in doc
