"""Serving engine: batched generation, bucketing, packed-ternary serving,
engine output == manual prefill/decode loop."""
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.cim_linear import CIMConfig, hbm_bytes, ternarize_params
from repro.models import registry
from repro.serve import Request, ServeEngine, make_decode_step, \
    make_prefill_step


def _setup(arch="internlm2-1.8b", dtype=jnp.float32):
    cfg = dataclasses.replace(configs.smoke(arch), dtype=dtype)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


import pytest


@pytest.mark.parametrize("on_device_loop", [True, False],
                         ids=["device-loop", "legacy-step-loop"])
def test_engine_generates_batch(on_device_loop):
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, capacity=64, max_batch=4,
                      on_device_loop=on_device_loop)
    key = jax.random.key(1)
    for i in range(6):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (8,), 0,
                                    cfg.vocab_size)
        eng.submit(Request(uid=i, prompt=prompt, max_new=5))
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.out_tokens) == 5 for r in done)
    assert all(0 <= t < cfg.padded_vocab for r in done for t in r.out_tokens)


@pytest.mark.parametrize("on_device_loop", [True, False],
                         ids=["device-loop", "legacy-step-loop"])
def test_engine_matches_manual_loop(on_device_loop):
    cfg, model, params = _setup()
    prompt = jax.random.randint(jax.random.key(2), (8,), 0, cfg.vocab_size)

    eng = ServeEngine(model, params, capacity=64, max_batch=1,
                      on_device_loop=on_device_loop)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4))
    got = eng.run()[0].out_tokens

    pre = make_prefill_step(model, 64)
    dec = make_decode_step(model)
    tok, state = pre(params, {"tokens": prompt[None]})
    want = [int(tok[0])]
    for _ in range(3):
        tok, state = dec(params, tok, state)
        want.append(int(tok[0]))
    assert got == want


def test_bucketing_by_prompt_length():
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, capacity=64, max_batch=8)
    for i, ln in enumerate([8, 8, 16, 8, 16]):
        eng.submit(Request(uid=i, prompt=jnp.zeros((ln,), jnp.int32),
                           max_new=2))
    done = eng.run()
    assert len(done) == 5


def test_eos_stops_row():
    cfg, model, params = _setup()
    prompt = jnp.zeros((4,), jnp.int32)
    eng = ServeEngine(model, params, capacity=32, max_batch=1)
    # eos = whatever greedy produces first -> generation stops at 1 token
    pre = make_prefill_step(model, 32)
    tok, _ = pre(params, {"tokens": prompt[None]})
    eng.submit(Request(uid=0, prompt=prompt, max_new=8, eos_id=int(tok[0])))
    done = eng.run()
    assert len(done[0].out_tokens) == 1


def test_packed_ternary_serving_runs_and_shrinks_weights():
    cfg, model, params = _setup()
    raw = hbm_bytes(params)
    cim = CIMConfig(mode="ternary", packing="base3")
    packed = ternarize_params(params, cim)
    assert hbm_bytes(packed) < raw
    eng = ServeEngine(model, packed, capacity=32, max_batch=2, cim=cim)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=jnp.arange(6, dtype=jnp.int32),
                           max_new=3))
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.out_tokens) == 3 for r in done)


def test_packed_xla_backend_matches_pallas_interpret():
    cfg, model, params = _setup()
    cim_p = CIMConfig(mode="ternary", packing="base3")
    cim_x = CIMConfig(mode="ternary", packing="base3", backend="xla")
    packed = ternarize_params(params, cim_p)
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
    lp, _ = model.prefill(packed, batch, 16, cim=cim_p)
    lx, _ = model.prefill(packed, batch, 16, cim=cim_x)
    assert jnp.allclose(lp.astype(jnp.float32), lx.astype(jnp.float32),
                        atol=1e-3, rtol=1e-3)


# ------------------------------------------------- latency percentiles

def test_latency_stats_interpolates_percentiles():
    """Linear interpolation between order statistics (ISSUE 5
    satellite): the old nearest-rank ``int(q*(n-1)+0.5)`` made every
    small-sample p99 degenerate to the max.  Pin exact values for known
    inputs."""
    from repro.serve import latency_stats, percentile

    def stats(vals):
        rs = [Request(uid=i, prompt=None) for i in range(len(vals))]
        for r, v in zip(rs, vals):
            r.latency_s = v
        return latency_stats(rs)

    s = stats([1.0, 2.0, 3.0, 4.0, 5.0])
    assert s["p50_s"] == 3.0
    assert s["p99_s"] == 4.96            # 4 + 0.96*(5-4), not the max
    assert s["mean_s"] == 3.0

    s = stats([0.0, 10.0])
    assert s["p50_s"] == 5.0             # interpolated midpoint
    assert s["p99_s"] == 9.9

    one = stats([7.0])
    assert (one["p50_s"], one["p99_s"], one["p999_s"], one["mean_s"]) \
        == (7.0, 7.0, 7.0, 7.0)
    assert one["queue_wait_mean_s"] == 0.0 and one["service_mean_s"] == 7.0
    empty = stats([])
    assert set(empty) == set(one) and set(empty.values()) == {0.0}

    lat = sorted([3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.3, 5.8])
    assert percentile(lat, 0.0) == lat[0]
    assert percentile(lat, 1.0) == lat[-1]
    # monotone in q
    qs = [i / 20 for i in range(21)]
    vals = [percentile(lat, q) for q in qs]
    assert vals == sorted(vals)
