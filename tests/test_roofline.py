"""Roofline machinery: HLO parser vs XLA ground truth, loop awareness,
collective wire formulas, report plumbing."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analyze_compiled, collective_bytes, model_flops
from repro.roofline.hlo_cost import analyze_text, parse_module

W = jnp.ones((128, 128), jnp.float32)


def test_loop_free_matches_xla():
    def f(x):
        return (x @ W).sum()
    x = jnp.ones((128, 128), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    mine = analyze_text(c.as_text(), 1)
    assert abs(mine.flops - ca["flops"]) / ca["flops"] < 0.05
    assert abs(mine.bytes - ca["bytes accessed"]) / ca["bytes accessed"] < 0.3


def test_scan_multiplies_by_trip_count():
    def body(x, _):
        return x @ W, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y.sum()
    x = jnp.ones((128, 128), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    mine = analyze_text(c.as_text(), 1)
    one_matmul = 2 * 128**3
    assert mine.flops == pytest.approx(12 * one_matmul, rel=0.05)
    assert mine.unknown_trips == 0


def test_nested_scan():
    def inner(x, _):
        return x @ W, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()
    x = jnp.ones((128, 128), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    mine = analyze_text(c.as_text(), 1)
    assert mine.flops == pytest.approx(15 * 2 * 128**3, rel=0.05)


SYNTH_HLO = """
HloModule test

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p0), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[64,64]{1,0} all-reduce(%ag), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(%ar), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
  %cp = f32[8,64]{1,0} collective-permute(%rs), channel_id=4, source_target_pairs={{0,1}}
  ROOT %out = f32[64,64]{1,0} all-gather(%cp), channel_id=5, replica_groups=[1,8]<=[8], dimensions={0}
}
"""


def test_collective_wire_formulas():
    cost = analyze_text(SYNTH_HLO, 8)
    sz = 64 * 64 * 4
    shard = 8 * 64 * 4
    assert cost.coll_by_kind["all-reduce"] == pytest.approx(2 * sz * 7 / 8)
    # two all-gathers: group of 4 and group of 8
    assert cost.coll_by_kind["all-gather"] == pytest.approx(
        sz * 3 / 4 + sz * 7 / 8)
    assert cost.coll_by_kind["reduce-scatter"] == pytest.approx(shard * 7)
    assert cost.coll_by_kind["collective-permute"] == pytest.approx(shard)


def test_analyze_compiled_report():
    from repro import configs

    def f(x):
        return (x @ W).sum()
    x = jnp.ones((128, 128), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    cfg = configs.smoke("internlm2-1.8b")
    r = analyze_compiled(c, arch="t", shape="s", mesh_name="1", chips=1,
                         cfg=cfg, tokens=1024, kind="train")
    assert r.bottleneck in ("compute", "memory", "collective")
    assert r.t_compute > 0 and r.t_memory > 0
    assert r.model_flops_total == pytest.approx(
        6 * cfg.param_count() * 1024)
    assert "|" in r.row()


def test_model_flops_moe_uses_active_params():
    from repro import configs
    cfg = configs.get("mixtral-8x7b")
    mf = model_flops(cfg, 1000, "train")
    assert mf < 6 * cfg.param_count() * 1000
    assert mf == pytest.approx(6 * cfg.active_param_count() * 1000)


def test_dus_counts_slice_not_buffer():
    def f(x, buf):
        return jax.lax.dynamic_update_slice(buf, x[None], (3, 0, 0))
    x = jnp.ones((64, 64), jnp.float32)
    buf = jnp.zeros((100, 64, 64), jnp.float32)
    # donate buf so the in-place DUS needs no defensive copy
    c = jax.jit(f, donate_argnums=(1,)).lower(x, buf).compile()
    mine = analyze_text(c.as_text(), 1)
    # traffic ~ 2x the 64x64 update, NOT the 100x64x64 buffer
    assert mine.bytes < 10 * 64 * 64 * 4
