"""llm_capacity benchmark helpers: LayerSpec derivation consistency."""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest

from benchmarks.llm_capacity import lm_layer_specs
from repro import configs


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen3-14b",
                                  "mixtral-8x7b", "kimi-k2-1t-a32b",
                                  "whisper-large-v3"])
def test_layer_specs_match_param_count(arch):
    """The energy model's LayerSpecs must account for (almost) all of the
    model's parameters — within 15% of the ParamDef ground truth (norms,
    embed table, ssm/conv oddments are excluded by design)."""
    cfg = configs.get(arch)
    spec_params = sum(l.params() for l in lm_layer_specs(cfg))
    true_params = cfg.param_count()
    # embed table excluded from specs; compare against matmul-ish params
    ratio = spec_params / true_params
    assert 0.6 < ratio < 1.15, (arch, ratio)


def test_moe_macs_use_active_fraction():
    cfg = configs.get("kimi-k2-1t-a32b")
    specs = lm_layer_specs(cfg, batch=1)
    expert_macs = sum(l.macs() for l in specs if "moe" in l.name)
    expert_params = sum(l.params() for l in specs if "moe" in l.name)
    frac = cfg.experts_per_token / cfg.num_experts
    assert expert_macs == pytest.approx(expert_params * frac, rel=1e-6)


def test_batch_scales_dense_macs_linearly():
    cfg = configs.get("glm4-9b")
    m1 = sum(l.macs() for l in lm_layer_specs(cfg, 1))
    m8 = sum(l.macs() for l in lm_layer_specs(cfg, 8))
    assert m8 == pytest.approx(8 * m1, rel=1e-6)
