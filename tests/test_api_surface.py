"""Public-API snapshot: the exported surface of ``repro.kernels`` and
``repro.core.cim_linear`` is pinned to tests/api_manifest.json.

Runs in the `quick` CI gate (not marked slow), so any surface drift —
a renamed export, a changed signature, a new CIMConfig field — shows up
as an explicit manifest diff instead of an accident discovered by a
downstream breakage.

Regenerate after an INTENTIONAL surface change:

    PYTHONPATH=src:tests python tests/test_api_surface.py --update
"""
import dataclasses
import inspect
import json
import os
import sys

import pytest

MANIFEST_PATH = os.path.join(os.path.dirname(__file__), "api_manifest.json")

# module -> exported names (repro.kernels pins its whole __all__)
SURFACE = {
    "repro.kernels": None,           # None: use __all__
    "repro.kernels.ops": ["PackedTernary", "pack_weights",
                          "quantize_acts_int8", "ternary_matmul",
                          "ternary_matmul_int8", "cim_matmul",
                          "ternary_matmul_xla", "ternary_matmul_int8_xla"],
    "repro.core.cim_linear": ["CIMConfig", "linear", "ternarize_params",
                              "hbm_bytes", "MODES"],
}


def _describe(obj) -> dict:
    """JSON-stable description of one exported symbol."""
    if dataclasses.is_dataclass(obj) and isinstance(obj, type):
        entry = {"kind": "dataclass",
                 "fields": {f.name: repr(f.default)
                            if f.default is not dataclasses.MISSING
                            else "<required>"
                            for f in dataclasses.fields(obj)}}
        methods = {n: str(inspect.signature(m))
                   for n, m in vars(obj).items()
                   if not n.startswith("_") and callable(m)}
        if methods:
            entry["methods"] = methods
        return entry
    if inspect.isclass(obj):
        return {"kind": "class",
                "methods": {n: str(inspect.signature(m))
                            for n, m in vars(obj).items()
                            if not n.startswith("_")
                            and inspect.isfunction(m)}}
    if callable(obj):
        return {"kind": "function", "signature": str(inspect.signature(obj))}
    if inspect.ismodule(obj):
        return {"kind": "module"}
    return {"kind": type(obj).__name__, "value": repr(obj)}


def snapshot() -> dict:
    import importlib
    out = {}
    for modname, names in SURFACE.items():
        mod = importlib.import_module(modname)
        if names is None:
            names = list(getattr(mod, "__all__"))
        out[modname] = {name: _describe(getattr(mod, name))
                        for name in sorted(names)}
    return out


def test_public_api_matches_manifest():
    assert os.path.exists(MANIFEST_PATH), (
        f"missing {MANIFEST_PATH}; generate it with "
        f"`PYTHONPATH=src:tests python tests/test_api_surface.py --update`")
    with open(MANIFEST_PATH) as f:
        pinned = json.load(f)
    current = snapshot()
    diffs = []
    for mod in sorted(set(pinned) | set(current)):
        p, c = pinned.get(mod, {}), current.get(mod, {})
        for name in sorted(set(p) | set(c)):
            if name not in c:
                diffs.append(f"{mod}.{name}: removed from surface")
            elif name not in p:
                diffs.append(f"{mod}.{name}: new export (not in manifest)")
            elif p[name] != c[name]:
                diffs.append(f"{mod}.{name}: {p[name]} -> {c[name]}")
    assert not diffs, (
        "public API drift vs tests/api_manifest.json — if intentional, "
        "regenerate with `PYTHONPATH=src:tests python "
        "tests/test_api_surface.py --update`:\n  " + "\n  ".join(diffs))


def test_manifest_covers_plan_entrypoints():
    # the redesign's load-bearing exports must stay pinned
    with open(MANIFEST_PATH) as f:
        pinned = json.load(f)
    kernels = pinned["repro.kernels"]
    for name in ("ExecutionPlan", "plan_matmul", "execute",
                 "register_backend", "BackendSpec"):
        assert name in kernels, name


if __name__ == "__main__":
    if "--update" in sys.argv:
        with open(MANIFEST_PATH, "w") as f:
            json.dump(snapshot(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {MANIFEST_PATH}")
    else:
        print(__doc__)
        sys.exit(pytest.main([__file__, "-q"]))
