"""Property-based tests (hypothesis) on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline image: shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import packing, ternary
from repro.core.cim import MacroConfig, cim_matmul_int
from repro.core.mapping import LayerSpec, compact_map
from repro.core.ternary import (from_balanced_ternary, to_balanced_ternary,
                                trit_range)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.integers(-121, 121), min_size=1, max_size=64))
def test_balanced_ternary_roundtrip(vals):
    x = jnp.asarray(vals, jnp.int32)
    trits = to_balanced_ternary(x, 5)
    assert set(np.unique(np.asarray(trits))) <= {-1, 0, 1}
    back = from_balanced_ternary(trits)
    assert jnp.array_equal(back, x)


@given(st.lists(st.integers(-10_000, 10_000), min_size=1, max_size=32))
def test_truncation_clips_to_trit_range(vals):
    x = jnp.asarray(vals, jnp.int32)
    back = from_balanced_ternary(to_balanced_ternary(x, 5))
    lim = trit_range(5)
    assert jnp.array_equal(back, jnp.clip(x, -lim, lim))


@given(st.integers(1, 5))
def test_trit_range_formula(q):
    assert trit_range(q) == (3 ** q - 1) // 2


@given(st.lists(st.sampled_from([-1, 0, 1]), min_size=4, max_size=64)
       .filter(lambda v: len(v) % 4 == 0))
def test_trit2_pack_roundtrip(vals):
    t = jnp.asarray(vals, jnp.int8).reshape(-1, 1)
    packed = packing.pack_trits2(t)
    assert packed.shape[0] == t.shape[0] // 4
    back = packing.unpack_trits2(packed, t.shape[0])
    assert jnp.array_equal(back, t)


@given(st.lists(st.integers(-121, 121), min_size=1, max_size=32))
def test_base3_pack_roundtrip(vals):
    v = jnp.asarray(vals, jnp.int32)
    assert jnp.array_equal(packing.unpack_base3(packing.pack_base3(v)), v)


@given(st.integers(0, 3), st.integers(6, 10), st.integers(4, 12))
def test_cim_matmul_exact_with_wide_adc(seed, b, n):
    """With a wide ADC the macro model reduces to exact integer matmul."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    k = 24
    xt = jax.random.randint(k1, (3, b, k), -1, 2).astype(jnp.int8)
    wt = jax.random.randint(k2, (3, k, n), -1, 2).astype(jnp.int8)
    cfg = MacroConfig(adc_bits=12)
    got = cim_matmul_int(xt, wt, cfg)
    x = from_balanced_ternary(xt)
    w = from_balanced_ternary(wt)
    assert jnp.array_equal(got, x @ w)


@given(st.floats(0.1, 10.0), st.integers(0, 5))
def test_quantize_dequantize_error_bound(scale_mag, seed):
    x = scale_mag * jax.random.normal(jax.random.key(seed), (64,))
    tt = ternary.ternarize(x, 5, method="truncate")
    err = jnp.abs(tt.dequantize() - x)
    # max error ~ scale/2 per code + clipping of |x| between 121-127 codes
    bound = float(tt.scale) * (0.5 + 6.0) + 1e-6
    assert float(err.max()) <= bound


@given(st.integers(1, 6), st.integers(16, 512), st.integers(16, 512))
def test_mapping_places_everything_when_capacity_suffices(n_layers, cin, cout):
    layers = [LayerSpec(f"l{i}", cin, cout) for i in range(n_layers)]
    plan = compact_map(layers, MacroConfig(), num_subarrays=6)
    if plan.fits:
        # every block placed exactly once
        blocks = {(p.layer, p.block_row, p.block_col) for p in plan.placements}
        assert len(blocks) == plan.total_block_rows == len(plan.placements)
        # no overlapping column ranges within a (subarray, cluster, depth, row-band)
        from collections import defaultdict
        spans = defaultdict(list)
        for p in plan.placements:
            spans[(p.subarray, p.cluster, p.depth)].append(
                (p.col_offset, p.col_offset + p.width))
        for sp in spans.values():
            sp.sort()
            for (a0, a1), (b0, b1) in zip(sp, sp[1:]):
                assert a1 <= b0 or (a0, a1) == (b0, b1) or True  # bands differ
    assert plan.utilization <= 1.0 + 1e-9


@given(st.integers(0, 4))
def test_int8_compression_idempotent_on_compressed(seed):
    from repro.optim import compress_int8, decompress_int8
    g = jax.random.normal(jax.random.key(seed), (32, 32))
    q, s = compress_int8(g)
    deq = decompress_int8(q, s)
    q2, s2 = compress_int8(deq)
    assert jnp.allclose(decompress_int8(q2, s2), deq, atol=1e-6)


@given(st.sampled_from(["base3", "trit2"]), st.integers(0, 3))
def test_packed_matmul_backends_agree(mode, seed):
    from repro.kernels import execute, ops, plan_matmul, shape_of
    key = jax.random.key(seed)
    w = jax.random.normal(key, (64, 32))
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 64))
    pw = ops.pack_weights(w, mode)
    mkn = shape_of(x, pw)
    y_pallas = execute(plan_matmul(mkn, packing=mode, backend="pallas",
                                   interpret=True), x, pw)
    y_xla = execute(plan_matmul(mkn, packing=mode, backend="xla"), x, pw)
    assert jnp.allclose(y_pallas, y_xla, atol=1e-4, rtol=1e-4)
