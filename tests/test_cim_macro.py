"""Tests for the TL-nvSRAM-CIM functional macro (store/restore/CIM modes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # offline image: shim
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import cim, device_models as dm, ternary

jax.config.update("jax_platform_name", "cpu")


class TestStoreRestore:
    def test_store_levels_table1(self):
        trits = jnp.array([1, 0, -1])
        levels = cim.store_trits_to_levels(trits)
        np.testing.assert_array_equal(np.asarray(levels),
                                      [cim.LRS, cim.MRS, cim.HRS])

    def test_ideal_roundtrip(self):
        trits = jnp.array([-1, 0, 1, 1, 0, -1], dtype=jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(cim.roundtrip_store_restore(trits)), np.asarray(trits))

    def test_nominal_resistance_restore(self):
        """With nominal (variation-free) resistances the differential
        discharge comparison must decode every state correctly."""
        d = dm.DeviceParams()
        trits = jnp.array([-1, 0, 1], dtype=jnp.int8)
        levels = cim.store_trits_to_levels(trits)
        r = dm.level_resistance(levels, d)
        got = cim.restore_levels_to_trits(levels, resistances=r, device=d)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(trits))

    def test_optimal_mrs_is_paper_value(self):
        # §3.2: MRS maximizing min(MRS/LRS, HRS/MRS) evaluates to ~282 kΩ
        assert abs(dm.optimal_mrs(80e3, 1e6) - 282.8e3) < 1e3


class TestCIMMode:
    def test_exact_equals_int_matmul_small(self):
        """With 16-row groups the ADC covers counts 0..31; only the extreme
        all-(-1) count of 32 saturates. For random +-1/0 data the CIM MAC
        must equal the integer matmul."""
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = jax.random.randint(k1, (5, 4, 37), -1, 2, dtype=jnp.int8)
        w = jax.random.randint(k2, (5, 37, 13), -1, 2, dtype=jnp.int8)
        cfg = cim.MacroConfig()
        got = cim.cim_matmul_int(x, w, cfg)
        xi = ternary.from_balanced_ternary(x)
        wi = ternary.from_balanced_ternary(w)
        want = xi.astype(jnp.int32) @ wi.astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_adc_saturation_extreme_pattern(self):
        """All products = -1 in a full 16-row group -> count 32 -> clips to
        31 -> one LSB of error: the macro's intrinsic nonideality."""
        cfg = cim.MacroConfig()
        x = jnp.ones((1, 1, 16), dtype=jnp.int8)
        w = -jnp.ones((1, 16, 1), dtype=jnp.int8)
        got = int(cim.cim_matmul_int(x, w, cfg)[0, 0])
        assert got == -15  # true -16, saturated by the 5-bit ADC
        # with a 6-bit ADC the same pattern is exact
        cfg6 = cim.MacroConfig(adc_bits=6)
        assert int(cim.cim_matmul_int(x, w, cfg6)[0, 0]) == -16

    @given(st.integers(0, 2**31 - 1), st.integers(1, 5), st.integers(1, 5),
           st.integers(1, 40))
    @settings(max_examples=25, deadline=None)
    def test_property_exactness_random_shapes(self, seed, qi, qw, k):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        x = jax.random.randint(k1, (qi, 3, k), -1, 2, dtype=jnp.int8)
        w = jax.random.randint(k2, (qw, k, 7), -1, 2, dtype=jnp.int8)
        # 8-bit ADC -> headroom for any 16-row count: must be exact
        cfg = cim.MacroConfig(adc_bits=8)
        got = cim.cim_matmul_int(x, w, cfg)
        want = (ternary.from_balanced_ternary(x).astype(jnp.int32)
                @ ternary.from_balanced_ternary(w).astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_float_cim_matmul_close_to_float(self):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (8, 64))
        w = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
        y_cim = cim.cim_matmul(x, w)
        y_ref = x @ w
        rel = float(jnp.linalg.norm(y_cim - y_ref) / jnp.linalg.norm(y_ref))
        assert rel < 0.02, rel  # 5t x 5t quantization noise only

    def test_signal_table_modes(self):
        # Table 2 structure: store/restore are two-phase; CIM is single.
        assert ("store", 1) in cim.SIGNAL_TABLE and ("store", 2) in cim.SIGNAL_TABLE
        assert ("restore", 1) in cim.SIGNAL_TABLE and ("restore", 2) in cim.SIGNAL_TABLE
        assert cim.SIGNAL_TABLE[("store", 2)]["STR2"] == cim.VSTR
        assert cim.SIGNAL_TABLE[("cim", 0)]["CBL"] == "MAC"


class TestMacroConfig:
    def test_paper_geometry(self):
        cfg = cim.MacroConfig()
        assert cfg.trit_cols == 160
        assert cfg.weights_per_row == 32
        assert cfg.adcs == 32
        assert cfg.trits_per_cell == 240
        assert cfg.row_groups(256) == 16
