"""Checkpointing: roundtrip, atomicity under mid-save crashes, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.ones((3,)), jnp.arange(5)],
    }


def test_roundtrip_with_target(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, extra={"note": "hi"})
    got, extra = ck.restore(str(tmp_path), target=t)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b)


def test_bfloat16_preserved(tmp_path):
    t = {"x": jnp.asarray([1.5, -2.25], jnp.bfloat16)}
    ck.save(str(tmp_path), 0, t)
    got, _ = ck.restore(str(tmp_path), target=t)
    assert got["x"].dtype == jnp.bfloat16
    assert jnp.array_equal(t["x"], got["x"])


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 13):
        ck.save(str(tmp_path), s, t)
    assert ck.latest_step(str(tmp_path)) == 13
    removed = ck.gc_old_steps(str(tmp_path), keep=2)
    assert removed == [1, 5]
    assert ck.available_steps(str(tmp_path)) == [9, 13]


def test_crash_mid_save_preserves_previous(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 10, t)
    with pytest.raises(RuntimeError, match="simulated crash"):
        ck.save(str(tmp_path), 20, t, _fail_after_files=1)
    # the wreckage is a .tmp dir; step 10 is still the latest COMPLETE one
    assert ck.latest_step(str(tmp_path)) == 10
    got, _ = ck.restore(str(tmp_path), target=t)
    assert jnp.array_equal(got["params"]["w"], t["params"]["w"])
    # next save cleans the wreckage
    ck.save(str(tmp_path), 20, t)
    assert ck.latest_step(str(tmp_path)) == 20


def test_restore_without_target_builds_dict(tmp_path):
    t = {"a": {"b": jnp.ones((2, 2))}, "c": jnp.zeros((3,))}
    ck.save(str(tmp_path), 3, t)
    got, _ = ck.restore(str(tmp_path))
    assert jnp.array_equal(got["a"]["b"], t["a"]["b"])
    assert jnp.array_equal(got["c"], t["c"])


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore with explicit (single-device) shardings — the re-shard path."""
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, t)
    got, _ = ck.restore(str(tmp_path), target=t, shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert jnp.array_equal(a, b)
    assert all(l.sharding == sh for l in jax.tree.leaves(got))


def test_manager_interval(tmp_path):
    m = ck.CheckpointManager(str(tmp_path), interval=5, keep=2)
    t = _tree()
    for s in range(12):
        m.maybe_save(s, t)
    assert ck.available_steps(str(tmp_path)) == [5, 10]
