"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
with shape/dtype sweeps and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # offline image: shim
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core.packing import pack_base3, pack_trits2
from repro.core.ternary import to_balanced_ternary
from repro.kernels import ops, ref
from repro.kernels.cim_mac import cim_mac
from repro.kernels.ternary_matmul import ternary_matmul

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(8, 16, 8), (32, 64, 16), (128, 128, 128), (100, 130, 70),
          (256, 512, 96), (1, 4096, 8)]


class TestTernaryMatmulKernel:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
    def test_base3_vs_ref(self, m, k, n, xdtype):
        key = jax.random.PRNGKey(m * 1000 + k + n)
        x = jax.random.normal(key, (m, k), xdtype)
        vals = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -121, 122)
        wp = pack_base3(vals)
        scale = jax.random.uniform(jax.random.fold_in(key, 2), (n,)) * 0.01
        got = ternary_matmul(x, wp, scale, interpret=True, bm=32, bn=32, bk=32)
        want = ref.ternary_matmul_ref(x, wp, scale, "base3")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2 if xdtype == jnp.bfloat16 else 1e-5,
                                   atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [(8, 16, 8), (64, 128, 32), (33, 60, 17)])
    def test_trit2_vs_ref(self, m, k, n):
        key = jax.random.PRNGKey(k)
        kpad = -k % 4
        x = jax.random.normal(key, (m, k + kpad), jnp.float32)
        trits = jax.random.randint(jax.random.fold_in(key, 1), (k + kpad, n),
                                   -1, 2, dtype=jnp.int8)
        wp = pack_trits2(trits)
        got = ternary_matmul(x, wp, 1.0, mode="trit2", interpret=True,
                             bm=32, bn=32, bk=32)
        want = ref.ternary_matmul_ref(x, wp, 1.0, "trit2")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @given(st.integers(0, 2**31 - 1), st.sampled_from([16, 48, 96]),
           st.sampled_from([8, 24, 40]))
    @settings(max_examples=10, deadline=None)
    def test_property_base3(self, seed, k, n):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (5, k))
        vals = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -121, 122)
        wp = pack_base3(vals)
        got = ternary_matmul(x, wp, 1.0, interpret=True, bm=8, bn=8, bk=16)
        want = x @ vals.astype(jnp.float32)
        # blocked K accumulation reorders f32 sums vs the single matmul;
        # with |w| up to 121 the bound is ~1e-4 relative, not 1e-5.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)


class TestCimMacKernel:
    @pytest.mark.parametrize("m,k,n", [(8, 16, 8), (16, 64, 16), (32, 200, 24),
                                       (4, 37, 13)])
    @pytest.mark.parametrize("qi,qw", [(5, 5), (1, 1), (3, 2)])
    def test_vs_oracle(self, m, k, n, qi, qw):
        key = jax.random.PRNGKey(m + k + n + qi * 10 + qw)
        x = jax.random.randint(key, (qi, m, k), -1, 2, dtype=jnp.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (qw, k, n), -1, 2,
                               dtype=jnp.int8)
        got = cim_mac(x, w, adc_bits=5, bm=16, bn=16, bk=16, interpret=True)
        want = ref.cim_mac_ref(x, w, adc_bits=5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_adc_saturation_matches_oracle(self):
        # the all-(-1)-products corner that saturates the 5-bit ADC
        x = jnp.ones((1, 4, 16), dtype=jnp.int8)
        w = -jnp.ones((1, 16, 4), dtype=jnp.int8)
        got = cim_mac(x, w, adc_bits=5, bm=8, bn=8, bk=16, interpret=True)
        want = ref.cim_mac_ref(x, w, adc_bits=5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(got[0, 0]) == -15

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_exact_vs_int_matmul_with_wide_adc(self, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.randint(key, (2, 4, 48), -1, 2, dtype=jnp.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (2, 48, 8), -1, 2,
                               dtype=jnp.int8)
        got = cim_mac(x, w, adc_bits=8, bm=8, bn=8, bk=16, interpret=True)
        from repro.core.ternary import from_balanced_ternary
        want = (from_balanced_ternary(x).astype(jnp.int32)
                @ from_balanced_ternary(w).astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestOpsWrappers:
    def test_pack_weights_base3_matmul(self):
        key = jax.random.PRNGKey(0)
        w = 0.02 * jax.random.normal(key, (96, 48))
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 10, 96))
        pw = ops.pack_weights(w, "base3")
        assert pw.data.dtype == jnp.uint8 and pw.data.shape == (96, 48)
        y = ops.ternary_matmul(x, pw, interpret=True, bm=16, bn=16, bk=32)
        rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.02, rel

    def test_pack_weights_trit2_density(self):
        w = 0.02 * jax.random.normal(jax.random.PRNGKey(1), (128, 64))
        pw = ops.pack_weights(w, "trit2")
        assert pw.data.shape == (32, 64)        # 4 trits/byte: 8x vs bf16
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
        y = ops.ternary_matmul(x, pw, interpret=True, bm=8, bn=16, bk=32)
        # single-trit quantization is lossy; just require usable correlation
        ref_y = x @ w
        cos = float(jnp.sum(y * ref_y) /
                    (jnp.linalg.norm(y) * jnp.linalg.norm(ref_y)))
        assert cos > 0.85, cos

    def test_ops_cim_matmul_matches_core(self):
        from repro.core import cim as cim_core
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (6, 64))
        w = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (64, 24))
        got = ops.cim_matmul(x, w, interpret=True, bm=8, bn=8, bk=16)
        # core path quantizes per-tensor; ops path per-tensor too for plain w
        want = cim_core.cim_matmul(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)
