"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
with shape/dtype sweeps and hypothesis property tests, plus the
plan/registry API contract (ExecutionPlan resolution, capability
matching, deprecation shims)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # offline image: shim
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core.packing import pack_base3, pack_trits2
from repro.core.ternary import to_balanced_ternary
from repro.kernels import (BackendSpec, ExecutionPlan, backend_names,
                           execute, ops, plan_cache_clear, plan_cache_info,
                           plan_matmul, ref, register_backend, shape_of,
                           unregister_backend)
from repro.kernels.cim_mac import cim_mac
from repro.kernels.ternary_matmul import ternary_matmul

jax.config.update("jax_platform_name", "cpu")

SHAPES = [(8, 16, 8), (32, 64, 16), (128, 128, 128), (100, 130, 70),
          (256, 512, 96), (1, 4096, 8)]


class TestTernaryMatmulKernel:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
    def test_base3_vs_ref(self, m, k, n, xdtype):
        key = jax.random.PRNGKey(m * 1000 + k + n)
        x = jax.random.normal(key, (m, k), xdtype)
        vals = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -121, 122)
        wp = pack_base3(vals)
        scale = jax.random.uniform(jax.random.fold_in(key, 2), (n,)) * 0.01
        got = ternary_matmul(x, wp, scale, interpret=True, bm=32, bn=32, bk=32)
        want = ref.ternary_matmul_ref(x, wp, scale, "base3")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2 if xdtype == jnp.bfloat16 else 1e-5,
                                   atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [(8, 16, 8), (64, 128, 32), (33, 60, 17)])
    def test_trit2_vs_ref(self, m, k, n):
        key = jax.random.PRNGKey(k)
        kpad = -k % 4
        x = jax.random.normal(key, (m, k + kpad), jnp.float32)
        trits = jax.random.randint(jax.random.fold_in(key, 1), (k + kpad, n),
                                   -1, 2, dtype=jnp.int8)
        wp = pack_trits2(trits)
        got = ternary_matmul(x, wp, 1.0, mode="trit2", interpret=True,
                             bm=32, bn=32, bk=32)
        want = ref.ternary_matmul_ref(x, wp, 1.0, "trit2")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @given(st.integers(0, 2**31 - 1), st.sampled_from([16, 48, 96]),
           st.sampled_from([8, 24, 40]))
    @settings(max_examples=10, deadline=None)
    def test_property_base3(self, seed, k, n):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (5, k))
        vals = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -121, 122)
        wp = pack_base3(vals)
        got = ternary_matmul(x, wp, 1.0, interpret=True, bm=8, bn=8, bk=16)
        want = x @ vals.astype(jnp.float32)
        # blocked K accumulation reorders f32 sums vs the single matmul;
        # with |w| up to 121 the bound is ~1e-4 relative, not 1e-5.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)


class TestCimMacKernel:
    @pytest.mark.parametrize("m,k,n", [(8, 16, 8), (16, 64, 16), (32, 200, 24),
                                       (4, 37, 13)])
    @pytest.mark.parametrize("qi,qw", [(5, 5), (1, 1), (3, 2)])
    def test_vs_oracle(self, m, k, n, qi, qw):
        key = jax.random.PRNGKey(m + k + n + qi * 10 + qw)
        x = jax.random.randint(key, (qi, m, k), -1, 2, dtype=jnp.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (qw, k, n), -1, 2,
                               dtype=jnp.int8)
        got = cim_mac(x, w, adc_bits=5, bm=16, bn=16, bk=16, interpret=True)
        want = ref.cim_mac_ref(x, w, adc_bits=5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_adc_saturation_matches_oracle(self):
        # the all-(-1)-products corner that saturates the 5-bit ADC
        x = jnp.ones((1, 4, 16), dtype=jnp.int8)
        w = -jnp.ones((1, 16, 4), dtype=jnp.int8)
        got = cim_mac(x, w, adc_bits=5, bm=8, bn=8, bk=16, interpret=True)
        want = ref.cim_mac_ref(x, w, adc_bits=5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(got[0, 0]) == -15

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_exact_vs_int_matmul_with_wide_adc(self, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.randint(key, (2, 4, 48), -1, 2, dtype=jnp.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (2, 48, 8), -1, 2,
                               dtype=jnp.int8)
        got = cim_mac(x, w, adc_bits=8, bm=8, bn=8, bk=16, interpret=True)
        from repro.core.ternary import from_balanced_ternary
        want = (from_balanced_ternary(x).astype(jnp.int32)
                @ from_balanced_ternary(w).astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _operands(m=5, k=384, n=256, mode="base3"):
    key = jax.random.PRNGKey(m + k + n)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    return x, ops.pack_weights(w, mode)


class TestExecutionPlanAPI:
    def test_plan_is_frozen_hashable_and_cached(self):
        plan_cache_clear()
        p1 = plan_matmul((5, 384, 256), backend="pallas")
        p2 = plan_matmul((5, 384, 256), backend="pallas")
        assert p1 is p2                     # lru-cached resolution
        assert plan_cache_info().hits >= 1
        assert hash(p1) == hash(p2) and {p1: "ok"}[p2] == "ok"
        with pytest.raises(Exception):      # frozen dataclass
            p1.backend = "xla"

    def test_plan_resolves_auto_fields_once(self):
        p = plan_matmul((5, 384, 256))
        assert p.backend in backend_names() and p.backend != "auto"
        assert isinstance(p.interpret, bool)       # probe hoisted
        assert p.blocks is not None if p.backend == "pallas" else True

    def test_unknown_names_list_choices(self):
        with pytest.raises(ValueError,
                           match=r"registered: \['device', 'paged_attn', "
                                 r"'paged_attn_ref', 'pallas'"):
            plan_matmul((4, 64, 32), backend="cuda")
        with pytest.raises(ValueError, match=r"'float', 'int8'"):
            plan_matmul((4, 64, 32), domain="fp8")
        with pytest.raises(ValueError, match=r"'base3', 'trit2'"):
            plan_matmul((4, 64, 32), packing="dense")
        with pytest.raises(ValueError, match=r"'auto', 'decode', 'prefill'"):
            plan_matmul((4, 64, 32), phase="warmup")
        with pytest.raises(ValueError, match=r"'cim', 'ternary'"):
            plan_matmul((4, 64, 32), op="conv")

    def test_capability_mismatch_fails_loudly(self):
        # an int8 plan on a float-only backend must not fall through
        register_backend(BackendSpec(
            name="float_only", ops=frozenset({"ternary"}),
            domains=frozenset({"float"}),
            packings=frozenset({"base3", "trit2"}),
            platforms=frozenset({"cpu", "tpu"}), priority=1,
            runner=lambda plan, x, w: x))
        try:
            with pytest.raises(ValueError,
                               match=r"does not support domain 'int8'"):
                plan_matmul((4, 64, 32), backend="float_only",
                            domain="int8")
            # ... and auto-selection never picks it for int8
            p = plan_matmul((4, 64, 32), domain="int8")
            assert p.backend != "float_only"
        finally:
            unregister_backend("float_only")
        assert "float_only" not in backend_names()
        # xla cannot run the macro-exact cim op
        with pytest.raises(ValueError, match=r"does not support op 'cim'"):
            plan_matmul((4, 64, 32), op="cim", backend="xla")

    def test_execute_rejects_mismatched_operands(self):
        x, pw = _operands()
        plan = plan_matmul(shape_of(x, pw), backend="xla")
        with pytest.raises(ValueError, match="does not match plan"):
            execute(plan, x[:2], pw)        # plans are per-shape
        pw2 = ops.pack_weights(0.02 * jnp.ones((384, 256)), "trit2")
        with pytest.raises(ValueError, match="packing"):
            execute(plan, x, pw2)

    def test_ref_backend_matches_oracle(self):
        for mode in ("base3", "trit2"):
            x, pw = _operands(mode=mode)
            y = execute(plan_matmul(shape_of(x, pw), packing=mode,
                                    backend="ref"), x, pw)
            want = ref.ternary_matmul_ref(x, pw.data, pw.scale, mode)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(want))

    def test_plan_request_from_cfg_object(self):
        from repro.core.cim_linear import CIMConfig
        cfg = CIMConfig(mode="ternary", packing="trit2", domain="int8",
                        backend="xla")
        p = plan_matmul((8, 128, 64), cfg=cfg)
        assert (p.backend, p.domain, p.packing) == ("xla", "int8", "trit2")
        assert cfg.plan_request()["domain"] == "int8"
        r = cfg.resolve()
        assert r.backend == "xla" and isinstance(r.interpret, bool)


class TestDeprecationShims:
    @pytest.mark.parametrize("mode", ["base3", "trit2"])
    def test_ternary_matmul_kwargs_warn_and_match_plan(self, mode):
        x, pw = _operands(mode=mode)
        with pytest.warns(DeprecationWarning, match="plan_matmul"):
            y_old = ops.ternary_matmul(x, pw, backend="xla")
        y_new = execute(plan_matmul(shape_of(x, pw), packing=mode,
                                    backend="xla"), x, pw)
        np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    def test_int8_shim_warns_and_matches_plan(self):
        x, pw = _operands(mode="trit2")
        with pytest.warns(DeprecationWarning, match="plan_matmul"):
            y_old = ops.ternary_matmul_int8(x, pw, interpret=True)
        y_new = execute(plan_matmul(shape_of(x, pw), packing="trit2",
                                    domain="int8", backend="pallas",
                                    interpret=True), x, pw)
        np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    def test_cim_shim_warns_and_matches_plan(self):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (6, 64))
        w = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (64, 24))
        with pytest.warns(DeprecationWarning, match="plan_matmul"):
            y_old = ops.cim_matmul(x, w, interpret=True, bm=8, bn=8, bk=16)
        plan = plan_matmul(shape_of(x, w), op="cim", interpret=True,
                           bm=8, bn=8, bk=16)
        np.testing.assert_array_equal(np.asarray(y_old),
                                      np.asarray(execute(plan, x, w)))

    def test_plain_calls_do_not_warn(self):
        x, pw = _operands()
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ops.ternary_matmul(x, pw)       # no routing kwargs: silent


class TestOpsWrappers:
    def test_pack_weights_base3_matmul(self):
        key = jax.random.PRNGKey(0)
        w = 0.02 * jax.random.normal(key, (96, 48))
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 10, 96))
        pw = ops.pack_weights(w, "base3")
        assert pw.data.dtype == jnp.uint8 and pw.data.shape == (96, 48)
        y = execute(plan_matmul(shape_of(x, pw), backend="pallas",
                                interpret=True, bm=16, bn=16, bk=32),
                    x, pw)
        rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.02, rel

    def test_pack_weights_trit2_density(self):
        w = 0.02 * jax.random.normal(jax.random.PRNGKey(1), (128, 64))
        pw = ops.pack_weights(w, "trit2")
        assert pw.data.shape == (32, 64)        # 4 trits/byte: 8x vs bf16
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 128))
        y = execute(plan_matmul(shape_of(x, pw), packing="trit2",
                                backend="pallas", interpret=True,
                                bm=8, bn=16, bk=32), x, pw)
        # single-trit quantization is lossy; just require usable correlation
        ref_y = x @ w
        cos = float(jnp.sum(y * ref_y) /
                    (jnp.linalg.norm(y) * jnp.linalg.norm(ref_y)))
        assert cos > 0.85, cos

    def test_ops_cim_matmul_matches_core(self):
        from repro.core import cim as cim_core
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (6, 64))
        w = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (64, 24))
        got = execute(plan_matmul(shape_of(x, w), op="cim", interpret=True,
                                  bm=8, bn=8, bk=16), x, w)
        # core path quantizes per-tensor; ops path per-tensor too for plain w
        want = cim_core.cim_matmul(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)


class TestPlanCacheAndShimFrames:
    def test_plan_cache_is_bounded(self):
        """ISSUE 5 satellite: plan resolution must not grow without
        bound under varied-shape traffic (paged serving widens the
        key set)."""
        from repro.kernels.plan import PLAN_CACHE_SIZE
        plan_cache_clear()
        info = plan_cache_info()
        assert info.maxsize == PLAN_CACHE_SIZE
        # overfill with distinct shapes: currsize stays bounded and
        # resolution keeps working (eviction, not failure)
        for m in range(PLAN_CACHE_SIZE + 64):
            plan_matmul((m + 1, 32, 16), backend="xla")
        info = plan_cache_info()
        assert info.currsize <= PLAN_CACHE_SIZE
        assert info.misses >= PLAN_CACHE_SIZE + 64
        plan_cache_clear()
        assert plan_cache_info().currsize == 0

    def test_shim_warning_points_at_caller(self):
        """ISSUE 5 satellite: every deprecation shim must attribute its
        warning to the USER's call site (this file), not ops.py or the
        _warn_legacy helper."""
        import repro.kernels.ops as ops_mod
        x, pw = _operands()
        xf = jax.random.normal(jax.random.PRNGKey(9), (6, 64))
        wf = 0.05 * jax.random.normal(jax.random.PRNGKey(10), (64, 24))
        shims = [
            lambda: ops.ternary_matmul(x, pw, backend="xla"),
            lambda: ops.ternary_matmul_int8(x, pw, backend="xla"),
            lambda: ops.cim_matmul(xf, wf, interpret=True,
                                   bm=8, bn=8, bk=16),
        ]
        for shim in shims:
            with pytest.warns(DeprecationWarning) as rec:
                shim()
            dep = [w for w in rec
                   if w.category is DeprecationWarning
                   and "plan_matmul" in str(w.message)]
            assert dep, "shim did not warn"
            assert dep[0].filename == __file__, (
                f"warning attributed to {dep[0].filename}, "
                f"not the caller ({__file__})")
            assert dep[0].filename != ops_mod.__file__
