"""Optimizers, state_defs consistency, gradient compression."""
import jax
import jax.numpy as jnp

from repro.models.config import ParamDef, init_params, is_def
from repro.optim import (adamw, adafactor, sgd, warmup_cosine,
                         compress_int8, decompress_int8, ef_init,
                         ef_compress_grads)


PDEFS = {"w": ParamDef((32, 16), ("embed", "mlp")),
         "b": ParamDef((16,), ("none",), "zeros"),
         "stack": ParamDef((4, 8, 8), ("layers", "embed", "mlp"))}


def _quadratic_steps(opt, steps=60):
    params = init_params(jax.random.key(0), PDEFS, jnp.float32)
    target = jax.tree.map(lambda p: jnp.ones_like(p) * 0.5, params)
    state = opt.init(params)

    def loss_fn(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss_fn(params))
    for i in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state, m = opt.update(g, state, params, i)
    return l0, float(loss_fn(params))


def test_adamw_descends():
    l0, l1 = _quadratic_steps(adamw(5e-2))
    assert l1 < 0.1 * l0


def test_adafactor_descends():
    l0, l1 = _quadratic_steps(adafactor(5e-1))
    assert l1 < 0.2 * l0


def test_sgd_descends():
    l0, l1 = _quadratic_steps(sgd(5e-2, momentum=0.9))
    assert l1 < 0.1 * l0


def _assert_defs_match_state(defs_tree, state):
    flat_d = jax.tree.leaves(defs_tree, is_leaf=is_def)
    flat_s = jax.tree.leaves(state)
    assert len(flat_d) == len(flat_s)
    for d, s in zip(flat_d, flat_s):
        assert tuple(d.shape) == tuple(s.shape), (d, s.shape)
        assert len(d.axes) == len(d.shape)


def test_adamw_state_defs_match_init():
    opt = adamw(1e-3)
    params = init_params(jax.random.key(0), PDEFS, jnp.float32)
    _assert_defs_match_state(opt.state_defs(PDEFS), opt.init(params))


def test_adafactor_state_defs_match_init():
    opt = adafactor(1e-3)
    params = init_params(jax.random.key(0), PDEFS, jnp.float32)
    _assert_defs_match_state(opt.state_defs(PDEFS), opt.init(params))
    # factored: the (32,16) matrix must NOT have a full second moment
    st = opt.init(params)
    assert set(st["w"].keys()) == {"r", "c"}
    assert st["w"]["r"].shape == (32,)
    assert st["w"]["c"].shape == (16,)
    assert st["stack"]["r"].shape == (4, 8)
    assert st["b"]["v"].shape == (16,)


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(99)) < 0.2


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.key(1), (128, 64))
    q, s = compress_int8(g)
    err = jnp.abs(decompress_int8(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """Sum of EF-compressed grads converges to sum of true grads."""
    key = jax.random.key(2)
    grads = {"w": jax.random.normal(key, (64, 32))}
    resid = ef_init(grads)
    total_true = jnp.zeros((64, 32))
    total_comp = jnp.zeros((64, 32))
    for i in range(30):
        g = {"w": grads["w"] * (1 + 0.1 * i)}
        comp, resid = ef_compress_grads(g, resid)
        total_true += g["w"]
        total_comp += comp["w"]
    # residual is bounded; accumulated difference == final residual
    diff = jnp.abs(total_true - total_comp)
    assert float(diff.max()) <= float(jnp.abs(resid["w"]).max()) + 1e-5
