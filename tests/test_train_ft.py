"""Fault tolerance: crash/restart replay equivalence, atomic-save crashes,
straggler detection, loss actually decreasing on the synthetic chain task.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import DataConfig
from repro.models import registry
from repro.optim import adamw
from repro.train import FailurePlan, Trainer, TrainerConfig


def _mk(tmp_path, total=12, interval=4, plan=None, step_time_fn=None,
        seed=0):
    cfg = configs.smoke("internlm2-1.8b")
    model = registry.build(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                      seed=seed)
    tc = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path),
                       ckpt_interval=interval, ckpt_keep=3, seed=seed)
    return Trainer(model, adamw(1e-3), data, tc, failure_plan=plan,
                   step_time_fn=step_time_fn)


def _params_equal(a, b):
    return all(jnp.array_equal(x, y) for x, y in
               zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))


def test_loss_decreases(tmp_path):
    tr = _mk(tmp_path / "a", total=15)
    tr.run()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]


def test_crash_restart_is_bitwise_identical(tmp_path):
    ref = _mk(tmp_path / "ref", total=12).run()

    plan = FailurePlan(crash_at=(6,))
    tr = _mk(tmp_path / "crash", total=12, plan=plan)
    got = tr.run()
    assert tr.restarts == 1
    assert int(got.step) == 12
    assert _params_equal(ref, got)


def test_crash_during_save_recovers(tmp_path):
    ref = _mk(tmp_path / "ref", total=12).run()

    plan = FailurePlan(crash_in_save=(8,))
    tr = _mk(tmp_path / "crash", total=12, plan=plan)
    got = tr.run()
    assert tr.restarts == 1
    assert _params_equal(ref, got)


def test_double_failure(tmp_path):
    ref = _mk(tmp_path / "ref", total=16).run()
    plan = FailurePlan(crash_at=(5, 11), crash_in_save=(12,))
    tr = _mk(tmp_path / "crash", total=16, plan=plan)
    got = tr.run()
    assert tr.restarts == 3
    assert _params_equal(ref, got)


def test_straggler_detection(tmp_path):
    # steps 8/9/10 are 10x slower than the 0.01s median
    times = {8: 0.1, 9: 0.12, 10: 0.11}
    tr = _mk(tmp_path, total=14,
             step_time_fn=lambda s: times.get(s, 0.01))
    tr.run()
    assert tr.straggler_events >= 3
    assert tr.mitigations >= 1
    flagged = [h["step"] for h in tr.history if h["straggler"]]
    assert 8 in flagged and 9 in flagged
