"""Unit + property tests for the balanced-ternary codec and quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # offline image: shim
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from repro.core import packing, ternary

jax.config.update("jax_platform_name", "cpu")


class TestCodec:
    def test_trit_range(self):
        assert ternary.trit_range(5) == 121
        assert ternary.trit_range(1) == 1
        assert ternary.trit_range(3) == 13

    def test_roundtrip_exhaustive_5t(self):
        vals = jnp.arange(-121, 122)
        trits = ternary.to_balanced_ternary(vals, 5)
        assert trits.shape == (5, 243)
        assert set(np.unique(np.asarray(trits))) <= {-1, 0, 1}
        back = ternary.from_balanced_ternary(trits)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))

    def test_clipping(self):
        vals = jnp.array([127, -128, 500])
        back = ternary.from_balanced_ternary(ternary.to_balanced_ternary(vals, 5))
        np.testing.assert_array_equal(np.asarray(back), [121, -121, 121])

    @given(st.lists(st.integers(-121, 121), min_size=1, max_size=64),
           st.integers(1, 5))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, vals, q):
        lim = ternary.trit_range(q)
        arr = jnp.array(vals)
        back = ternary.from_balanced_ternary(ternary.to_balanced_ternary(arr, q))
        np.testing.assert_array_equal(np.asarray(back),
                                      np.clip(vals, -lim, lim))


class TestSignals:
    def test_table1_weights(self):
        trits = jnp.array([1, 0, -1])
        q1, q2 = ternary.weight_signals(trits)
        np.testing.assert_array_equal(np.asarray(q1), [0, 1, 1])
        np.testing.assert_array_equal(np.asarray(q2), [0, 0, 1])
        back = ternary.signals_to_weight_trit(q1, q2)
        np.testing.assert_array_equal(np.asarray(back), [1, 0, -1])

    def test_table1_inputs(self):
        trits = jnp.array([1, 0, -1])
        in1, in2 = ternary.input_signals(trits)
        np.testing.assert_array_equal(np.asarray(in1), [1, 1, 0])
        np.testing.assert_array_equal(np.asarray(in2), [1, 0, 0])


class TestQuantization:
    def test_truncate_matches_8b_for_small_weights(self):
        # NN-like weights (small) -> truncation changes nothing vs 8b
        key = jax.random.PRNGKey(0)
        w = 0.02 * jax.random.normal(key, (256, 64))
        q8 = ternary.quantize_8b(w)
        qt = ternary.quantize_8b_truncate_5t(w)
        frac_clipped = np.mean(np.asarray(q8.values != qt.values))
        assert frac_clipped < 0.02  # only the rare |q|>121 tail clips

    def test_dequant_error_bounded(self):
        key = jax.random.PRNGKey(1)
        w = jax.random.normal(key, (128, 128))
        tt = ternary.ternarize(w, 5)
        err = jnp.abs(tt.dequantize() - w).max()
        # worst case: |q8|=127 clipped to 121 plus rounding -> 6.5 * scale
        assert float(err) <= float(tt.scale) * 6.5 + 1e-6

    def test_ternarize_planes_valid(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (32, 32))
        tt = ternary.ternarize(w, 5)
        assert tt.trits.shape == (5, 32, 32)
        assert set(np.unique(np.asarray(tt.trits))) <= {-1, 0, 1}


class TestPacking:
    @given(st.integers(0, 10_000), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_trit2_roundtrip(self, seed, qdummy):
        key = jax.random.PRNGKey(seed)
        trits = jax.random.randint(key, (16, 8), -1, 2, dtype=jnp.int8)
        packed = packing.pack_trits2(trits)
        assert packed.shape == (4, 8) and packed.dtype == jnp.uint8
        back = packing.unpack_trits2(packed)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(trits))

    def test_base3_roundtrip(self):
        vals = jnp.arange(-121, 122)
        packed = packing.pack_base3(vals)
        assert packed.dtype == jnp.uint8
        back = packing.unpack_base3(packed)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))

    def test_base3_is_one_byte_per_weight(self):
        assert packing.packed_bytes((128, 256), "base3") == 128 * 256
        assert packing.packed_bytes((128, 256), "bf16") == 2 * 128 * 256
        assert packing.packed_bytes((128, 256), "trit2", num_trits=1) == 128 * 256 // 4

    def test_planes_base3_consistency(self):
        vals = jnp.arange(-121, 122)
        trits = ternary.to_balanced_ternary(vals, 5)
        packed = packing.pack_trit_planes_base3(trits)
        planes = packing.unpack_base3_to_planes(packed, 5)
        np.testing.assert_array_equal(np.asarray(planes), np.asarray(trits))
