"""Device-fidelity serving: fault models, the ``device`` backend, the
fidelity plan axis, and the restore-scrub repair channel.

Covers the PR's contracts:

  * ``confusion_from_yields`` rows sum to 1 (yields validated/clamped);
  * empirical injection rate matches ``expected_trit_error_rate``;
  * fault injection is bitwise-deterministic per campaign key;
  * the ``device`` backend occupies exactly the device-fidelity cells
    of the capability lattice, and every unsupported fidelity request
    fails loudly (never a silent fall-through);
  * noise-aware routing: ``device`` requests resolve exact for prefill;
  * exact-fidelity serving is untouched by the fault machinery (inert
    hooks, unchanged transfer contract, bitwise-identical tokens);
  * the scrub gate: drift degrades the served weights measurably and
    the restore-scrub REPAIRS them (bounded by 1 - yield, not a no-op).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.cim_linear import CIMConfig, ternarize_params
from repro.core.error_injection import (confusion_from_yields,
                                        expected_trit_error_rate,
                                        inject_trit_errors)
from repro.models import registry
from repro.serve import Request, Scheduler
from repro import faults
from repro import kernels
from repro.kernels import (execute, get_backend, plan_matmul,
                           resolve_backend, route_fidelity, shape_of)

YIELDS = (0.97, 0.995, 0.96)


# ------------------------------------------------- confusion channel

def test_confusion_rows_sum_to_one():
    c = confusion_from_yields(jnp.asarray(YIELDS))
    assert c.shape == (3, 3)
    assert jnp.allclose(c.sum(axis=-1), 1.0, atol=1e-6)
    # diagonal is the per-state yield
    assert jnp.allclose(jnp.diagonal(c), jnp.asarray(YIELDS), atol=1e-6)


def test_confusion_validates_and_clamps():
    # Monte-Carlo yields at small sample counts can exceed 1 by eps;
    # clamped instead of producing negative error probabilities
    c = confusion_from_yields(jnp.asarray([1.0 + 1e-6, 0.5, -0.25]))
    assert jnp.allclose(c.sum(axis=-1), 1.0, atol=1e-6)
    assert float(c[0, 1]) == pytest.approx(0.0, abs=1e-6)
    assert float(c[2, 2]) == pytest.approx(0.0, abs=1e-6)
    with pytest.raises(ValueError, match="shape"):
        confusion_from_yields(jnp.asarray([0.9, 0.9]))
    with pytest.raises(ValueError, match="finite"):
        confusion_from_yields(jnp.asarray([0.9, float("nan"), 0.9]))


def test_empirical_injection_rate_matches_expected():
    prior = (0.25, 0.5, 0.25)
    key = jax.random.key(0)
    trits = (jax.random.choice(key, jnp.asarray([-1, 0, 1], jnp.int8),
                               (400_000,), p=jnp.asarray(prior)))
    out = inject_trit_errors(trits, jnp.asarray(YIELDS),
                             jax.random.key(1))
    got = float(jnp.mean(out != trits))
    want = expected_trit_error_rate(YIELDS, prior)
    assert got == pytest.approx(want, rel=0.08)


def test_injection_bitwise_deterministic_per_key():
    trits = jax.random.randint(jax.random.key(2), (64, 128), -1, 2,
                               dtype=jnp.int32).astype(jnp.int8)
    y = jnp.asarray(YIELDS)
    a = inject_trit_errors(trits, y, jax.random.key(7))
    b = inject_trit_errors(trits, y, jax.random.key(7))
    c = inject_trit_errors(trits, y, jax.random.key(8))
    assert jnp.array_equal(a, b)
    assert not jnp.array_equal(a, c)


def test_fault_model_channels_deterministic():
    fm = faults.FaultModel(seed=3, restore_yield=YIELDS, stuck_rate=0.01)
    fm2 = faults.FaultModel(seed=3, restore_yield=YIELDS, stuck_rate=0.01)
    trits = jax.random.randint(jax.random.key(4), (5, 64, 32), -1, 2,
                               dtype=jnp.int32).astype(jnp.int8)
    assert jnp.array_equal(fm.fault_trits(trits, "w"),
                           fm2.fault_trits(trits, "w"))
    assert jnp.array_equal(fm.conductance_multiplier(trits, "g"),
                           fm2.conductance_multiplier(trits, "g"))
    # a different campaign seed is a different device instance
    fm3 = dataclasses.replace(fm, seed=4)
    assert not jnp.array_equal(fm.fault_trits(trits, "w"),
                               fm3.fault_trits(trits, "w"))


# ------------------------------------------- fidelity capability axis

def test_device_backend_capability_cells():
    assert "device" in kernels.backend_names()
    spec = get_backend("device")
    assert spec.fidelities == frozenset({"device"})
    assert spec.ops == frozenset({"ternary"})
    # auto under a device request resolves the device backend...
    assert resolve_backend("ternary", "auto",
                           fidelity="device").name == "device"
    # ...and never shadows an exact request, whatever its priority
    assert resolve_backend("ternary", "auto",
                           fidelity="exact").name != "device"


def test_unsupported_fidelity_fails_loudly():
    with pytest.raises(ValueError, match="does not support fidelity"):
        resolve_backend("ternary", "pallas", fidelity="device")
    with pytest.raises(ValueError, match="does not support"):
        resolve_backend("cim", "device", fidelity="device")
    with pytest.raises(ValueError, match="no registered backend"):
        resolve_backend("cim", "auto", fidelity="device")
    with pytest.raises(ValueError, match="unknown fidelity"):
        plan_matmul((4, 64, 32), fidelity="analog")
    # float mode has no packed weights for the device model to fault
    with pytest.raises(ValueError, match="device"):
        CIMConfig(mode="float", fidelity="device").resolve()


def test_route_fidelity_prefill_exact():
    assert route_fidelity("device", "prefill") == "exact"
    assert route_fidelity("device", "decode") == "device"
    assert route_fidelity("device", "auto") == "device"
    assert route_fidelity("exact", "prefill") == "exact"
    plan = plan_matmul((4, 64, 32), "prefill", fidelity="device")
    assert plan.fidelity == "exact" and plan.backend != "device"
    plan = plan_matmul((4, 64, 32), "decode", fidelity="device")
    assert plan.fidelity == "device" and plan.backend == "device"
    assert plan.adc_bits == 5 and plan.num_trits == 5


@pytest.mark.parametrize("packing", ["base3", "trit2"])
def test_device_execute_deterministic_and_correlated(packing):
    kx, kw = jax.random.split(jax.random.key(5))
    x = jax.random.normal(kx, (8, 64))
    w = jax.random.normal(kw, (64, 48))
    pw = kernels.ops.pack_weights(w, packing)
    exact = execute(plan_matmul(shape_of(x, pw), packing=packing), x, pw)

    prev = faults.set_fault_model(faults.FaultModel(
        seed=0, restore_yield=YIELDS))
    try:
        plan = plan_matmul(shape_of(x, pw), packing=packing,
                           fidelity="device")
        y1 = execute(plan, x, pw)
        y2 = execute(plan, x, pw)
    finally:
        faults.set_fault_model(prev)
    assert jnp.array_equal(y1, y2)          # bitwise per campaign
    assert bool(jnp.all(jnp.isfinite(y1)))
    corr = jnp.corrcoef(y1.ravel(), exact.ravel())[0, 1]
    assert float(corr) > 0.8                # analog, but the same MAC


# ------------------------------------------------- scrub/drift repair

def _packed_tree(packing="base3"):
    w1 = jax.random.normal(jax.random.key(6), (64, 96))
    w2 = jax.random.normal(jax.random.key(7), (96, 64))
    cfg = CIMConfig(mode="ternary", packing=packing)
    return ternarize_params({"w1": w1, "w2": w2}, cfg)


def test_drift_compounds_and_scrub_repairs():
    pristine = _packed_tree()
    key = jax.random.key(9)
    served = pristine
    rates = []
    for chunk in range(6):
        served = faults.disturb_packed_params(
            served, 0.01, jax.random.fold_in(key, chunk))
        rates.append(faults.packed_trit_error_rate(served, pristine))
    # degradation is measurable and compounds monotonically
    assert rates[0] > 0.0
    assert rates[-1] > 2.5 * rates[0]
    # scrub repairs to the restore bound, independent of drift history
    scrubbed = faults.scrub_packed_params(pristine, YIELDS,
                                          jax.random.key(10))
    post = faults.packed_trit_error_rate(scrubbed, pristine)
    assert post < rates[-1]
    bound = expected_trit_error_rate(YIELDS, (1 / 3, 1 / 3, 1 / 3))
    assert post <= 2.0 * bound
    # the scrub is a real restore, not a no-op copy: at yield < 1 the
    # repaired tree is NOT bitwise pristine
    assert post > 0.0
    # ideal restore (yield=None) IS the pristine tree
    ideal = faults.scrub_packed_params(pristine, None, jax.random.key(10))
    assert faults.packed_trit_error_rate(ideal, pristine) == 0.0


@pytest.mark.parametrize("packing", ["base3", "trit2"])
def test_packed_trit_roundtrip(packing):
    tree = _packed_tree(packing)
    leaf = tree["w1"]
    trits = faults.packed_to_trits(leaf)
    back = faults.trits_to_packed(trits, leaf)
    assert jnp.array_equal(back.data, leaf.data)
    assert back.mode == leaf.mode


# ------------------------------------------------- serving integration

def _requests(cfg, count=3, max_new=6):
    key = jax.random.key(11)
    return [Request(uid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (8,), 0, cfg.vocab_size),
                    max_new=max_new)
            for i in range(count)]


def _smoke(arch="internlm2-1.8b"):
    cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_exact_serving_parity_and_inert_hooks():
    cfg, model, params = _smoke()
    cim = CIMConfig(mode="ternary", packing="base3")
    tern = ternarize_params(params, cim)
    # exact configs resolve identically for both phases: same frozen
    # config -> same jit cache entry -> bitwise-identical serving
    assert cim.resolve() == cim.resolve(phase="prefill")

    runs = []
    for _ in range(2):
        s = Scheduler(model, tern, capacity=64, slots=2, chunk=4, cim=cim)
        for r in _requests(cfg):
            s.submit(r)
        done = {r.uid: r.out_tokens for r in s.run()}
        # fault machinery is inert under exact fidelity
        assert s._fault_serving is False
        assert s._round_extras() == ()
        assert s.adc_clip_lo == 0 and s.adc_clip_hi == 0
        assert s.scrubs_run == 0
        # unchanged transfer contract: one device->host sync per chunk
        assert s.host_transfers == s.chunks_run
        runs.append(done)
    assert runs[0] == runs[1]


@pytest.mark.slow
def test_device_serving_scrub_and_transfer_contract():
    cfg, model, params = _smoke()
    cim = CIMConfig(mode="ternary", packing="base3")
    tern = ternarize_params(params, cim)
    prev = faults.set_fault_model(faults.FaultModel(
        seed=0, restore_yield=YIELDS, drift_rate=0.002))
    try:
        cimd = dataclasses.replace(cim, fidelity="device")
        s = Scheduler(model, tern, capacity=64, slots=2, chunk=2,
                      cim=cimd, scrub_every=2)
        assert s.cim.backend == "device" and s.cim.fidelity == "device"
        assert s.cim_prefill.fidelity == "exact"
        assert s.cim_prefill.backend != "device"
        for r in _requests(cfg, count=2, max_new=4):
            s.submit(r)
        done = s.run()
        assert all(len(r.out_tokens) == 4 for r in done)
        # the ADC probe scalars ride the existing per-chunk transfer
        assert s.host_transfers == s.chunks_run
        assert s.scrubs_run >= 1
        # served weights sit at the restore bound, not bitwise pristine
        err = faults.packed_trit_error_rate(s.params, s._params_pristine)
        bound = expected_trit_error_rate(YIELDS, (1 / 3, 1 / 3, 1 / 3))
        assert 0.0 < err <= 3.0 * bound
    finally:
        faults.set_fault_model(prev)


@pytest.mark.slow
def test_dryrun_device_fidelity_cell(tmp_path):
    """The launcher smoke cell: a device-fidelity decode cell lowers and
    compiles against the production mesh (subprocess — dryrun pins 512
    fake devices before jax initializes and must never be imported)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)        # dryrun sets its own device count
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "internlm2-1.8b", "--shape", "decode_32k",
         "--packed", "base3", "--fidelity", "device",
         "--continuous", "8", "--tag", "fidelity-smoke",
         "--out-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out_files = [f for f in os.listdir(tmp_path)
                 if f.endswith("fidelity-smoke.json")]
    assert len(out_files) == 1
    with open(tmp_path / out_files[0]) as f:
        cell = json.load(f)
    assert cell["cim_backend"] == "device"
    assert cell["cim_fidelity"] == "device"
    assert cell["compile_s"] > 0
