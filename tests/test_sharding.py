"""Sharding rules: divisibility, no axis reuse, quantum units, spec trees."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from conftest import FakeMesh
from repro.dist import mesh as mesh_lib
from repro.dist import sharding as shd

MESH = FakeMesh((16, 16), ("data", "model"))
POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def spec(axes, shape, rules=None, mesh=MESH):
    return shd.logical_to_spec(axes, shape, rules or shd.train_rules(), mesh)


def test_basic_tp_sharding():
    assert spec(("embed", "mlp"), (4096, 14336)) == P("data", "model")


def test_divisibility_blocks_sharding():
    # 100 not divisible by 16 -> replicated
    assert spec(("embed", "mlp"), (100, 14336)) == P(None, "model")


def test_no_axis_reuse():
    # embed takes 'data'; a second dim asking for data gets None
    r = shd.train_rules().with_overrides(mlp=("data",))
    assert spec(("embed", "mlp"), (4096, 4096), r) == P("data")


def test_quantum_prevents_head_splitting():
    # kv dim = 2 heads x 128 = 256: divisible by 16 raw, but only 2 units
    r = shd.train_rules(quantum={"kv": 128})
    assert spec(("embed_rp", "kv"), (4096, 256), r) == P("model")
    # 16 heads x 128 -> shardable
    r2 = shd.train_rules(quantum={"heads": 128})
    assert spec(("embed", "heads"), (4096, 2048), r2) == P("data", "model")


def test_batch_uses_pod_and_data():
    s = spec(("batch", "seq"), (256, 4096), mesh=POD)
    assert s == P(("pod", "data"))


def test_batch_of_one_replicates():
    assert spec(("batch", "seq"), (1, 524288), mesh=POD) == P()


def test_serve_rules_shard_cache_seq():
    r = shd.serve_rules()
    s = shd.logical_to_spec(("layers", "batch", "cache_seq", "kv", "none"),
                            (40, 128, 32768, 8, 128), r, MESH)
    assert s == P(None, "data", "model")


def test_slot_pool_folds_over_dp_axes():
    """Continuous-batching slot pool: the leading 'slot' axis shards
    like 'batch' (over DP), the per-slot inner batch of 1 replicates,
    and cache_seq keeps its serve-mode TP sharding."""
    r = shd.serve_rules()
    s = shd.logical_to_spec(
        ("slot", "layers", "batch", "cache_seq", "kv", "none"),
        (32, 40, 1, 32768, 8, 128), r, POD)
    assert s == P(("pod", "data"), None, None, "model")


def test_slot_spmd_axes_resolution():
    # 32 slots on the pod mesh: folds over both DP axes
    assert shd.slot_spmd_axes(shd.serve_rules(), POD, 32) == \
        ("pod", "data")
    # 16 slots: 2x16 does not divide -> trailing-drop to pod only? no:
    # folding drops TRAILING axes, so ('pod','data') -> ('pod',) when
    # 16 % (2*16) != 0 but 16 % 2 == 0
    assert shd.slot_spmd_axes(shd.serve_rules(), POD, 16) == "pod"
    assert shd.slot_spmd_axes(shd.serve_rules(), MESH, 32) == "data"
    # indivisible pool replicates (None) rather than failing under vmap
    assert shd.slot_spmd_axes(shd.serve_rules(), MESH, 3) is None
    # replicated-slot override
    r = shd.serve_rules().with_overrides(slot=None)
    assert shd.slot_spmd_axes(r, MESH, 32) is None


def test_fsdp_off_replicates_embed():
    r = shd.train_rules(fsdp=False)
    assert spec(("embed", "mlp"), (4096, 14336), r) == P(None, "model")


def test_spec_tree_on_model_defs():
    from repro import configs
    from repro.models import registry
    model = registry.build(configs.get("internlm2-1.8b"))     # 16 heads
    tree = shd.spec_tree(model.param_defs, shd.rules_for(
        configs.get("internlm2-1.8b"), "train"), MESH)
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(l, P) for l in leaves)
    # attention q-proj must be TP-sharded (16 heads / 16-way model axis)
    blocks = tree["blocks"]
    assert "model" in jax.tree.leaves(
        blocks["wq"], is_leaf=lambda x: isinstance(x, P))[0]


def test_qwen3_heads_not_divisible_stay_whole():
    """40 heads on a 16-way TP axis: quantum forbids mid-head splits, so
    the q projection replicates (recorded honestly in the roofline)."""
    from repro import configs
    cfg = configs.get("qwen3-14b")
    r = shd.rules_for(cfg, "train")
    s = shd.logical_to_spec(("layers", "embed", "heads"),
                            (40, 5120, 40 * 128), r, MESH)
    assert s == P(None, "data")


def test_mesh_spec_helpers():
    assert mesh_lib.SINGLE_POD.num_devices == 256
    assert mesh_lib.MULTI_POD.num_devices == 512
    assert mesh_lib.MULTI_POD.dp_axes == ("pod", "data")
    s = mesh_lib.spec_for(8)
    assert s.num_devices == 8
    s = mesh_lib.spec_for(8, multi_pod=True)
    assert s.num_devices == 8 and "pod" in s.axes
