"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, output shapes + no NaNs; prefill/decode
consistency against the training-mode forward pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import DataConfig, batch_for
from repro.models import registry
from repro.optim import adamw

ARCHS = list(configs.ARCHS)


def _smoke(arch, dtype=None):
    cfg = configs.smoke(arch)
    if dtype is not None:
        cfg = dataclasses.replace(cfg, dtype=dtype)
    return cfg


def _batch(cfg, b=2, s=32, seed=0):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=s, global_batch=b,
                    seed=seed)
    return batch_for(cfg, dc, jnp.asarray(0))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = _smoke(arch)
    model = registry.build(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, seed=1)
    opt = adamw(1e-3)
    ost = opt.init(params)

    @jax.jit
    def step(params, ost, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(
            params)
        new_p, new_o, m = opt.update(grads, ost, params, 0)
        return loss, new_p, m["grad_norm"]

    loss, new_p, gnorm = step(params, ost, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    assert bool(jnp.isfinite(gnorm))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x.astype(jnp.float32)))),
        jax.tree.map(lambda a, b: a.astype(jnp.float32) -
                     b.astype(jnp.float32), params, new_p), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode(t_k | prefill(t_0..k-1)) logits == forward logits column k-1/k.

    MoE capacity is made non-binding: capacity-overflow drops depend on
    the total token count, so a 12-token prefill and a 16-token forward
    legitimately drop different tokens at cf=1.25."""
    cfg = _smoke(arch, dtype=jnp.float32)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=64.0)
    model = registry.build(cfg)
    params = model.init(jax.random.key(2))
    b, s, k = 2, 16, 12
    batch = _batch(cfg, b=b, s=s, seed=2)
    full_logits = model.forward(params, batch).astype(jnp.float32)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :k]
    pre_batch.pop("labels", None)
    logits_k, state = model.prefill(params, pre_batch, capacity=s)
    got = logits_k[:, -1].astype(jnp.float32)
    want = full_logits[:, k - 1]
    assert jnp.allclose(got, want, atol=2e-3, rtol=2e-3), (
        float(jnp.max(jnp.abs(got - want))))

    tok = batch["tokens"][:, k][:, None]
    logits_d, state = model.decode(params, tok, state)
    got = logits_d[:, -1].astype(jnp.float32)
    want = full_logits[:, k]
    assert jnp.allclose(got, want, atol=2e-3, rtol=2e-3), (
        float(jnp.max(jnp.abs(got - want))))


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x7b",
                                  "xlstm-125m", "zamba2-7b"])
def test_multi_step_decode_no_nan(arch):
    cfg = _smoke(arch, dtype=jnp.float32)
    model = registry.build(cfg)
    params = model.init(jax.random.key(3))
    batch = _batch(cfg, b=1, s=8, seed=3)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    logits, state = model.prefill(params, pre, capacity=32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(5):
        logits, state = model.decode(params, tok, state)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    table = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
    }
    for arch, (L, d, h, kv, ff, v) in table.items():
        cfg = configs.get(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    assert configs.get("kimi-k2-1t-a32b").num_experts == 384
    assert configs.get("kimi-k2-1t-a32b").experts_per_token == 8
    assert configs.get("mixtral-8x7b").num_experts == 8
    assert configs.get("zamba2-7b").ssm_state == 64


def test_moe_param_count_kimi_is_about_1t():
    cfg = configs.get("kimi-k2-1t-a32b")
    n = cfg.param_count()
    assert 0.9e12 < n < 1.4e12, n
    na = cfg.active_param_count()
    assert 2e10 < na < 6e10, na
