"""Decode-path fast lane: shape-adaptive block dispatch, the int8
MXU domain, the on-device serve loop, and the wall-clock bench metrics.

Contracts pinned here (ISSUE 2 acceptance):
  * decode shapes (M = 1/4/8) agree pallas == xla == oracle in both
    packing modes, float and int8 domains (int8 bitwise);
  * adaptive blocking cuts padded-M FLOP waste >= 8x vs fixed bm=128
    for batch <= 16 decode shapes;
  * the on-device decode loop emits tokens identical to the legacy
    per-step driver and performs exactly ONE host transfer per bucket.

Plus the ExecutionPlan migration contract (ISSUE 4 acceptance): every
(backend, domain, packing) dispatch cell is bitwise identical between
the deprecated kwarg routing and plan_matmul/execute, and plan
resolution under jit is cache-hit free of re-probing.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (execute, ops, plan_cache_clear, plan_cache_info,
                           plan_matmul, ref, shape_of)
from repro.kernels.ternary_matmul import (DEFAULT_BLOCKS, SUBLANE,
                                          select_block_shapes,
                                          ternary_matmul_int8)

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------- block selection

class TestSelectBlockShapes:
    def test_prefill_keeps_mxu_tiles(self):
        assert select_block_shapes(512, 2048, 2048) == DEFAULT_BLOCKS
        assert select_block_shapes(128, 4096, 1024) == DEFAULT_BLOCKS

    @pytest.mark.parametrize("m", [1, 4, 8, 16, 64])
    def test_decode_shrinks_bm_to_sublane_multiple(self, m):
        bm, bn, bk = select_block_shapes(m, 2048, 2048)
        assert bm == -(-m // SUBLANE) * SUBLANE
        assert bm < 128 and bk >= 512     # deeper K tile for skinny M
        assert bn % 128 == 0 and bk % 128 == 0

    def test_bk_clamped_to_k_extent(self):
        _, _, bk = select_block_shapes(4, 256, 512)
        assert bk == 256                  # round_up(256, 128), not 1024

    def test_trit2_packed_tile_stays_whole(self):
        _, _, bk = select_block_shapes(8, 4096, 4096, "trit2")
        assert bk % 4 == 0

    def test_vmem_budget_shrinks_bk(self):
        _, _, bk = select_block_shapes(8, 65536, 128,
                                       vmem_budget_bytes=256 * 1024)
        assert bk <= 512

    def test_int8_domain_uses_int8_sublane(self):
        # int8 second-to-last-dim tile is 32 rows, not the f32 8
        bm, _, _ = select_block_shapes(8, 2048, 2048, domain="int8")
        assert bm == 32
        assert select_block_shapes(128, 2048, 2048,
                                   domain="int8") == DEFAULT_BLOCKS


# ------------------------------------------- decode shapes, three backends

DECODE_MS = [1, 4, 8]


class TestDecodeShapeEquivalence:
    @pytest.mark.parametrize("mode", ["base3", "trit2"])
    @pytest.mark.parametrize("m", DECODE_MS)
    def test_float_pallas_xla_oracle(self, m, mode):
        key = jax.random.PRNGKey(m)
        x = jax.random.normal(key, (m, 384), jnp.float32)
        w = 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (384, 256))
        pw = ops.pack_weights(w, mode)
        mkn = shape_of(x, pw)
        y_pallas = execute(plan_matmul(mkn, packing=mode, backend="pallas",
                                       interpret=True), x, pw)  # auto blocks
        y_xla = execute(plan_matmul(mkn, packing=mode, backend="xla"),
                        x, pw)
        y_oracle = ref.ternary_matmul_ref(x, pw.data, pw.scale, mode)
        np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_oracle),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_oracle),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("mode", ["base3", "trit2"])
    @pytest.mark.parametrize("m", DECODE_MS)
    def test_int8_domain_bitwise(self, m, mode):
        """Integer accumulation is exact: all three backends bit-match."""
        key = jax.random.PRNGKey(100 + m)
        x = jax.random.normal(key, (m, 384), jnp.float32)
        w = 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (384, 256))
        pw = ops.pack_weights(w, mode)
        mkn = shape_of(x, pw)
        y_pallas = execute(plan_matmul(mkn, packing=mode, domain="int8",
                                       backend="pallas", interpret=True),
                           x, pw)
        y_xla = execute(plan_matmul(mkn, packing=mode, domain="int8",
                                    backend="xla"), x, pw)
        xi, xs = ops.quantize_acts_int8(x)
        y_oracle = ref.ternary_matmul_int8_ref(xi, xs, pw.data, pw.scale,
                                               mode)
        np.testing.assert_array_equal(np.asarray(y_pallas),
                                      np.asarray(y_xla))
        np.testing.assert_array_equal(np.asarray(y_xla),
                                      np.asarray(y_oracle))

    @pytest.mark.parametrize("mode", ["base3", "trit2"])
    def test_int8_domain_via_dispatch_and_close_to_float(self, mode):
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (8, 256), jnp.float32)
        w = 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (256, 128))
        pw = ops.pack_weights(w, mode)
        mkn = shape_of(x, pw)
        y_int = execute(plan_matmul(mkn, packing=mode, domain="int8",
                                    backend="xla"), x, pw)
        y_f = execute(plan_matmul(mkn, packing=mode, backend="xla"), x, pw)
        rel = float(jnp.linalg.norm(y_int - y_f) /
                    (jnp.linalg.norm(y_f) + 1e-9))
        assert rel < 0.02, rel            # 7-bit activations: ~1% error
        with pytest.raises(ValueError, match="domain"):
            plan_matmul(mkn, packing=mode, domain="INT8")

    def test_int8_kernel_explicit_blocks_match_auto(self):
        key = jax.random.PRNGKey(9)
        x = jax.random.normal(key, (5, 200), jnp.float32)
        w = 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (200, 96))
        pw = ops.pack_weights(w, "trit2")
        mkn = shape_of(x, pw)
        auto = execute(plan_matmul(mkn, packing="trit2", domain="int8",
                                   backend="pallas", interpret=True), x, pw)
        pinned = execute(plan_matmul(mkn, packing="trit2", domain="int8",
                                     backend="pallas", interpret=True,
                                     bm=8, bn=32, bk=64), x, pw)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(pinned))


class TestXlaStackedWeights:
    def test_trit2_kpad_slices_contraction_axis(self):
        """Regression: layer-stacked (L, K/4, N) trit2 weights with K not
        a byte multiple — the K-padding slice must hit the K axis, not the
        leading layer axis."""
        key = jax.random.PRNGKey(3)
        k = 102                            # pads to 104 trits
        w = 0.02 * jax.random.normal(key, (2, k, 48))
        pw = ops.pack_weights(w, "trit2")
        assert pw.data.shape == (2, 26, 48)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, k))
        y = ops.ternary_matmul_xla(x, pw)          # (2, 4, 48)
        assert y.shape == (2, 4, 48)
        for layer in range(2):
            pl_ = ops.PackedTernary(pw.data[layer], pw.scale[layer], "trit2")
            np.testing.assert_allclose(np.asarray(y[layer]),
                                       np.asarray(ops.ternary_matmul_xla(
                                           x, pl_)), rtol=1e-6, atol=1e-6)


# --------------------------------------- plan API: old-vs-new dispatch

class TestPlanDispatchParity:
    """Bitwise parity of the deprecated kwarg routing vs plan/execute
    across EVERY (backend, domain, packing) dispatch cell."""

    @pytest.mark.parametrize("backend", ["pallas", "xla"])
    @pytest.mark.parametrize("domain", ["float", "int8"])
    @pytest.mark.parametrize("mode", ["base3", "trit2"])
    def test_cell_bitwise_identical(self, backend, domain, mode):
        key = jax.random.PRNGKey(42)
        x = jax.random.normal(key, (7, 384), jnp.float32)
        w = 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (384, 256))
        pw = ops.pack_weights(w, mode)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            y_old = ops.ternary_matmul(x, pw, backend=backend,
                                       domain=domain)
        plan = plan_matmul(shape_of(x, pw), backend=backend, domain=domain,
                           packing=mode)
        np.testing.assert_array_equal(np.asarray(y_old),
                                      np.asarray(execute(plan, x, pw)))

    def test_pinned_blocks_parity(self):
        x, w = (jax.random.normal(jax.random.PRNGKey(1), (5, 200)),
                0.02 * jax.random.normal(jax.random.PRNGKey(2), (200, 96)))
        pw = ops.pack_weights(w, "trit2")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            y_old = ops.ternary_matmul_int8(x, pw, interpret=True,
                                            bm=8, bn=32, bk=64)
        plan = plan_matmul(shape_of(x, pw), packing="trit2", domain="int8",
                           backend="pallas", interpret=True,
                           bm=8, bn=32, bk=64)
        assert plan.blocks == (8, 32, 64)
        np.testing.assert_array_equal(np.asarray(y_old),
                                      np.asarray(execute(plan, x, pw)))

    def test_plan_blocks_equal_adaptive_selection(self):
        # plan resolution hoists the same shape-adaptive choice the
        # kernel used to make per call (int8 lane uses its own sublane)
        p_f = plan_matmul((8, 1024, 1024), backend="pallas")
        p_i = plan_matmul((8, 1024, 1024), backend="pallas", domain="int8")
        assert p_f.blocks == select_block_shapes(8, 1024, 1024, "base3")
        assert p_i.blocks == select_block_shapes(8, 1024, 1024, "base3",
                                                 domain="int8")

    def test_plan_cache_hits_under_jit(self):
        from repro.core.cim_linear import CIMConfig, linear
        cfg = CIMConfig(mode="ternary", packing="base3").resolve()
        w = 0.02 * jax.random.normal(jax.random.PRNGKey(0), (128, 64))
        pw = ops.pack_weights(w, "base3")
        step = jax.jit(lambda x: linear(x, pw, cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 128))
        plan_cache_clear()
        step(x)                               # trace: one resolution
        misses = plan_cache_info().misses
        assert misses == 1
        step(x + 1.0)                         # warm executable: no resolve
        assert plan_cache_info().misses == misses
        step(jax.random.normal(jax.random.PRNGKey(2), (6, 128)))
        assert plan_cache_info().misses == misses + 1   # new shape only


# ------------------------------------------------------- bench metrics

class TestWallclockMetrics:
    def test_decode_flop_waste_reduction_ge_8x(self):
        from benchmarks.wallclock import padded_flops
        for m in (1, 4, 8, 16):
            for mode in ("base3", "trit2"):
                adaptive = select_block_shapes(m, 1024, 1024, mode)
                fixed = DEFAULT_BLOCKS
                red = (padded_flops(m, 1024, 1024, fixed)
                       / padded_flops(m, 1024, 1024, adaptive))
                assert red >= 8.0, (m, mode, red)

    def test_shape_cell_schema(self):
        from benchmarks import schema
        from benchmarks.wallclock import shape_cell
        cell = shape_cell(8, 1024, 1024, "base3", "decode", "xla",
                          time_it=False)
        assert schema.WALLCLOCK_CELL <= cell.keys()
        assert cell["flop_waste_fixed"] == 16 * cell["flop_waste_adaptive"]
        assert cell["hbm_bytes_adaptive"] < cell["hbm_bytes_fixed"]

    def test_schema_flags_missing_keys(self):
        from benchmarks import schema
        errs = schema.validate("wallclock", {"backend": "xla"})
        assert errs and "missing top-level keys" in errs[0]


# ------------------------------------------------------- serve fast lane

def _setup(arch="internlm2-1.8b"):
    from repro import configs
    from repro.models import registry
    cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _submit_mixed(eng, cfg, n=6, plen=8):
    from repro.serve import Request
    key = jax.random.key(1)
    for i in range(n):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,), 0,
                                    cfg.vocab_size)
        eng.submit(Request(uid=i, prompt=prompt,
                           max_new=5 if i % 2 else 3))


class TestOnDeviceServeLoop:
    def test_token_identical_to_legacy(self):
        from repro.serve import ServeEngine
        cfg, model, params = _setup()
        outs = {}
        for on_device in (True, False):
            eng = ServeEngine(model, params, capacity=64, max_batch=4,
                              on_device_loop=on_device)
            _submit_mixed(eng, cfg)
            outs[on_device] = {r.uid: r.out_tokens for r in eng.run()}
        assert outs[True] == outs[False]
        assert sorted(len(t) for t in outs[True].values()) == [3, 3, 3,
                                                               5, 5, 5]

    def test_one_host_transfer_per_bucket(self):
        from repro.serve import ServeEngine
        cfg, model, params = _setup()
        eng = ServeEngine(model, params, capacity=64, max_batch=4)
        _submit_mixed(eng, cfg, n=6)       # 6 reqs, max_batch 4 -> 2 buckets
        eng.run()
        assert eng.host_transfers == 2
        # legacy driver syncs every step: strictly more transfers
        leg = ServeEngine(model, params, capacity=64, max_batch=4,
                          on_device_loop=False)
        _submit_mixed(leg, cfg, n=6)
        leg.run()
        assert leg.host_transfers > leg.steps_run / 2
        assert leg.steps_run == eng.steps_run

    def test_eos_stops_row_on_device(self):
        from repro.serve import Request, ServeEngine, make_prefill_step
        cfg, model, params = _setup()
        prompt = jnp.zeros((4,), jnp.int32)
        pre = make_prefill_step(model, 32)
        tok, _ = pre(params, {"tokens": prompt[None]})
        eng = ServeEngine(model, params, capacity=32, max_batch=1)
        eng.submit(Request(uid=0, prompt=prompt, max_new=8,
                           eos_id=int(tok[0])))
        done = eng.run()
        assert len(done[0].out_tokens) == 1
        assert eng.host_transfers == 1

    def test_decode_loop_matches_step_loop_directly(self):
        from repro.serve import (make_decode_loop, make_decode_step,
                                 make_prefill_step)
        cfg, model, params = _setup()
        prompts = jnp.stack([jnp.arange(6, dtype=jnp.int32),
                             jnp.arange(6, dtype=jnp.int32)[::-1]])
        pre = make_prefill_step(model, 32)
        max_new = 5
        tok, state = pre(params, {"tokens": prompts})
        loop = make_decode_loop(model, max_new)
        buf, counts, steps = loop(
            params, tok, state,
            jnp.asarray([max_new, max_new], jnp.int32),
            jnp.asarray([-1, -1], jnp.int32))
        assert int(steps) == max_new - 1
        tok2, state2 = pre(params, {"tokens": prompts})
        dec = make_decode_step(model)
        want = [np.asarray(tok2)]
        for _ in range(max_new - 1):
            tok2, state2 = dec(params, tok2, state2)
            want.append(np.asarray(tok2))
        np.testing.assert_array_equal(np.asarray(buf),
                                      np.stack(want, axis=1))
        np.testing.assert_array_equal(np.asarray(counts), [max_new] * 2)
